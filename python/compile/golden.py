"""Golden outputs for the Rust integration tests.

Runs the JAX model directly (the same code that was AOT-lowered) on fixed
inputs and records logits, so `rust/tests/engine_integration.rs` can assert
that the full AOT -> HLO-text -> PJRT path reproduces JAX numerics.

Usage: python -m compile.golden --out ../artifacts
"""
from __future__ import annotations

import argparse
import json
import os

import jax.numpy as jnp
import numpy as np

from . import corpus, model as M
from .weights_io import load_weights


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--model", default="s")
    args = ap.parse_args()

    cfg = M.SIZES[args.model]
    params = {n: jnp.asarray(a) for n, a in load_weights(
        os.path.join(args.out, f"weights_{cfg.name}.bin"))}

    world = corpus.build_world(1)
    toks = corpus.generate_tokens(world, 424242, 64)
    tokens = jnp.asarray(np.array(toks, np.int32)[None, :])  # [1, 64]

    out = {"model": cfg.name, "tokens": [int(t) for t in toks]}

    # NONE prefill: last-position logits
    lg, _, _ = M.prefill(cfg, params, tokens, None, M.QuantSpec("none"),
                         fused=True)
    out["logits_none_last"] = [float(x) for x in np.asarray(lg)[0, -1]]

    # static q2 prefill with a fixed clip vector
    cv = jnp.full((cfg.n_layers,), -6.0, jnp.float32)
    lq, _, _ = M.prefill(cfg, params, tokens, cv, M.QuantSpec("static", 2),
                         fused=True)
    out["c_vec"] = [-6.0] * cfg.n_layers
    out["logits_q2_last"] = [float(x) for x in np.asarray(lq)[0, -1]]

    # decode consistency fixture: prefill 32 tokens, then the expected
    # logits when decoding token 32 at position 32.
    t32 = tokens[:, :32]
    lg32, kc, vc = M.prefill(cfg, params, t32, None, M.QuantSpec("none"),
                             fused=True)
    pad = cfg.max_seq - 32
    kc = jnp.pad(kc, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
    vc = jnp.pad(vc, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
    ld, _, _ = M.decode(cfg, params, tokens[:, 32], jnp.array([32]),
                        kc, vc, None, M.QuantSpec("none"))
    out["decode_pos"] = 32
    out["logits_decode32"] = [float(x) for x in np.asarray(ld)[0]]

    path = os.path.join(args.out, f"golden_{cfg.name}.json")
    with open(path, "w") as f:
        json.dump(out, f)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
