"""Pallas implementation of the EXAQ quantized softmax (paper §4, Algo. 2).

Two kernels:

  * `exaq_softmax_static`  — the hardware-faithful path. The clip threshold
    C is a per-call scalar (calibrated per layer, paper §5.1.1), so the two
    lookup tables are genuinely shared across the whole tensor:
      - LUT_exp  (2^M entries)      : code -> exp(v_code)      (paper §4.1)
      - LUT_sum  ((2^M)^g entries)  : packed key of g codes -> sum of their
        exps (paper §4.2, Fig. 5). g = 4 at M=2 (byte key), 2 at M=3/4.
    The denominator is computed with S/g LUT_sum gathers plus a closed-form
    correction for masked lanes (masked lanes are forced onto code 0, whose
    value is exactly C, so their total contribution is (S-n)*exp(C)).

  * `quant_softmax_dynamic` — the ablation path: per-row statistics decide C
    (EXAQ: C = slope*sigma + intercept; NAIVE: C = min/2). Per-row C means
    per-row tables, which defeats the LUT purpose in hardware, so this
    variant takes the direct exp/sum path; it exists to measure how much
    accuracy static calibration gives up (DESIGN.md experiment index).

TPU adaptation (DESIGN.md §3): the LUTs live in VMEM and the gathers are
one-op `jnp.take` per lane on the VPU — the analogue of the paper's 1-cycle
scalar LUT unit on Gaudi-2. Block shape (block_rows, S) keeps one softmax
row resident; quantize -> gather -> packed-sum -> normalize fuse into a
single HBM read + write per element.

Kernels are lowered with `interpret=True` everywhere: the CPU PJRT plugin
cannot execute Mosaic custom-calls, and interpret mode traces to plain HLO
that the Rust runtime can run (see /opt/xla-example/README.md).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

_NEG = jnp.finfo(jnp.float32).min


def _pad_rows(x, vlen, block_rows):
    """Pad the row axis up to a multiple of block_rows with dummy rows
    (vlen = S, x = 0) so the grid divides evenly; caller slices back."""
    R = x.shape[0]
    pad = (-R) % block_rows
    if pad:
        S = x.shape[1]
        x = jnp.concatenate([x, jnp.zeros((pad, S), x.dtype)], axis=0)
        vlen = jnp.concatenate(
            [vlen, jnp.full((pad,), S, vlen.dtype)], axis=0)
    return x, vlen, R


def _static_kernel(len_ref, x_ref, lexp_ref, lsum_ref, c_ref, o_ref,
                   *, bits: int, group: int):
    x = x_ref[...]                       # (BR, S)
    vlen = len_ref[...]                  # (BR,)
    C = c_ref[0]
    BR, S = x.shape
    nlev = (1 << bits) - 1
    step = -C / nlev

    lanes = jax.lax.broadcasted_iota(jnp.int32, (BR, S), 1)
    valid = lanes < vlen[:, None]

    # max over valid lanes, shift so xs <= 0
    m = jnp.max(jnp.where(valid, x, _NEG), axis=1, keepdims=True)
    xs = jnp.where(valid, jnp.clip(x - m, C, 0.0), C)

    # quantize: mid-tread codes; masked lanes land exactly on code 0
    codes = jnp.clip(jnp.round((xs - C) / step), 0, nlev).astype(jnp.int32)

    # (1) exponent via LUT_exp — single gather per lane (Algo.2 line 6)
    e = jnp.take(lexp_ref[...], codes, axis=0)

    # (2) denominator via LUT_sum over packed keys (Algo.2 lines 10-13):
    # S/g gathers instead of S accumulations.
    keyed = codes.reshape(BR, S // group, group)
    key = keyed[..., 0]
    for j in range(1, group):
        key = key + (keyed[..., j] << (bits * j))
    gsum = jnp.take(lsum_ref[...], key, axis=0)          # (BR, S/g)
    total = jnp.sum(gsum, axis=1)                        # (BR,)
    # masked-lane correction: each masked lane contributed exp(C) = LUT[0]
    n_masked = (S - vlen).astype(jnp.float32)
    denom = jnp.maximum(total - n_masked * lexp_ref[0], 1e-30)

    # (3) normalize
    o_ref[...] = jnp.where(valid, e / denom[:, None], 0.0)


@functools.partial(jax.jit, static_argnames=("bits", "block_rows"))
def exaq_softmax_static(x, valid_len, C, *, bits: int = 2,
                        block_rows: int = 8):
    """Quantized softmax with a shared (calibrated) clip threshold.

    x: [R, S] float32 rows; valid_len: [R] int32; C: scalar (< 0; clamped).
    Returns [R, S] probabilities, masked lanes exactly 0.
    """
    R0, S = x.shape
    group = ref.lut_group(bits)
    if S % group:
        raise ValueError(f"row length {S} not divisible by group {group}")
    C = jnp.minimum(jnp.asarray(C, jnp.float32), -ref.CLIP_EPS)
    lexp = ref.lut_exp_table(C, bits)
    lsum = ref.lut_sum_table(C, bits)
    x, valid_len, R0 = _pad_rows(x, valid_len.astype(jnp.int32), block_rows)
    R = x.shape[0]

    out = pl.pallas_call(
        functools.partial(_static_kernel, bits=bits, group=group),
        grid=(R // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((block_rows, S), lambda i: (i, 0)),
            pl.BlockSpec(lexp.shape, lambda i: (0,)),
            pl.BlockSpec(lsum.shape, lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, S), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, S), jnp.float32),
        interpret=True,
    )(valid_len, x, lexp, lsum, C.reshape(1))
    return out[:R0]


def _dynamic_kernel(len_ref, x_ref, coef_ref, o_ref, *, bits: int,
                    naive: bool):
    x = x_ref[...]
    vlen = len_ref[...]
    BR, S = x.shape
    nlev = (1 << bits) - 1

    lanes = jax.lax.broadcasted_iota(jnp.int32, (BR, S), 1)
    valid = lanes < vlen[:, None]
    n = jnp.maximum(vlen, 1).astype(jnp.float32)

    m = jnp.max(jnp.where(valid, x, _NEG), axis=1, keepdims=True)
    xs = jnp.where(valid, x - m, 0.0)

    if naive:
        # NAIVE baseline: midpoint of [min, max] = min/2 (max(xs) == 0)
        mn = jnp.min(jnp.where(valid, xs, 0.0), axis=1)
        C = mn / 2.0
    else:
        s1 = jnp.sum(jnp.where(valid, xs, 0.0), axis=1)
        s2 = jnp.sum(jnp.where(valid, xs * xs, 0.0), axis=1)
        mean = s1 / n
        sigma = jnp.sqrt(jnp.maximum(s2 / n - mean * mean, 0.0))
        C = coef_ref[0] * sigma + coef_ref[1]
    C = jnp.minimum(C, -ref.CLIP_EPS)[:, None]
    step = -C / nlev

    xs = jnp.where(valid, jnp.clip(xs, C, 0.0), C)
    codes = jnp.clip(jnp.round((xs - C) / step), 0, nlev)
    e = jnp.exp(C + codes * step)
    denom = jnp.maximum(
        jnp.sum(jnp.where(valid, e, 0.0), axis=1, keepdims=True), 1e-30)
    o_ref[...] = jnp.where(valid, e / denom, 0.0)


@functools.partial(jax.jit,
                   static_argnames=("bits", "mode", "block_rows"))
def quant_softmax_dynamic(x, valid_len, *, bits: int = 2,
                          mode: str = "exaq", block_rows: int = 8,
                          slope: float | None = None,
                          intercept: float | None = None):
    """Dynamic-statistics quantized softmax (per-row C). mode: exaq|naive."""
    R0, S = x.shape
    if mode == "exaq":
        if slope is None or intercept is None:
            slope, intercept = ref.EXAQ_TABLE1[bits]
    else:
        slope, intercept = 0.0, 0.0
    coef = jnp.array([slope, intercept], jnp.float32)
    x, valid_len, R0 = _pad_rows(x, valid_len.astype(jnp.int32), block_rows)
    R = x.shape[0]

    out = pl.pallas_call(
        functools.partial(_dynamic_kernel, bits=bits,
                          naive=(mode == "naive")),
        grid=(R // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((block_rows, S), lambda i: (i, 0)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, S), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, S), jnp.float32),
        interpret=True,
    )(valid_len, x, coef)
    return out[:R0]
