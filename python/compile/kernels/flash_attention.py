"""Fused causal attention with EXAQ-quantized softmax (one Pallas kernel).

The unfused model path materialises the full [B,H,Q,S] score tensor in HBM,
round-trips it through the softmax kernel, then reads it again for the PV
matmul — three HBM passes over the largest tensor in the layer. This kernel
keeps one (q-block, S) score tile in VMEM and does

    QK^T -> max-shift -> quantize -> LUT_exp gather -> LUT_sum packed
    denominator -> normalize -> PV

in a single pass: one HBM read of Q/K/V and one write of O per element,
which is the paper's bandwidth argument (§1: "runtime, bandwidth and
memory") realised with BlockSpec instead of threadblocks (DESIGN.md §3).

Grid: (B*H, Q/block_q). K and V for the whole row (S, hd) are resident in
VMEM — fine for the sequence lengths this repo targets (S <= 512; VMEM
budget table in EXPERIMENTS.md §Perf). bits=None gives the exact-softmax
fused baseline used for the NONE rows of Table 2.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

_NEG = jnp.finfo(jnp.float32).min


def _fused_kernel(q_ref, k_ref, v_ref, lexp_ref, lsum_ref, c_ref, o_ref,
                  *, bits, group, scale, q_offset, block_q):
    # q: (1, BQ, hd); k/v: (1, S, hd) — leading dim is the B*H grid axis.
    q = q_ref[0]                       # (BQ, hd)
    k = k_ref[0]                       # (S, hd)
    v = v_ref[0]
    BQ, hd = q.shape
    S = k.shape[0]

    scores = jnp.dot(q, k.T) * scale   # (BQ, S) — MXU work

    # causal validity: query row i (global q_offset + block index) sees
    # k-positions 0..global_i (+ kv history offset folded into q_offset).
    qi = pl.program_id(1) * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (BQ, S), 0)
    lanes = jax.lax.broadcasted_iota(jnp.int32, (BQ, S), 1)
    valid = lanes <= (qi + q_offset)

    m = jnp.max(jnp.where(valid, scores, _NEG), axis=1, keepdims=True)
    if bits is None:
        e = jnp.where(valid, jnp.exp(scores - m), 0.0)
        denom = jnp.maximum(jnp.sum(e, axis=1, keepdims=True), 1e-30)
        p = e / denom
    else:
        C = c_ref[0]
        nlev = (1 << bits) - 1
        step = -C / nlev
        xs = jnp.where(valid, jnp.clip(scores - m, C, 0.0), C)
        codes = jnp.clip(jnp.round((xs - C) / step), 0, nlev).astype(
            jnp.int32)
        e = jnp.take(lexp_ref[...], codes, axis=0)
        keyed = codes.reshape(BQ, S // group, group)
        key = keyed[..., 0]
        for j in range(1, group):
            key = key + (keyed[..., j] << (bits * j))
        total = jnp.sum(jnp.take(lsum_ref[...], key, axis=0), axis=1)
        n_masked = jnp.sum(jnp.where(valid, 0.0, 1.0), axis=1)
        denom = jnp.maximum(total - n_masked * lexp_ref[0], 1e-30)
        p = jnp.where(valid, e / denom[:, None], 0.0)

    o_ref[0] = jnp.dot(p, v)           # (BQ, hd)


@functools.partial(jax.jit,
                   static_argnames=("bits", "block_q", "q_offset"))
def fused_attention(q, k, v, C=None, *, bits: int | None = 2,
                    block_q: int = 16, q_offset: int = 0):
    """Fused causal MHA. q: [B,H,Q,hd]; k,v: [B,H,S,hd]; C scalar clip.

    q_offset: global position of q row 0 relative to the KV sequence
    (prefill: 0 with Q == S; decode-style: S - Q).
    """
    B, H, Q, hd = q.shape
    S = k.shape[2]
    group = ref.lut_group(bits) if bits is not None else 1
    if bits is not None and S % group:
        raise ValueError(f"S={S} not divisible by LUT group {group}")
    bq = min(block_q, Q)
    if Q % bq:
        raise ValueError(f"Q={Q} not divisible by block_q={bq}")
    scale = 1.0 / (hd ** 0.5)

    if bits is not None:
        C = jnp.minimum(jnp.asarray(C, jnp.float32), -ref.CLIP_EPS)
        lexp = ref.lut_exp_table(C, bits)
        lsum = ref.lut_sum_table(C, bits)
        carr = C.reshape(1)
    else:  # placeholders so the kernel arity is stable
        lexp = jnp.zeros((1,), jnp.float32)
        lsum = jnp.zeros((1,), jnp.float32)
        carr = jnp.zeros((1,), jnp.float32)

    qf = q.reshape(B * H, Q, hd)
    kf = k.reshape(B * H, S, hd)
    vf = v.reshape(B * H, S, hd)

    out = pl.pallas_call(
        functools.partial(_fused_kernel, bits=bits, group=group,
                          scale=scale, q_offset=q_offset, block_q=bq),
        grid=(B * H, Q // bq),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda g, i: (g, i, 0)),
            pl.BlockSpec((1, S, hd), lambda g, i: (g, 0, 0)),
            pl.BlockSpec((1, S, hd), lambda g, i: (g, 0, 0)),
            pl.BlockSpec(lexp.shape, lambda g, i: (0,)),
            pl.BlockSpec(lsum.shape, lambda g, i: (0,)),
            pl.BlockSpec((1,), lambda g, i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda g, i: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Q, hd), jnp.float32),
        interpret=True,
    )(qf, kf, vf, lexp, lsum, carr)
    return out.reshape(B, H, Q, hd)
