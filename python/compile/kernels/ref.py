"""Pure-jnp reference oracles for the EXAQ kernels.

Everything in this file is the *ground truth* the Pallas kernels are tested
against (pytest + hypothesis in python/tests/test_kernels.py). The reference
computes the same mathematics through a different computational path —
direct `exp` of the quantized values and explicit masked reductions instead
of LUT gathers and packed LUT_sum accumulation — so agreement is a real
signal, not a tautology.

Quantization spec (shared with rust/src/exaq/quant.rs — keep in sync):

  Given a softmax input row x[0..S) with `n` valid leading lanes:
    m      = max over valid lanes
    xs     = x - m                      (so xs <= 0 on valid lanes)
    C < 0  = clip threshold (static: calibrated per layer; dynamic EXAQ:
             C = slope * sigma(xs_valid) + intercept; dynamic NAIVE:
             C = (min(xs_valid) + max(xs_valid)) / 2 = min(xs_valid)/2)
    levels = mid-tread on [C, 0]: step = -C / (2^M - 1), v_k = C + k*step,
             k = clamp(round((xs - C)/step), 0, 2^M - 1)
    masked lanes are forced to xs = C so they land exactly on code 0
    e_k    = exp(v_k)   (LUT_exp)
    denom  = sum of e over valid lanes
           = (packed LUT_sum over all lanes) - (S - n) * exp(C)
    out    = e / denom on valid lanes, 0 elsewhere.

  Note vs. the paper: the paper's error analysis uses Δ = -C/2^M (mid-rise);
  we realise the quantizer as mid-tread with Δ' = -C/(2^M - 1) so that the
  row maximum (xs = 0) is representable exactly — essential at M=2 where
  losing the peak of the distribution costs more than the analysis'
  constant-factor difference. The analytic clipping solver
  (rust/src/exaq/solver.rs) keeps the paper's Δ so Table 1 reproduces the
  published coefficients.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

#: Minimum magnitude for the clip threshold; C is clamped to <= -CLIP_EPS so
#: the quantization step is never zero (degenerate all-equal rows).
CLIP_EPS = 1e-3

#: Table 1 of the paper: M -> (slope, intercept) of C*(sigma).
EXAQ_TABLE1 = {2: (-1.66, -1.85), 3: (-1.75, -2.06), 4: (-1.02, -3.62)}
# M=4 is our extension (paper §4.2 mentions 4-bit packing); coefficients
# come from rust `repro fit-table1 --bits 4` and are cross-checked in tests.


def lut_group(bits: int) -> int:
    """How many codes are packed into one LUT_sum key (paper: byte-sized
    keys -> 4 codes at 2 bits; 2 codes at 3 and 4 bits)."""
    return {2: 4, 3: 2, 4: 2}[bits]


def quant_codes(xs, C, bits: int):
    """Mid-tread quantization codes of xs (assumed <= 0) against clip C<0."""
    nlev = (1 << bits) - 1
    step = -C / nlev
    k = jnp.round((xs - C) / step)
    return jnp.clip(k, 0, nlev).astype(jnp.int32)


def dequant(codes, C, bits: int):
    nlev = (1 << bits) - 1
    step = -C / nlev
    return C + codes.astype(jnp.float32) * step


def lut_exp_table(C, bits: int):
    """LUT_exp: code -> exp(v_code). Shape (2^bits,)."""
    k = jnp.arange(1 << bits, dtype=jnp.float32)
    nlev = (1 << bits) - 1
    step = -C / nlev
    return jnp.exp(C + k * step)


def lut_sum_table(C, bits: int):
    """LUT_sum: packed key of `lut_group(bits)` codes -> sum of their exps.
    Key layout (low code first): key = sum_j codes[j] << (bits * j).
    Shape ((2^bits)^group,)."""
    g = lut_group(bits)
    e = lut_exp_table(C, bits)  # (2^bits,)
    n = 1 << bits
    keys = jnp.arange(n ** g)
    total = jnp.zeros(n ** g, dtype=jnp.float32)
    for j in range(g):
        digit = (keys >> (bits * j)) % n
        total = total + e[digit]
    return total


def _row_stats(xs, valid):
    """(sigma, min) over valid lanes of xs, rows of shape [..., S]."""
    n = jnp.maximum(jnp.sum(valid, axis=-1), 1).astype(jnp.float32)
    s1 = jnp.sum(jnp.where(valid, xs, 0.0), axis=-1)
    s2 = jnp.sum(jnp.where(valid, xs * xs, 0.0), axis=-1)
    mean = s1 / n
    var = jnp.maximum(s2 / n - mean * mean, 0.0)
    sigma = jnp.sqrt(var)
    mn = jnp.min(jnp.where(valid, xs, 0.0), axis=-1)
    return sigma, mn


def clip_from_mode(xs, valid, mode: str, bits: int,
                   slope=None, intercept=None):
    """Per-row dynamic clip threshold. mode in {'exaq','naive'}."""
    sigma, mn = _row_stats(xs, valid)
    if mode == "exaq":
        if slope is None or intercept is None:
            slope, intercept = EXAQ_TABLE1[bits]
        C = slope * sigma + intercept
    elif mode == "naive":
        C = mn / 2.0
    else:
        raise ValueError(f"unknown mode {mode}")
    return jnp.minimum(C, -CLIP_EPS)


def exact_softmax(x, valid_len):
    """Masked exact softmax over the last axis. x: [..., S],
    valid_len: [...] int — number of valid leading lanes per row."""
    S = x.shape[-1]
    lanes = jnp.arange(S)
    valid = lanes < valid_len[..., None]
    neg = jnp.finfo(jnp.float32).min
    xm = jnp.where(valid, x, neg)
    m = jnp.max(xm, axis=-1, keepdims=True)
    e = jnp.where(valid, jnp.exp(x - m), 0.0)
    denom = jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-30)
    return e / denom


def quant_softmax(x, valid_len, bits: int, C=None, mode: str = "exaq",
                  slope=None, intercept=None):
    """Reference quantized softmax (static if C given, else dynamic).

    x: [..., S] float32; valid_len: [...] int32; C: scalar (static) or None
    (dynamic per-row). Returns probabilities with masked lanes exactly 0.
    """
    S = x.shape[-1]
    lanes = jnp.arange(S)
    valid = lanes < valid_len[..., None]
    neg = jnp.finfo(jnp.float32).min
    xm = jnp.where(valid, x, neg)
    m = jnp.max(xm, axis=-1, keepdims=True)
    xs = jnp.where(valid, x - m, 0.0)

    if C is None:
        C = clip_from_mode(xs, valid, mode, bits, slope, intercept)[..., None]
    else:
        C = jnp.minimum(jnp.asarray(C, jnp.float32), -CLIP_EPS)
        C = jnp.broadcast_to(C, xs.shape[:-1])[..., None]

    # masked lanes forced onto code 0 (value exactly C)
    xs = jnp.where(valid, jnp.clip(xs, C, 0.0), C)
    codes = quant_codes(xs, C, bits)
    e = jnp.exp(dequant(codes, C, bits))
    denom = jnp.maximum(
        jnp.sum(jnp.where(valid, e, 0.0), axis=-1, keepdims=True), 1e-30)
    return jnp.where(valid, e / denom, 0.0)


def causal_valid_len(q_len: int, k_len: int):
    """valid_len vector for causal attention: row i attends to k-positions
    0..(k_len - q_len + i). Standard prefill: q_len == k_len -> i+1."""
    off = k_len - q_len
    return jnp.arange(q_len, dtype=jnp.int32) + off + 1


def attention_ref(q, k, v, bits=None, C=None, mode="exaq"):
    """Reference causal MHA core. q: [B,H,Q,hd], k/v: [B,H,S,hd].
    bits=None -> exact softmax; else quantized (static C or dynamic mode)."""
    B, H, Q, hd = q.shape
    S = k.shape[2]
    scale = 1.0 / np.sqrt(hd)
    scores = jnp.einsum("bhqd,bhsd->bhqs", q, k) * scale
    vlen = jnp.broadcast_to(causal_valid_len(Q, S), (B, H, Q))
    if bits is None:
        p = exact_softmax(scores, vlen)
    else:
        p = quant_softmax(scores, vlen, bits, C=C, mode=mode)
    return jnp.einsum("bhqs,bhsd->bhqd", p, v)
