"""Procedural world, vocabulary and training corpus for tinyllama.

This module is the *specification* of the synthetic language shared between
the Python build path (training corpus) and the Rust runtime
(`rust/src/eval/world.rs`, the eval-task generators). Both sides implement
the exact same deterministic derivation:

  SplitMix64(world_seed) drives, in this exact call order:
    1. for each object i in 0..N_OBJECTS: color[i], material[i]
    2. a Fisher-Yates shuffle of the object indices (owned-object permutation)
    3. for each person p in 0..N_PEOPLE: place[p]

Cross-language consistency is enforced by the golden dump
(`artifacts/world.json`, written by `dump_world`) which the Rust test-suite
re-derives and compares byte-for-byte.

The language is a closed-vocabulary, fully regular "world-fact" English:
attribute statements, ownership, location, hardness comparisons, Q/A forms
and two-hop property chains. The seven evaluation task families in
`rust/src/eval/tasks.rs` are drawn from the same templates, so evaluation
prompts are in-distribution and a converged model scores far above chance —
which is what makes softmax-quantization damage measurable (the paper's
Table 2 axis).
"""
from __future__ import annotations

import json
from dataclasses import dataclass

MASK64 = (1 << 64) - 1


class SplitMix64:
    """Deterministic PRNG, mirrored bit-for-bit in rust/src/util/rng.rs."""

    def __init__(self, seed: int):
        self.state = seed & MASK64

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
        return (z ^ (z >> 31)) & MASK64

    def below(self, n: int) -> int:
        """Unbiased-enough modulo draw (spec: plain modulo, both languages)."""
        return self.next_u64() % n

    def uniform(self) -> float:
        """f64 in [0,1): top 53 bits / 2^53 (same derivation in Rust)."""
        return (self.next_u64() >> 11) / float(1 << 53)


# ---------------------------------------------------------------------------
# Fixed word lists — identical constants in rust/src/eval/world.rs.
# ---------------------------------------------------------------------------
NAMES = [
    "alice", "bob", "carol", "david", "erin", "frank", "grace", "henry",
    "iris", "jack", "karen", "leo", "mona", "nina", "oscar", "paul",
    "quinn", "rosa", "sam", "tina",
]
OBJECTS = [
    "ball", "cup", "book", "knife", "hammer", "pillow", "bottle", "lamp",
    "chair", "rope", "coin", "plate", "shirt", "box", "mirror", "brick",
    "blanket", "spoon", "vase", "drum", "kite", "glove", "candle", "basket",
]
PLACES = [
    "kitchen", "garden", "library", "garage", "park", "office", "attic",
    "cellar", "market", "station", "museum", "bakery",
]
COLORS = ["red", "blue", "green", "yellow", "black", "white", "purple", "orange"]
MATERIALS = ["wood", "metal", "glass", "stone", "cloth", "plastic", "rubber", "paper"]
PROPERTIES = ["hard", "soft", "fragile", "sturdy", "heavy", "light"]
FUNCTION_WORDS = [
    "the", "is", "in", "has", "made", "of", "than", "harder", "softer",
    "question", "answer", "yes", "no", "it", "belongs", "to", "a",
    "which", "or",
]
PUNCT = [".", "?", ":"]
SPECIALS = ["<pad>", "<bos>", "<eos>", "<sep>"]

#: material -> characteristic property (the "open book" fact table).
MATERIAL_PROP = {
    "wood": "sturdy",
    "metal": "heavy",
    "glass": "fragile",
    "stone": "hard",
    "cloth": "soft",
    "plastic": "light",
    "rubber": "soft",
    "paper": "fragile",
}
#: material -> hardness rank for comparison sentences (higher = harder).
HARDNESS = {
    "stone": 7, "metal": 6, "wood": 5, "glass": 4,
    "plastic": 3, "rubber": 2, "paper": 1, "cloth": 0,
}

VOCAB: list[str] = (
    SPECIALS + NAMES + OBJECTS + PLACES + COLORS + MATERIALS + PROPERTIES
    + FUNCTION_WORDS + PUNCT
)
TOK = {w: i for i, w in enumerate(VOCAB)}
VOCAB_SIZE = len(VOCAB)
PAD, BOS, EOS, SEP = TOK["<pad>"], TOK["<bos>"], TOK["<eos>"], TOK["<sep>"]

N_PEOPLE, N_OBJECTS, N_PLACES = len(NAMES), len(OBJECTS), len(PLACES)
N_COLORS, N_MATERIALS = len(COLORS), len(MATERIALS)


def encode(words: list[str]) -> list[int]:
    return [TOK[w] for w in words]


def decode(ids) -> list[str]:
    return [VOCAB[int(i)] for i in ids]


# ---------------------------------------------------------------------------
# World derivation
# ---------------------------------------------------------------------------
@dataclass
class World:
    seed: int
    color: list[int]      # object index  -> color index
    material: list[int]   # object index  -> material index
    owned: list[int]      # person index  -> object index (injective)
    place: list[int]      # person index  -> place index

    def object_color(self, obj: int) -> str:
        return COLORS[self.color[obj]]

    def object_material(self, obj: int) -> str:
        return MATERIALS[self.material[obj]]

    def object_property(self, obj: int) -> str:
        return MATERIAL_PROP[self.object_material(obj)]

    def object_hardness(self, obj: int) -> int:
        return HARDNESS[self.object_material(obj)]

    def owner_of(self, obj: int) -> int | None:
        try:
            return self.owned.index(obj)
        except ValueError:
            return None


def build_world(seed: int) -> World:
    rng = SplitMix64(seed)
    color = []
    material = []
    for _ in range(N_OBJECTS):
        color.append(rng.below(N_COLORS))
        material.append(rng.below(N_MATERIALS))
    # Fisher-Yates over object indices; person p owns perm[p].
    perm = list(range(N_OBJECTS))
    for i in range(N_OBJECTS - 1, 0, -1):
        j = rng.below(i + 1)
        perm[i], perm[j] = perm[j], perm[i]
    owned = perm[:N_PEOPLE]
    place = [rng.below(N_PLACES) for _ in range(N_PEOPLE)]
    return World(seed=seed, color=color, material=material, owned=owned, place=place)


# ---------------------------------------------------------------------------
# Sentence templates. Each generator returns a list of words (already split).
# Template ids are shared with Rust (eval task families reference them).
# ---------------------------------------------------------------------------
def s_color(w: World, obj: int) -> list[str]:
    return ["the", OBJECTS[obj], "is", w.object_color(obj), "."]


def s_material(w: World, obj: int) -> list[str]:
    return ["the", OBJECTS[obj], "is", "made", "of", w.object_material(obj), "."]


def s_mat_prop(mat: int) -> list[str]:
    m = MATERIALS[mat]
    return [m, "is", MATERIAL_PROP[m], "."]


def s_place(w: World, person: int) -> list[str]:
    return [NAMES[person], "is", "in", "the", PLACES[w.place[person]], "."]


def s_has(w: World, person: int) -> list[str]:
    return [NAMES[person], "has", "the", OBJECTS[w.owned[person]], "."]


def s_belongs(w: World, person: int) -> list[str]:
    return ["the", OBJECTS[w.owned[person]], "belongs", "to", NAMES[person], "."]


def s_harder(w: World, a: int, b: int) -> list[str]:
    """Comparison sentence; only emitted when strictly comparable."""
    ha, hb = w.object_hardness(a), w.object_hardness(b)
    if ha > hb:
        return ["the", OBJECTS[a], "is", "harder", "than", "the", OBJECTS[b], "."]
    return ["the", OBJECTS[b], "is", "harder", "than", "the", OBJECTS[a], "."]


def s_bool_qa(w: World, obj: int, color: int) -> list[str]:
    ans = "yes" if w.color[obj] == color else "no"
    return ["question", ":", "is", "the", OBJECTS[obj], COLORS[color], "?",
            "answer", ":", ans, "."]


def s_which_harder(w: World, a: int, b: int) -> list[str]:
    winner = a if w.object_hardness(a) > w.object_hardness(b) else b
    return ["question", ":", "which", "is", "harder", ":", OBJECTS[a], "or",
            OBJECTS[b], "?", "answer", ":", OBJECTS[winner], "."]


def s_coref(w: World, person: int) -> list[str]:
    obj = w.owned[person]
    return [NAMES[person], "has", "the", OBJECTS[obj], ".",
            "it", "is", w.object_color(obj), "."]


def s_chain(w: World, obj: int) -> list[str]:
    m = w.object_material(obj)
    return ["the", OBJECTS[obj], "is", "made", "of", m, ".",
            m, "is", MATERIAL_PROP[m], ".",
            "the", OBJECTS[obj], "is", MATERIAL_PROP[m], "."]


def s_prop_direct(w: World, obj: int) -> list[str]:
    """Two-hop fact stated directly (teaches the arc-challenge composition)."""
    return ["the", OBJECTS[obj], "is", w.object_property(obj), "."]


#: template id -> (sampler arity spec). Sampling order of rng calls is part
#: of the spec: first the template index, then each argument in order.
N_TEMPLATES = 11


def sample_sentence(w: World, rng: SplitMix64) -> list[str]:
    t = rng.below(N_TEMPLATES)
    if t == 0:
        return s_color(w, rng.below(N_OBJECTS))
    if t == 1:
        return s_material(w, rng.below(N_OBJECTS))
    if t == 2:
        return s_mat_prop(rng.below(N_MATERIALS))
    if t == 3:
        return s_place(w, rng.below(N_PEOPLE))
    if t == 4:
        return s_has(w, rng.below(N_PEOPLE))
    if t == 5:
        return s_belongs(w, rng.below(N_PEOPLE))
    if t == 6:
        a = rng.below(N_OBJECTS)
        b = rng.below(N_OBJECTS)
        while w.object_hardness(a) == w.object_hardness(b):
            b = rng.below(N_OBJECTS)
        return s_harder(w, a, b)
    if t == 7:
        obj = rng.below(N_OBJECTS)
        # 50/50 true/false colour question: draw a colour, coin-flip to force
        # the true colour.
        color = rng.below(N_COLORS)
        if rng.below(2) == 0:
            color = w.color[obj]
        return s_bool_qa(w, obj, color)
    if t == 8:
        a = rng.below(N_OBJECTS)
        b = rng.below(N_OBJECTS)
        while w.object_hardness(a) == w.object_hardness(b):
            b = rng.below(N_OBJECTS)
        return s_which_harder(w, a, b)
    if t == 9:
        return s_coref(w, rng.below(N_PEOPLE))
    return s_chain(w, rng.below(N_OBJECTS))


def generate_tokens(world: World, corpus_seed: int, n_tokens: int) -> list[int]:
    """Token stream: sentences back-to-back, <sep> between documents of ~8
    sentences. The stream is later chunked into fixed-length rows."""
    rng = SplitMix64(corpus_seed)
    out: list[int] = [BOS]
    sent_in_doc = 0
    while len(out) < n_tokens:
        out.extend(encode(sample_sentence(world, rng)))
        sent_in_doc += 1
        if sent_in_doc == 8:
            out.append(SEP)
            sent_in_doc = 0
    return out[:n_tokens]


def dump_world(world: World, path: str) -> None:
    """Golden dump consumed by the Rust cross-check test."""
    payload = {
        "seed": world.seed,
        "vocab": VOCAB,
        "color": world.color,
        "material": world.material,
        "owned": world.owned,
        "place": world.place,
        "material_prop": MATERIAL_PROP,
        "hardness": HARDNESS,
        # A short golden corpus prefix pins the sentence-sampler spec too.
        "corpus_prefix": generate_tokens(world, corpus_seed=world.seed + 1,
                                         n_tokens=256),
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)


if __name__ == "__main__":
    w = build_world(1)
    toks = generate_tokens(w, 2, 200)
    print(" ".join(decode(toks)))
