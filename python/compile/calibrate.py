"""Build-time calibration: per-layer softmax-input statistics.

Mirrors the paper's §5.1.1 protocol: a calibration set of 100 sequences run
as 25 iterations of batch 4. For each model we record per-layer
(sigma, min, mean, count) plus the per-iteration sigma series that
regenerates Fig. 6 (sigma of softmax inputs across layers and iterations).

The Rust side consumes artifacts/calibration.json and derives the clip
thresholds itself (rust/src/exaq/clip.rs):
    EXAQ : C_l = slope_M * sigma_l + intercept_M     (Table 1)
    NAIVE: C_l = (min_l + max_l) / 2 = min_l / 2     (max = 0 post-shift)

The same statistics can be regenerated at runtime by the Rust calibration
driver (rust/src/calib) through the `prefill_stats` artifact; this script
exists so `make artifacts` yields a complete, self-consistent bundle
without needing the Rust binary mid-build.

Usage: python -m compile.calibrate --out ../artifacts
"""
from __future__ import annotations

import argparse
import json
import os

import jax.numpy as jnp
import numpy as np

from . import corpus, model as M
from .train import FAMILY_WORLD_SEED
from .weights_io import load_weights

CALIB_ITERS = 25
CALIB_BATCH = 4
CALIB_SEED = 20240555


def welford_merge(a, b):
    """a,b: (count, mean, M2, min) -> combined."""
    n1, m1, M1, mn1 = a
    n2, m2, M2, mn2 = b
    n = n1 + n2
    d = m2 - m1
    return (n, m1 + d * n2 / n, M1 + M2 + d * d * n1 * n2 / n,
            min(mn1, mn2))


def calibrate_model(cfg: M.ModelConfig, params, family: int):
    world = corpus.build_world(FAMILY_WORLD_SEED[family])
    seq = M.SIZES["s"].max_seq if False else 64
    toks = corpus.generate_tokens(
        world, CALIB_SEED, CALIB_ITERS * CALIB_BATCH * seq + 1)
    agg = [None] * cfg.n_layers
    fig6 = []  # per-iteration, per-layer sigma
    for it in range(CALIB_ITERS):
        lo = it * CALIB_BATCH * seq
        t = jnp.asarray(np.array(toks[lo: lo + CALIB_BATCH * seq],
                                 dtype=np.int32).reshape(CALIB_BATCH, seq))
        _, st = M.prefill_stats(cfg, params, t,
                                jnp.full((CALIB_BATCH,), seq, jnp.int32))
        st = np.asarray(st, np.float64)
        fig6.append([float(np.sqrt(r[2] / r[0])) for r in st])
        for layer in range(cfg.n_layers):
            row = tuple(st[layer])
            agg[layer] = row if agg[layer] is None else \
                welford_merge(agg[layer], row)
    layers = []
    for n, mean, m2, mn in agg:
        layers.append({"count": n, "mean": mean,
                       "sigma": float(np.sqrt(m2 / n)), "min": mn})
    return {"layers": layers, "fig6_sigma": fig6,
            "iters": CALIB_ITERS, "batch": CALIB_BATCH, "seq": seq}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()

    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)

    out = {"protocol": {"iters": CALIB_ITERS, "batch": CALIB_BATCH,
                        "set_size": CALIB_ITERS * CALIB_BATCH},
           "models": {}}
    for name, info in manifest["models"].items():
        c = info["config"]
        cfg = M.ModelConfig(
            name=c["name"], n_layers=c["n_layers"], d_model=c["d_model"],
            n_heads=c["n_heads"], d_ff=c["d_ff"],
            vocab_size=c["vocab_size"], max_seq=c["max_seq"])
        params = {n: jnp.asarray(a) for n, a in load_weights(
            os.path.join(args.out, info["weights"]))}
        out["models"][name] = calibrate_model(cfg, params, info["family"])
        sig = [round(l["sigma"], 3) for l in out["models"][name]["layers"]]
        print(f"{name}: sigma per layer = {sig}")

    with open(os.path.join(args.out, "calibration.json"), "w") as f:
        json.dump(out, f, indent=1)
    print("wrote calibration.json")


if __name__ == "__main__":
    main()
