"""Build-time training of the tinyllama family on the procedural corpus.

This is the build-path substitute for downloading pretrained LLaMA weights
(DESIGN.md §2): the paper's method is post-training quantization, so all it
needs from the model is a converged attention stack whose softmax-input
distribution looks like Fig. 6 (sigma roughly in [0.9, 3.4]). Training uses
exact softmax (quantization is applied only at inference, as in the paper).

Hand-rolled AdamW + cosine schedule (no optax in the image). The step is
jitted once and scanned in chunks so the Python overhead is negligible.

Usage:  python -m compile.train --size s --family 1 --out ../artifacts
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus, model as M
from .weights_io import save_weights

#: world seeds per family — family 2 ("LLaMA-2", Table 5) lives in a
#: different world instance so its facts differ.
FAMILY_WORLD_SEED = {1: 1, 2: 7}
CORPUS_SEED = {1: 11, 2: 17}


def make_dataset(family: int, n_tokens: int, seq: int) -> np.ndarray:
    world = corpus.build_world(FAMILY_WORLD_SEED[family])
    toks = corpus.generate_tokens(world, CORPUS_SEED[family], n_tokens)
    n_rows = (len(toks) - 1) // seq
    x = np.array(toks[: n_rows * seq + 1], dtype=np.int32)
    rows = np.stack([x[i * seq: i * seq + seq + 1] for i in range(n_rows)])
    return rows  # [N, seq+1]


def loss_fn(cfg, params, batch):
    tokens, targets = batch[:, :-1], batch[:, 1:]
    logits, _, _ = M.prefill(cfg, params, tokens, fused=False,
                             quant=M.QuantSpec("none"))
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


def adamw_init(params):
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": zeros, "v": {k: jnp.zeros_like(v) for k, v in
                              params.items()}, "t": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, opt, lr, *, b1=0.9, b2=0.95, eps=1e-8,
                 wd=0.01):
    t = opt["t"] + 1
    tf = t.astype(jnp.float32)
    new_m, new_v, new_p = {}, {}, {}
    for k in params:
        m = b1 * opt["m"][k] + (1 - b1) * grads[k]
        v = b2 * opt["v"][k] + (1 - b2) * grads[k] ** 2
        mh = m / (1 - b1 ** tf)
        vh = v / (1 - b2 ** tf)
        decay = wd if params[k].ndim >= 2 else 0.0
        new_p[k] = params[k] - lr * (mh / (jnp.sqrt(vh) + eps)
                                     + decay * params[k])
        new_m[k], new_v[k] = m, v
    return new_p, {"m": new_m, "v": new_v, "t": t}


def cosine_lr(step, total, peak, warmup=40, floor_frac=0.1):
    warm = peak * (step + 1) / warmup
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
    cos = peak * (floor_frac + (1 - floor_frac)
                  * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup, warm, cos)


def train(cfg: M.ModelConfig, family: int, steps: int, batch: int,
          seq: int, seed: int, peak_lr: float, log_every: int = 50):
    data = make_dataset(family, n_tokens=steps * batch * seq + seq + 1,
                        seq=seq)
    params = M.init_params(cfg, seed)
    opt = adamw_init(params)

    def step_fn(carry, idx):
        params, opt = carry
        rows = jax.lax.dynamic_slice_in_dim(all_rows, idx * batch, batch)
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, rows))(params)
        # global-norm gradient clipping (deeper configs diverge without it)
        gn = jnp.sqrt(sum(jnp.sum(g * g) for g in grads.values()))
        scale = jnp.minimum(1.0, 1.0 / jnp.maximum(gn, 1e-8))
        grads = {k: g * scale for k, g in grads.items()}
        lr = cosine_lr(idx, steps, peak_lr)
        params, opt = adamw_update(params, grads, opt, lr)
        return (params, opt), loss

    all_rows = jnp.asarray(data[: steps * batch])
    scan_chunk = log_every
    losses = []
    t0 = time.time()
    jstep = jax.jit(lambda c, xs: jax.lax.scan(step_fn, c, xs))
    carry = (params, opt)
    for start in range(0, steps, scan_chunk):
        idxs = jnp.arange(start, min(start + scan_chunk, steps))
        carry, ls = jstep(carry, idxs)
        ls = np.asarray(ls)
        losses.extend(ls.tolist())
        print(f"[{cfg.name}] step {start + len(ls):4d}/{steps} "
              f"loss {ls[-1]:.4f}  ({time.time() - t0:.1f}s)", flush=True)
    return carry[0], losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", required=True)
    ap.add_argument("--family", type=int, default=1)
    ap.add_argument("--steps", type=int, default=350)
    ap.add_argument("--batch", type=int, default=12)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=2.5e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()

    table = M.SIZES if args.family == 1 else M.V2_SIZES
    cfg = table[args.size]
    os.makedirs(args.out, exist_ok=True)

    params, losses = train(cfg, args.family, args.steps, args.batch,
                           args.seq, args.seed, args.lr)
    named = [(n, np.asarray(params[n])) for n in M.param_names(cfg)]
    wpath = os.path.join(args.out, f"weights_{cfg.name}.bin")
    save_weights(wpath, named)
    lpath = os.path.join(args.out, f"trainlog_{cfg.name}.json")
    with open(lpath, "w") as f:
        json.dump({"config": cfg.name, "n_params": cfg.n_params(),
                   "steps": args.steps, "batch": args.batch,
                   "seq": args.seq, "loss": losses}, f)
    print(f"saved {wpath} ({cfg.n_params()} params), "
          f"final loss {losses[-1]:.4f}")

    # world golden dump (once per family)
    world = corpus.build_world(FAMILY_WORLD_SEED[args.family])
    corpus.dump_world(world, os.path.join(
        args.out, f"world_family{args.family}.json"))


if __name__ == "__main__":
    main()
