"""Build the complete artifact bundle: train -> calibrate -> AOT.

Driven by `make artifacts`. Each stage is skipped when its outputs are
newer than its inputs (cheap mtime checks), so repeated `make artifacts`
is a no-op.

Usage: python -m compile.build_all --out ../artifacts
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys

FAMILY_SIZES = {1: ["s", "m", "l", "xl"], 2: ["s", "m", "l"]}
TRAIN_STEPS = {"s": 800, "m": 800, "l": 700, "xl": 700}


def run(mod: str, *args: str) -> None:
    cmd = [sys.executable, "-m", mod, *args]
    print("::", " ".join(cmd), flush=True)
    subprocess.run(cmd, check=True, cwd=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    here = os.path.dirname(os.path.abspath(__file__))
    out = os.path.normpath(os.path.join(here, "..", args.out)) \
        if not os.path.isabs(args.out) else args.out
    os.makedirs(out, exist_ok=True)

    src_mtime = max(os.path.getmtime(os.path.join(here, f))
                    for f in os.listdir(here) if f.endswith(".py"))

    from .model import SIZES, V2_SIZES  # noqa: delayed import (jax init)
    for family, sizes in FAMILY_SIZES.items():
        table = SIZES if family == 1 else V2_SIZES
        for size in sizes:
            name = table[size].name
            wpath = os.path.join(out, f"weights_{name}.bin")
            if (args.force or not os.path.exists(wpath)
                    or os.path.getmtime(wpath) < src_mtime):
                run("compile.train", "--size", size, "--family", str(family),
                    "--steps", str(TRAIN_STEPS[size]), "--out", args.out)
            else:
                print(f":: weights_{name}.bin up to date", flush=True)

    manifest = os.path.join(out, "manifest.json")
    stale = (args.force or not os.path.exists(manifest)
             or os.path.getmtime(manifest) < src_mtime)
    run("compile.aot", "--out", args.out,
        *([] if stale else ["--skip-existing"]))

    calib = os.path.join(out, "calibration.json")
    if (args.force or not os.path.exists(calib)
            or os.path.getmtime(calib) < os.path.getmtime(manifest)):
        run("compile.calibrate", "--out", args.out)
    else:
        print(":: calibration.json up to date", flush=True)
    print(":: artifacts complete", flush=True)


if __name__ == "__main__":
    main()
