"""tinyllama — a LLaMA-architecture decoder in JAX (Layer 2).

Faithful LLaMA structure (the paper evaluates LLaMA-1/2): RMSNorm ->
attention with rotary position embeddings -> RMSNorm -> SwiGLU MLP, tied
input/output embedding. The attention softmax is pluggable (QuantSpec):

  kind = "none"          exact softmax                  (Table 2 NONE rows)
  kind = "static"        EXAQ kernel, calibrated per-layer clip C passed at
                         runtime as a [n_layers] vector — the same lowered
                         executable serves both the EXAQ and NAIVE rows of
                         Table 2 (they differ only in how Rust computes C
                         from calibration stats)
  kind = "dynamic_exaq"  per-row sigma -> C = slope*sigma + intercept
  kind = "dynamic_naive" per-row C = min/2                (ablation)

Entry points lowered by aot.py (all fixed-shape, batch/seq static):

  prefill(weights.., tokens[B,S], c_vec[L])        -> logits[B,S,V], kv
  decode (weights.., token[B], pos[B], kv, c_vec)  -> logits[B,V], kv'
  prefill_stats(weights.., tokens[B,S], lengths[B])-> logits, stats[L,4]

Stats rows are (sum, sum_sq, count, min) of the max-shifted softmax inputs
over valid causal lanes — the sufficient statistics the Rust calibration
driver (rust/src/calib) folds into per-layer sigma/min for Fig. 6 and the
EXAQ/NAIVE clip thresholds.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref
from .kernels.exaq_softmax import exaq_softmax_static, quant_softmax_dynamic
from .kernels.flash_attention import fused_attention
from . import corpus

_NEG = jnp.finfo(jnp.float32).min


@dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab_size: int = corpus.VOCAB_SIZE
    max_seq: int = 128
    rope_base: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def n_params(self) -> int:
        d, f, l = self.d_model, self.d_ff, self.n_layers
        per_layer = 4 * d * d + 3 * d * f + 2 * d
        return self.vocab_size * d + l * per_layer + d


#: The family-1 scale ladder mirrors the paper's 7B->65B axis (Table 2);
#: family-2 ("v2", Table 5) has a wider FFN and a different world seed.
SIZES = {
    "s":  ModelConfig("s",  n_layers=2, d_model=96,  n_heads=4, d_ff=256),
    "m":  ModelConfig("m",  n_layers=4, d_model=128, n_heads=4, d_ff=352),
    "l":  ModelConfig("l",  n_layers=5, d_model=192, n_heads=6, d_ff=512),
    "xl": ModelConfig("xl", n_layers=6, d_model=256, n_heads=8, d_ff=704),
}
V2_SIZES = {
    "s":  ModelConfig("v2-s", n_layers=2, d_model=96,  n_heads=4, d_ff=384),
    "m":  ModelConfig("v2-m", n_layers=4, d_model=128, n_heads=4, d_ff=512),
    "l":  ModelConfig("v2-l", n_layers=5, d_model=192, n_heads=6, d_ff=768),
}


@dataclass(frozen=True)
class QuantSpec:
    kind: str = "none"   # none|static|dynamic_exaq|dynamic_naive
    bits: int = 2

    def tag(self) -> str:
        if self.kind == "none":
            return "none"
        short = {"static": "q", "dynamic_exaq": "dynexaq",
                 "dynamic_naive": "dynnaive"}[self.kind]
        return f"{short}{self.bits}"


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------
def param_names(cfg: ModelConfig) -> list[str]:
    """Canonical flat ordering — the AOT manifest and the Rust weight
    loader both follow this exact order."""
    names = ["tok_emb"]
    for i in range(cfg.n_layers):
        names += [f"l{i}.rms1", f"l{i}.wq", f"l{i}.wk", f"l{i}.wv",
                  f"l{i}.wo", f"l{i}.rms2", f"l{i}.w1", f"l{i}.w2",
                  f"l{i}.w3"]
    names.append("norm_f")
    return names


def param_shape(cfg: ModelConfig, name: str) -> tuple[int, ...]:
    d, f = cfg.d_model, cfg.d_ff
    if name == "tok_emb":
        return (cfg.vocab_size, d)
    if name == "norm_f" or name.endswith((".rms1", ".rms2")):
        return (d,)
    if name.endswith((".wq", ".wk", ".wv", ".wo")):
        return (d, d)
    if name.endswith(".w1") or name.endswith(".w3"):
        return (d, f)
    if name.endswith(".w2"):
        return (f, d)
    raise KeyError(name)


def init_params(cfg: ModelConfig, seed: int = 0) -> dict[str, jnp.ndarray]:
    key = jax.random.PRNGKey(seed)
    params = {}
    for name in param_names(cfg):
        shape = param_shape(cfg, name)
        if len(shape) == 1:
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            key, sub = jax.random.split(key)
            fan_in = shape[0]
            params[name] = (jax.random.normal(sub, shape, jnp.float32)
                            * (1.0 / np.sqrt(fan_in)))
    return params


def params_to_flat(cfg: ModelConfig, params: dict) -> list[jnp.ndarray]:
    return [params[n] for n in param_names(cfg)]


def flat_to_params(cfg: ModelConfig, flat) -> dict:
    return dict(zip(param_names(cfg), flat))


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------
def rmsnorm(x, w, eps):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * w * jax.lax.rsqrt(ms + eps)


def rope_tables(cfg: ModelConfig):
    hd = cfg.head_dim
    inv = 1.0 / (cfg.rope_base ** (np.arange(0, hd, 2) / hd))
    t = np.arange(cfg.max_seq)
    ang = np.einsum("s,k->sk", t, inv)           # [S, hd/2]
    return jnp.asarray(np.cos(ang), jnp.float32), \
        jnp.asarray(np.sin(ang), jnp.float32)


def apply_rope(x, cos, sin):
    """x: [..., T, hd]; cos/sin: [..., T, hd/2] (already gathered)."""
    x1, x2 = x[..., 0::2], x[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x1 * sin + x2 * cos
    out = jnp.stack([r1, r2], axis=-1)
    return out.reshape(x.shape)


def _split_heads(x, H):
    B, T, D = x.shape
    return x.reshape(B, T, H, D // H).transpose(0, 2, 1, 3)  # [B,H,T,hd]


def _merge_heads(x):
    B, H, T, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B, T, H * hd)


def _softmax_rows(scores, vlen_rows, quant: QuantSpec, c_layer):
    """scores: [B,H,Q,S]; vlen_rows: [B,H,Q] int32. Dispatch by QuantSpec."""
    B, H, Q, S = scores.shape
    flat = scores.reshape(B * H * Q, S)
    vflat = vlen_rows.reshape(B * H * Q)
    if quant.kind == "none":
        p = ref.exact_softmax(flat, vflat)
    elif quant.kind == "static":
        p = exaq_softmax_static(flat, vflat, c_layer, bits=quant.bits)
    elif quant.kind == "dynamic_exaq":
        p = quant_softmax_dynamic(flat, vflat, bits=quant.bits, mode="exaq")
    elif quant.kind == "dynamic_naive":
        p = quant_softmax_dynamic(flat, vflat, bits=quant.bits, mode="naive")
    else:
        raise ValueError(quant.kind)
    return p.reshape(B, H, Q, S)


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------
def _attn_prefill(cfg, params, i, h, cos, sin, quant, c_layer, fused):
    """h: [B,S,D] -> (attn_out [B,S,D], k [B,H,S,hd], v [B,H,S,hd])."""
    B, S, D = h.shape
    H = cfg.n_heads
    q = _split_heads(h @ params[f"l{i}.wq"], H)
    k = _split_heads(h @ params[f"l{i}.wk"], H)
    v = _split_heads(h @ params[f"l{i}.wv"], H)
    q = apply_rope(q, cos[None, None, :S], sin[None, None, :S])
    k = apply_rope(k, cos[None, None, :S], sin[None, None, :S])

    if fused and quant.kind in ("none", "static"):
        bits = None if quant.kind == "none" else quant.bits
        o = fused_attention(q, k, v, c_layer, bits=bits,
                            block_q=min(16, S), q_offset=0)
    else:
        scale = 1.0 / np.sqrt(cfg.head_dim)
        scores = jnp.einsum("bhqd,bhsd->bhqs", q, k) * scale
        vlen = jnp.broadcast_to(
            ref.causal_valid_len(S, S), (B, H, S)).astype(jnp.int32)
        p = _softmax_rows(scores, vlen, quant, c_layer)
        o = jnp.einsum("bhqs,bhsd->bhqd", p, v)
    return _merge_heads(o) @ params[f"l{i}.wo"], k, v


def _mlp(params, i, h):
    gate = jax.nn.silu(h @ params[f"l{i}.w1"])
    up = h @ params[f"l{i}.w3"]
    return (gate * up) @ params[f"l{i}.w2"]


def prefill(cfg: ModelConfig, params: dict, tokens, c_vec=None,
            quant: QuantSpec = QuantSpec(), fused: bool = True):
    """tokens: [B,S] int32 -> (logits [B,S,V], kc, vc [L,B,H,S,hd])."""
    B, S = tokens.shape
    cos, sin = rope_tables(cfg)
    h = params["tok_emb"][tokens]
    kcs, vcs = [], []
    for i in range(cfg.n_layers):
        cl = None if c_vec is None else c_vec[i]
        a, k, v = _attn_prefill(cfg, params, i,
                                rmsnorm(h, params[f"l{i}.rms1"],
                                        cfg.norm_eps),
                                cos, sin, quant, cl, fused)
        h = h + a
        h = h + _mlp(params, i, rmsnorm(h, params[f"l{i}.rms2"],
                                        cfg.norm_eps))
        kcs.append(k)
        vcs.append(v)
    h = rmsnorm(h, params["norm_f"], cfg.norm_eps)
    logits = h @ params["tok_emb"].T
    return logits, jnp.stack(kcs), jnp.stack(vcs)


def decode(cfg: ModelConfig, params: dict, token, pos, kc, vc,
           c_vec=None, quant: QuantSpec = QuantSpec()):
    """Single-token step with per-row positions (continuous batching).

    token: [B] int32; pos: [B] int32 (0-based write position);
    kc/vc: [L,B,H,Smax,hd]. Returns (logits [B,V], kc', vc').
    """
    B = token.shape[0]
    H, Smax, hd = cfg.n_heads, kc.shape[3], cfg.head_dim
    cos, sin = rope_tables(cfg)
    cos_p, sin_p = cos[pos], sin[pos]            # [B, hd/2]
    h = params["tok_emb"][token][:, None, :]     # [B,1,D]
    kcs, vcs = [], []
    for i in range(cfg.n_layers):
        x = rmsnorm(h, params[f"l{i}.rms1"], cfg.norm_eps)
        q = _split_heads(x @ params[f"l{i}.wq"], H)   # [B,H,1,hd]
        k = _split_heads(x @ params[f"l{i}.wk"], H)
        v = _split_heads(x @ params[f"l{i}.wv"], H)
        q = apply_rope(q, cos_p[:, None, None], sin_p[:, None, None])
        k = apply_rope(k, cos_p[:, None, None], sin_p[:, None, None])

        # scatter k,v into the cache at per-row positions
        def put(cache, val, p):                  # [H,Smax,hd],[H,1,hd]
            return jax.lax.dynamic_update_slice(cache, val, (0, p, 0))
        kc_i = jax.vmap(put)(kc[i], k, pos)
        vc_i = jax.vmap(put)(vc[i], v, pos)

        scale = 1.0 / np.sqrt(hd)
        scores = jnp.einsum("bhqd,bhsd->bhqs", q, kc_i) * scale
        vlen = jnp.broadcast_to((pos + 1)[:, None, None],
                                (B, H, 1)).astype(jnp.int32)
        cl = None if c_vec is None else c_vec[i]
        p = _softmax_rows(scores, vlen, quant, cl)
        o = jnp.einsum("bhqs,bhsd->bhqd", p, vc_i)
        h = h + _merge_heads(o) @ params[f"l{i}.wo"]
        h = h + _mlp(params, i, rmsnorm(h, params[f"l{i}.rms2"],
                                        cfg.norm_eps))
        kcs.append(kc_i)
        vcs.append(vc_i)
    h = rmsnorm(h, params["norm_f"], cfg.norm_eps)
    logits = (h @ params["tok_emb"].T)[:, 0]
    return logits, jnp.stack(kcs), jnp.stack(vcs)


def prefill_stats(cfg: ModelConfig, params: dict, tokens, lengths):
    """Exact-softmax prefill that also emits per-layer calibration stats.

    Returns (logits [B,S,V], stats [L,4]) with stats rows
    (count, mean, M2, min) of max-shifted softmax inputs over lanes that
    are causally valid AND inside the per-sequence length. mean/M2 are
    combined across rows with the parallel-Welford rule (numerically safe
    in f32 even when |mean| is large); Rust merges batches the same way
    (rust/src/calib/welford.rs)."""
    B, S = tokens.shape
    H = cfg.n_heads
    cos, sin = rope_tables(cfg)
    h = params["tok_emb"][tokens]
    stats = []
    for i in range(cfg.n_layers):
        x = rmsnorm(h, params[f"l{i}.rms1"], cfg.norm_eps)
        q = _split_heads(x @ params[f"l{i}.wq"], H)
        k = _split_heads(x @ params[f"l{i}.wk"], H)
        v = _split_heads(x @ params[f"l{i}.wv"], H)
        q = apply_rope(q, cos[None, None, :S], sin[None, None, :S])
        k = apply_rope(k, cos[None, None, :S], sin[None, None, :S])
        scale = 1.0 / np.sqrt(cfg.head_dim)
        scores = jnp.einsum("bhqd,bhsd->bhqs", q, k) * scale

        rows = jnp.arange(S)[:, None]
        cols = jnp.arange(S)[None, :]
        causal = cols <= rows                              # [S,S]
        inlen = (rows < lengths[:, None, None, None]) & \
                (cols < lengths[:, None, None, None])      # [B,1,S,S]
        valid = jnp.broadcast_to(causal[None, None] & inlen, scores.shape)

        m = jnp.max(jnp.where(valid, scores, _NEG), axis=-1, keepdims=True)
        xs = jnp.where(valid, scores - m, 0.0)
        # Per-row moments (small, well-conditioned sums), then a
        # parallel-Welford combine across rows weighted by lane count.
        n_row = jnp.maximum(jnp.sum(valid, axis=-1), 1).astype(jnp.float32)
        mean_row = jnp.sum(xs, axis=-1) / n_row
        var_row = jnp.maximum(
            jnp.sum(jnp.where(valid, (xs - mean_row[..., None]) ** 2, 0.0),
                    axis=-1) / n_row, 0.0)
        w = (jnp.sum(valid, axis=-1) > 0).astype(jnp.float32) * n_row
        cnt = jnp.sum(w)
        mean = jnp.sum(w * mean_row) / cnt
        m2 = jnp.sum(w * (var_row + (mean_row - mean) ** 2))
        stats.append(jnp.stack([
            cnt, mean, m2, jnp.min(jnp.where(valid, xs, 0.0)),
        ]))

        e = jnp.where(valid, jnp.exp(jnp.where(valid, scores - m, 0.0)), 0.0)
        denom = jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-30)
        o = jnp.einsum("bhqs,bhsd->bhqd", e / denom, v)
        h = h + _merge_heads(o) @ params[f"l{i}.wo"]
        h = h + _mlp(params, i, rmsnorm(h, params[f"l{i}.rms2"],
                                        cfg.norm_eps))
    h = rmsnorm(h, params["norm_f"], cfg.norm_eps)
    return h @ params["tok_emb"].T, jnp.stack(stats)
