"""Flat binary weight format shared with rust/src/runtime/weights.rs.

Layout (little-endian):
  magic   4 bytes  b"TLW1"
  u32     n_tensors
  per tensor:
    u32       name_len, then name bytes (utf-8)
    u32       ndim, then ndim * u32 dims
    f32 data  prod(dims) * 4 bytes

Tensor order is `model.param_names(cfg)` — the same order the AOT manifest
lists executable inputs, so the Rust loader can feed buffers positionally.
"""
from __future__ import annotations

import struct

import numpy as np

MAGIC = b"TLW1"


def save_weights(path: str, named: list[tuple[str, np.ndarray]]) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(named)))
        for name, arr in named:
            arr = np.ascontiguousarray(arr, dtype=np.float32)
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


def load_weights(path: str) -> list[tuple[str, np.ndarray]]:
    out = []
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, "bad magic"
        (n,) = struct.unpack("<I", f.read(4))
        for _ in range(n):
            (ln,) = struct.unpack("<I", f.read(4))
            name = f.read(ln).decode()
            (nd,) = struct.unpack("<I", f.read(4))
            dims = struct.unpack(f"<{nd}I", f.read(4 * nd))
            cnt = int(np.prod(dims)) if nd else 1
            data = np.frombuffer(f.read(4 * cnt), dtype="<f4").reshape(dims)
            out.append((name, data))
    return out
