"""AOT lowering: JAX entry points -> HLO *text* artifacts + manifest.

The interchange format is HLO text, NOT a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids that the image's
xla_extension 0.5.1 (behind the published `xla` 0.1.6 crate) rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Every artifact is a fixed-shape executable. The quantized variants take the
per-layer clip thresholds `c_vec[L]` as a *runtime input*, so a single
lowering serves both the EXAQ and NAIVE rows of Table 2 — the Rust
coordinator decides the thresholds from calibration statistics
(rust/src/exaq). Entry points and signatures are recorded in
artifacts/manifest.json, which rust/src/runtime/manifest.rs parses.

Usage: python -m compile.aot --out ../artifacts [--sizes s,m,l,xl]
                             [--families 1,2] [--skip-existing]
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import corpus, model as M
from .weights_io import load_weights

SEQ = 64
PREFILL_BATCHES = (1, 8)
DECODE_BATCHES = (1, 8)
STATS_BATCH = 4  # paper §5.1.1: calibration runs use batch size 4


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # print_large_constants=True: the default printer elides dense
    # constants as `constant({...})`, which xla_extension 0.5.1's text
    # parser silently materialises as ZEROS (no error!) — the RoPE tables
    # would vanish. See EXPERIMENTS.md §Pitfalls.
    return comp.as_hlo_text(True)


def _sig(args) -> list[dict]:
    out = []
    for name, a in args:
        out.append({"name": name, "shape": list(a.shape),
                    "dtype": str(a.dtype)})
    return out


def lower_entry(cfg: M.ModelConfig, entry: str, quant: M.QuantSpec,
                batch: int):
    """Build (fn, example_args, input_names) for one artifact."""
    L, H, hd = cfg.n_layers, cfg.n_heads, cfg.head_dim
    wspecs = [(n, jax.ShapeDtypeStruct(M.param_shape(cfg, n), jnp.float32))
              for n in M.param_names(cfg)]
    nw = len(wspecs)
    needs_c = quant.kind == "static"

    if entry == "prefill":
        extra = [("tokens", jax.ShapeDtypeStruct((batch, SEQ), jnp.int32))]
        if needs_c:
            extra.append(("c_vec", jax.ShapeDtypeStruct((L,), jnp.float32)))

        def fn(*args):
            params = M.flat_to_params(cfg, args[:nw])
            tokens = args[nw]
            c_vec = args[nw + 1] if needs_c else None
            return M.prefill(cfg, params, tokens, c_vec, quant, fused=True)
    elif entry == "decode":
        kvshape = (L, batch, H, SEQ, hd)
        extra = [
            ("token", jax.ShapeDtypeStruct((batch,), jnp.int32)),
            ("pos", jax.ShapeDtypeStruct((batch,), jnp.int32)),
            ("kc", jax.ShapeDtypeStruct(kvshape, jnp.float32)),
            ("vc", jax.ShapeDtypeStruct(kvshape, jnp.float32)),
        ]
        if needs_c:
            extra.append(("c_vec", jax.ShapeDtypeStruct((L,), jnp.float32)))

        def fn(*args):
            params = M.flat_to_params(cfg, args[:nw])
            token, pos, kc, vc = args[nw:nw + 4]
            c_vec = args[nw + 4] if needs_c else None
            return M.decode(cfg, params, token, pos, kc, vc, c_vec, quant)
    elif entry == "prefill_stats":
        extra = [
            ("tokens", jax.ShapeDtypeStruct((batch, SEQ), jnp.int32)),
            ("lengths", jax.ShapeDtypeStruct((batch,), jnp.int32)),
        ]

        def fn(*args):
            params = M.flat_to_params(cfg, args[:nw])
            return M.prefill_stats(cfg, params, args[nw], args[nw + 1])
    else:
        raise ValueError(entry)

    specs = wspecs + extra
    return fn, [s for _, s in specs], _sig(specs)


def artifact_plan(cfg: M.ModelConfig, full: bool) -> list[dict]:
    plan = []
    for b in PREFILL_BATCHES:
        for q in (M.QuantSpec("none"), M.QuantSpec("static", 2),
                  M.QuantSpec("static", 3)):
            plan.append(dict(entry="prefill", quant=q, batch=b))
    for b in DECODE_BATCHES:
        for q in (M.QuantSpec("none"), M.QuantSpec("static", 2),
                  M.QuantSpec("static", 3)):
            plan.append(dict(entry="decode", quant=q, batch=b))
    plan.append(dict(entry="prefill_stats", quant=M.QuantSpec("none"),
                     batch=STATS_BATCH))
    if full:  # dynamic-statistics ablation (DESIGN.md experiment index)
        for kind in ("dynamic_exaq", "dynamic_naive"):
            plan.append(dict(entry="prefill", quant=M.QuantSpec(kind, 2),
                             batch=1))
    return plan


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--sizes", default="s,m,l,xl")
    ap.add_argument("--families", default="1,2")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {
        "format": 1,
        "seq": SEQ,
        "vocab": corpus.VOCAB,
        "specials": {"pad": corpus.PAD, "bos": corpus.BOS,
                     "eos": corpus.EOS, "sep": corpus.SEP},
        "table1": {str(k): list(v) for k, v in
                   __import__("compile.kernels.ref", fromlist=["ref"])
                   .EXAQ_TABLE1.items()},
        "models": {},
    }

    for family in [int(f) for f in args.families.split(",")]:
        table = M.SIZES if family == 1 else M.V2_SIZES
        sizes = [s for s in args.sizes.split(",") if s in table]
        for size in sizes:
            cfg = table[size]
            wpath = os.path.join(args.out, f"weights_{cfg.name}.bin")
            if not os.path.exists(wpath):
                print(f"!! missing {wpath}; run compile.train first — skip")
                continue
            entry_list = []
            # ablation artifacts only for family-1 "m"
            full = (family == 1 and size == "m")
            for item in artifact_plan(cfg, full):
                q: M.QuantSpec = item["quant"]
                key = f"{item['entry']}_{cfg.name}_{q.tag()}_b{item['batch']}"
                path = os.path.join(args.out, key + ".hlo.txt")
                fn, specs, sig = lower_entry(cfg, item["entry"], q,
                                             item["batch"])
                if not (args.skip_existing and os.path.exists(path)):
                    t0 = time.time()
                    lowered = jax.jit(fn).lower(*specs)
                    text = to_hlo_text(lowered)
                    with open(path, "w") as f:
                        f.write(text)
                    print(f"  {key}: {len(text) / 1e6:.2f} MB "
                          f"({time.time() - t0:.1f}s)", flush=True)
                entry_list.append({
                    "key": key, "file": os.path.basename(path),
                    "entry": item["entry"], "quant": q.kind,
                    "bits": q.bits if q.kind != "none" else 0,
                    "batch": item["batch"], "seq": SEQ, "inputs": sig,
                })
            manifest["models"][cfg.name] = {
                "family": family,
                "config": {
                    "name": cfg.name, "n_layers": cfg.n_layers,
                    "d_model": cfg.d_model, "n_heads": cfg.n_heads,
                    "d_ff": cfg.d_ff, "vocab_size": cfg.vocab_size,
                    "max_seq": SEQ, "head_dim": cfg.head_dim,
                    "n_params": cfg.n_params(),
                },
                "weights": os.path.basename(wpath),
                "param_names": M.param_names(cfg),
                "artifacts": entry_list,
            }

    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {mpath} ({len(manifest['models'])} models)")


if __name__ == "__main__":
    main()
