"""Model-level tests: shapes, fused/unfused agreement, decode==prefill,
stats correctness."""
import numpy as np
import jax
import jax.numpy as jnp

from compile import corpus, model as M

CFG = M.ModelConfig("test", n_layers=2, d_model=64, n_heads=4, d_ff=128,
                    max_seq=32)


def _params():
    return M.init_params(CFG, 0)


def _tokens(B, S, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.array(rng.integers(0, CFG.vocab_size, (B, S)), jnp.int32)


def test_prefill_shapes():
    p = _params()
    lg, kc, vc = M.prefill(CFG, p, _tokens(2, 16), fused=False)
    assert lg.shape == (2, 16, CFG.vocab_size)
    assert kc.shape == (CFG.n_layers, 2, CFG.n_heads, 16, CFG.head_dim)
    assert vc.shape == kc.shape
    assert np.isfinite(np.asarray(lg)).all()


def test_fused_equals_unfused():
    p = _params()
    t = _tokens(2, 16)
    for quant, cv in [(M.QuantSpec("none"), None),
                      (M.QuantSpec("static", 2),
                       jnp.full((CFG.n_layers,), -5.0)),
                      (M.QuantSpec("static", 3),
                       jnp.full((CFG.n_layers,), -6.0))]:
        a, _, _ = M.prefill(CFG, p, t, cv, quant, fused=True)
        b, _, _ = M.prefill(CFG, p, t, cv, quant, fused=False)
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-5)


def test_decode_matches_prefill():
    p = _params()
    S = 12
    t = _tokens(2, S + 1, seed=3)
    full, _, _ = M.prefill(CFG, p, t, fused=False)
    lg, kc, vc = M.prefill(CFG, p, t[:, :S], fused=False)
    pad = CFG.max_seq - S
    kc = jnp.pad(kc, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
    vc = jnp.pad(vc, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
    ld, kc2, vc2 = M.decode(CFG, p, t[:, S], jnp.array([S, S]), kc, vc)
    np.testing.assert_allclose(ld, full[:, S], rtol=2e-4, atol=2e-5)
    # cache row S was written
    assert not np.allclose(np.asarray(kc2)[:, :, :, S], 0)


def test_decode_per_row_positions():
    """Continuous batching: rows at different positions must each match
    their own prefill."""
    p = _params()
    t = _tokens(2, 13, seed=5)
    pos = [7, 11]
    kcs, vcs = [], []
    for b, pl in enumerate(pos):
        _, kc, vc = M.prefill(CFG, p, t[b:b + 1, :pl], fused=False)
        pad = CFG.max_seq - pl
        kcs.append(jnp.pad(kc, ((0, 0), (0, 0), (0, 0), (0, pad),
                                (0, 0))))
        vcs.append(jnp.pad(vc, ((0, 0), (0, 0), (0, 0), (0, pad),
                                (0, 0))))
    kc = jnp.concatenate(kcs, axis=1)
    vc = jnp.concatenate(vcs, axis=1)
    tok = jnp.array([t[0, pos[0]], t[1, pos[1]]], jnp.int32)
    ld, _, _ = M.decode(CFG, p, tok, jnp.array(pos, jnp.int32), kc, vc)
    for b, pl in enumerate(pos):
        want, _, _ = M.prefill(CFG, p, t[b:b + 1, :pl + 1], fused=False)
        np.testing.assert_allclose(ld[b], want[0, pl], rtol=2e-4,
                                   atol=2e-5)


def test_prefill_stats_match_bruteforce():
    p = _params()
    t = _tokens(2, 16, seed=7)
    lengths = jnp.array([16, 10], jnp.int32)
    _, stats = M.prefill_stats(CFG, p, t, lengths)
    s = np.asarray(stats)
    assert s.shape == (CFG.n_layers, 4)
    # counts: sum over batch of masked causal triangle * heads
    want_count = CFG.n_heads * (16 * 17 // 2 + 10 * 11 // 2)
    assert int(s[0, 0]) == want_count
    assert (s[:, 2] >= 0).all()      # M2
    assert (s[:, 3] <= 0).all()      # min of shifted values
    # sigma should be positive and finite
    sig = np.sqrt(s[:, 2] / s[:, 0])
    assert np.isfinite(sig).all() and (sig > 0).all()


def test_quant_spec_tags():
    assert M.QuantSpec("none").tag() == "none"
    assert M.QuantSpec("static", 2).tag() == "q2"
    assert M.QuantSpec("dynamic_exaq", 3).tag() == "dynexaq3"


def test_param_names_order_and_shapes():
    names = M.param_names(CFG)
    assert names[0] == "tok_emb"
    assert names[-1] == "norm_f"
    assert len(names) == 2 + 9 * CFG.n_layers
    for n in names:
        assert M.param_shape(CFG, n)
