"""Corpus / world spec tests (the cross-language contract)."""
import numpy as np

from compile import corpus


def test_splitmix_determinism():
    a = corpus.SplitMix64(42)
    b = corpus.SplitMix64(42)
    assert [a.next_u64() for _ in range(10)] == \
        [b.next_u64() for _ in range(10)]


def test_vocab_covers_all_templates():
    w = corpus.build_world(1)
    rng = corpus.SplitMix64(3)
    for _ in range(500):
        for word in corpus.sample_sentence(w, rng):
            assert word in corpus.TOK, f"{word} missing from vocab"


def test_world_ownership_injective():
    w = corpus.build_world(1)
    assert len(set(w.owned)) == len(w.owned)


def test_world_facts_consistent():
    w = corpus.build_world(1)
    for obj in range(corpus.N_OBJECTS):
        assert w.object_color(obj) in corpus.COLORS
        mat = w.object_material(obj)
        assert corpus.MATERIAL_PROP[mat] == w.object_property(obj)


def test_generate_tokens_deterministic_and_bounded():
    w = corpus.build_world(1)
    a = corpus.generate_tokens(w, 5, 500)
    b = corpus.generate_tokens(w, 5, 500)
    assert a == b
    assert len(a) == 500
    assert all(0 <= t < corpus.VOCAB_SIZE for t in a)
    assert a[0] == corpus.BOS


def test_comparison_sentences_are_true():
    w = corpus.build_world(1)
    rng = corpus.SplitMix64(9)
    seen = 0
    for _ in range(2000):
        s = corpus.sample_sentence(w, rng)
        if "harder" in s and s[0] == "the":
            i = s.index("harder")
            a = corpus.OBJECTS.index(s[1])
            b = corpus.OBJECTS.index(s[i + 3])
            assert w.object_hardness(a) > w.object_hardness(b)
            seen += 1
    assert seen > 10


def test_bool_qa_answers_are_correct():
    w = corpus.build_world(1)
    rng = corpus.SplitMix64(11)
    seen = 0
    for _ in range(2000):
        s = corpus.sample_sentence(w, rng)
        if s[:2] == ["question", ":"] and "is" == s[2]:
            obj = corpus.OBJECTS.index(s[4])
            color = s[5]
            ans = s[-2]
            want = "yes" if w.object_color(obj) == color else "no"
            assert ans == want
            seen += 1
    assert seen > 10
