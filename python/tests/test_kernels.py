"""Kernel vs reference-oracle correctness — the core L1 signal.

Hypothesis sweeps shapes, bit-widths, clip thresholds and valid-length
masks; every case must match the pure-jnp oracle in kernels/ref.py."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.exaq_softmax import (exaq_softmax_static,
                                          quant_softmax_dynamic)
from compile.kernels.flash_attention import fused_attention

SHAPES = st.tuples(st.integers(1, 17), st.sampled_from([8, 16, 32, 64]))


@settings(max_examples=25, deadline=None)
@given(shape=SHAPES, bits=st.sampled_from([2, 3, 4]),
       c=st.floats(-12.0, -0.5), seed=st.integers(0, 2**31 - 1))
def test_static_kernel_matches_ref(shape, bits, c, seed):
    R, S = shape
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 2.0, (R, S)).astype(np.float32)
    vlen = rng.integers(1, S + 1, R).astype(np.int32)
    got = exaq_softmax_static(jnp.array(x), jnp.array(vlen), c, bits=bits)
    want = ref.quant_softmax(jnp.array(x), jnp.array(vlen), bits, C=c)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=2e-6)


@settings(max_examples=15, deadline=None)
@given(shape=SHAPES, bits=st.sampled_from([2, 3]),
       mode=st.sampled_from(["exaq", "naive"]),
       seed=st.integers(0, 2**31 - 1))
def test_dynamic_kernel_matches_ref(shape, bits, mode, seed):
    R, S = shape
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1.5, (R, S)).astype(np.float32)
    vlen = rng.integers(1, S + 1, R).astype(np.int32)
    got = quant_softmax_dynamic(jnp.array(x), jnp.array(vlen), bits=bits,
                                mode=mode)
    want = ref.quant_softmax(jnp.array(x), jnp.array(vlen), bits, C=None,
                             mode=mode)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=2e-6)


@settings(max_examples=10, deadline=None)
@given(bits=st.sampled_from([None, 2, 3]), seed=st.integers(0, 2**31 - 1))
def test_fused_attention_matches_ref(bits, seed):
    rng = np.random.default_rng(seed)
    B, H, S, hd = 2, 2, 16, 8
    q = jnp.array(rng.normal(0, 1, (B, H, S, hd)), jnp.float32)
    k = jnp.array(rng.normal(0, 1, (B, H, S, hd)), jnp.float32)
    v = jnp.array(rng.normal(0, 1, (B, H, S, hd)), jnp.float32)
    C = None if bits is None else -5.0
    got = fused_attention(q, k, v, C, bits=bits, block_q=8)
    want = ref.attention_ref(q, k, v, bits=bits, C=C)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-6)


def test_probabilities_sum_to_one_over_valid_lanes():
    rng = np.random.default_rng(0)
    x = rng.normal(0, 3, (5, 32)).astype(np.float32)
    vlen = np.array([1, 7, 15, 31, 32], np.int32)
    p = np.asarray(exaq_softmax_static(jnp.array(x), jnp.array(vlen),
                                       -6.0, bits=2))
    np.testing.assert_allclose(p.sum(1), 1.0, atol=1e-5)
    for i, n in enumerate(vlen):
        assert (p[i, n:] == 0).all()


def test_lut_sum_equals_sum_of_lut_exp():
    for bits in (2, 3, 4):
        C = jnp.float32(-4.5)
        le = np.asarray(ref.lut_exp_table(C, bits))
        ls = np.asarray(ref.lut_sum_table(C, bits))
        g = ref.lut_group(bits)
        n = 1 << bits
        for key in range(len(ls)):
            want = sum(le[(key >> (bits * j)) % n] for j in range(g))
            assert abs(ls[key] - want) < 1e-5


def test_row_max_is_exactly_representable():
    # mid-tread spec: xs=0 must map to exp(0)=1 before normalisation
    for bits in (2, 3, 4):
        codes = ref.quant_codes(jnp.zeros(()), jnp.float32(-5.0), bits)
        val = ref.dequant(codes, jnp.float32(-5.0), bits)
        assert float(val) == 0.0


def test_degenerate_all_equal_row_is_uniform():
    x = jnp.zeros((1, 8), jnp.float32)
    p = np.asarray(exaq_softmax_static(x, jnp.array([8]), -3.0, bits=2))
    np.testing.assert_allclose(p, 1.0 / 8.0, atol=1e-6)


def test_bad_group_divisibility_raises():
    x = jnp.zeros((2, 10), jnp.float32)  # 10 % 4 != 0 at 2 bits
    with pytest.raises(ValueError):
        exaq_softmax_static(x, jnp.array([10, 10]), -3.0, bits=2)
