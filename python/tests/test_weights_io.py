"""TLW1 weight format roundtrip (mirrors rust/src/runtime/weights.rs)."""
import numpy as np

from compile.weights_io import load_weights, save_weights


def test_roundtrip(tmp_path):
    p = tmp_path / "w.bin"
    named = [
        ("tok_emb", np.arange(12, dtype=np.float32).reshape(3, 4)),
        ("norm_f", np.ones(4, dtype=np.float32)),
    ]
    save_weights(str(p), named)
    out = load_weights(str(p))
    assert [n for n, _ in out] == ["tok_emb", "norm_f"]
    np.testing.assert_array_equal(out[0][1], named[0][1])
    np.testing.assert_array_equal(out[1][1], named[1][1])


def test_float64_is_downcast(tmp_path):
    p = tmp_path / "w.bin"
    save_weights(str(p), [("x", np.array([1.5, 2.5], dtype=np.float64))])
    out = load_weights(str(p))
    assert out[0][1].dtype == np.float32
