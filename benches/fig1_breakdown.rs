//! Fig. 1 bench — runtime share by op type.
//!
//! Two complementary measurements:
//!   1. Cycle-model shares for the paper's LLaMA-2-7B shape (fitted to
//!      the paper's BF16 measurement, then predicted for FP8 / Algo.2).
//!   2. A *measured* share on our own stack: wall-clock of the lowered
//!      prefill with exact softmax vs with the EXAQ kernel — the delta is
//!      the softmax share our runtime actually exposes.

use std::path::Path;

use exaq_repro::cost::{GemmPrecision, MachineModel, TransformerShape};
use exaq_repro::report::{f as fnum, pct, Table};
use exaq_repro::runtime::{Engine, HostTensor, QuantMode};
use exaq_repro::util::clock::Stopwatch;
use exaq_repro::util::error::Result;

fn main() -> Result<()> {
    let m = MachineModel::default();
    let llama7b = TransformerShape {
        layers: 32, d_model: 4096, n_heads: 32, d_ff: 11008, seq: 2048,
        batch: 1, vocab: 32000,
    };
    let mut t = Table::new(
        "Fig. 1 — cycle-model runtime shares (LLaMA-2-7B shape)",
        &["scenario", "gemm", "softmax", "elementwise"]);
    for (name, prec, bits) in [
        ("BF16 + original softmax (paper: 24/39/37)",
         GemmPrecision::Bf16, None),
        ("FP8  + original softmax", GemmPrecision::Fp8, None),
        ("BF16 + EXAQ 2-bit", GemmPrecision::Bf16, Some(2)),
    ] {
        let s = m.breakdown(llama7b, prec, bits);
        t.row(&[name.to_string(), pct(s[0].share), pct(s[1].share),
                pct(s[2].share)]);
    }
    println!("{}", t.to_markdown());
    let _ = exaq_repro::report::write_csv("reports/fig1_breakdown.csv",
                                          &t);

    // measured on our bundle, if present
    let dir = Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        let mut engine = Engine::load(dir)?;
        let model = "m";
        let seq = engine.manifest.seq;
        let n_layers = engine.manifest.model(model)?.config.n_layers;
        let tokens = HostTensor::i32(vec![1; 8 * seq], &[8, seq]);
        let mut time_of = |quant, c: Option<&[f32]>| -> Result<f64> {
            engine.prefill(model, quant, &tokens, c)?; // warm/compile
            let t0 = Stopwatch::start();
            let reps = 5;
            for _ in 0..reps {
                engine.prefill(model, quant, &tokens, c)?;
            }
            Ok(t0.seconds() / reps as f64)
        };
        let cv = vec![-6.0f32; n_layers];
        let exact = time_of(QuantMode::None, None)?;
        let q2 = time_of(QuantMode::Static { bits: 2 }, Some(&cv))?;
        let mut t2 = Table::new(
            "Fig. 1 (measured) — our prefill wall-clock, batch 8",
            &["variant", "ms/prefill"]);
        t2.row(&["exact softmax".into(), fnum(exact * 1e3, 2)]);
        t2.row(&["EXAQ 2-bit softmax".into(), fnum(q2 * 1e3, 2)]);
        println!("{}", t2.to_markdown());
        println!("(CPU-interpret kernel timings are structural only — \
                  see DESIGN.md §7 L1)");
    } else {
        println!("artifacts/ missing — measured section skipped");
    }
    Ok(())
}
