//! Attention-plane bench — fused packed pipeline
//! (`AttentionPlane::attend`: scores stay in `PackedCodes` from QK^T
//! through the weighted-value pass) vs the two-step reference
//! (`softmax_rows` materializes the f32 probability plane, dense PV
//! re-reads it). The two paths are bit-identical by contract — the
//! bench asserts that before timing — so the columns isolate the cost
//! of the f32 round trip the fused layout deletes. Acceptance floor:
//! fused beats two-step wall time at M = 2, and the packed plane is
//! strictly smaller than the dense one at every M.
//!
//! The streaming columns time `StreamingAttention` on the same
//! inputs: `streaming_us` drives `attend_scores` (identical work to
//! the fused path, so the delta isolates the O(1)-score-memory
//! restructuring), `streaming_qkv_us` drives the full one-pass Q/K/V
//! front (QK^T fused into the tile loop — no score plane is ever
//! materialized by the caller either). Scores are derived from Q·K
//! via `simd::qk_strip` so all three front ends are bit-identical —
//! asserted before timing. `streaming_score_bytes` is the constant
//! peak score scratch (`footprint::streaming_strip_bytes`),
//! independent of `len` by construction.
//!
//! Hand-rolled harness (the image has no criterion): warmup + N timed
//! repetitions, best-of-5 reporting. `EXAQ_BENCH_REPS` overrides the
//! rep count (CI smoke runs with 1). Emits `BENCH_attention.json`
//! (`EXAQ_BENCH_COMMIT=1` also snapshots it to `BENCH_baseline/` for
//! the `repro compare` gate). Meta surfaces the thread-local plane /
//! engine cache counters so cache-policy regressions stay visible.

use exaq_repro::cost::{CycleTable, MachineModel};
use exaq_repro::exaq::batched;
use exaq_repro::exaq::footprint::{dense_plane_bytes,
                                  packed_plane_bytes,
                                  streaming_strip_bytes};
use exaq_repro::exaq::plane::{plane_cache_stats,
                              reset_plane_cache_stats,
                              with_cached_plane};
use exaq_repro::exaq::simd;
use exaq_repro::exaq::stream::StreamingAttention;
use exaq_repro::report::{f as fnum, jnum, jstr, BenchJson, Table};
use exaq_repro::util::clock::Stopwatch;
use exaq_repro::util::pool;
use exaq_repro::util::rng::SplitMix64;

fn bench<F: FnMut()>(mut f: F, reps: usize) -> f64 {
    for _ in 0..3 {
        f(); // warmup
    }
    let mut best = f64::INFINITY;
    for _ in 0..5 {
        let t0 = Stopwatch::start();
        for _ in 0..reps {
            f();
        }
        best = best.min(t0.seconds() / reps as f64);
    }
    best
}

fn env_reps(default: usize) -> usize {
    std::env::var("EXAQ_BENCH_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&r| r > 0)
        .unwrap_or(default)
}

fn main() {
    let mut rng = SplitMix64::new(7);
    let c = -6.0f32;
    let reps = env_reps(8);
    reset_plane_cache_stats();
    batched::reset_cache_stats();

    let mut t = Table::new(
        "Attention plane — fused packed PV vs two-step \
         softmax + dense PV vs streaming one-pass (wall-clock, Rust)",
        &["rows x len x d", "bits", "fused (us)", "two-step (us)",
          "streaming (us)", "qkv 1-pass (us)", "speedup",
          "packed (B)", "dense (B)", "strip (B)", "model speedup"]);
    let mut out = BenchJson::new("attention");
    out.meta("reps", jnum(reps as f64));
    out.meta("clip", jnum(c as f64));
    out.meta("simd", jstr(simd::default_level().name()));
    out.meta("threads", jnum(pool::default_threads() as f64));

    for (rows, len, d) in
        [(64usize, 1024usize, 64usize), (256, 256, 64), (32, 2048, 128)]
    {
        // scores come from a real QK^T so the streaming Q/K/V front
        // and the score-plane fronts see identical bit patterns
        let q: Vec<f32> = (0..rows * d)
            .map(|_| rng.normal() as f32)
            .collect();
        let k: Vec<f32> = (0..len * d)
            .map(|_| rng.normal() as f32)
            .collect();
        let scale = 1.0 / (d as f32).sqrt();
        let mut scores = vec![0.0f32; rows * len];
        for (r, row) in scores.chunks_exact_mut(len).enumerate() {
            simd::qk_strip(simd::default_level(),
                           &q[r * d..(r + 1) * d], &k, d, scale, row);
        }
        let values: Vec<f32> = (0..len * d)
            .map(|_| rng.normal() as f32)
            .collect();
        for bits in [2u32, 3, 4] {
            let mut fused_out = vec![0.0f32; rows * d];
            let mut two_out = vec![0.0f32; rows * d];
            let mut stream_out = vec![0.0f32; rows * d];
            let mut qkv_out = vec![0.0f32; rows * d];
            let mut stream = StreamingAttention::new(bits, c);
            // bit-exactness first: timing paths that disagree would
            // compare different arithmetic
            with_cached_plane(bits, c, |p| {
                p.attend(&scores, rows, len, &[], &values, d,
                         &mut fused_out);
                p.attend_two_step(&scores, rows, len, &[], &values, d,
                                  &mut two_out);
            });
            assert_eq!(fused_out, two_out,
                       "fused/two-step mismatch at bits={bits}");
            stream.attend_scores(&scores, rows, len, &[], &values, d,
                                 &mut stream_out);
            assert_eq!(fused_out, stream_out,
                       "fused/streaming mismatch at bits={bits}");
            stream.attend(&q, rows, len, &[], &k, &values, d, scale,
                          &mut qkv_out);
            assert_eq!(fused_out, qkv_out,
                       "fused/one-pass-QKV mismatch at bits={bits}");

            let fused = bench(
                || {
                    with_cached_plane(bits, c, |p| {
                        p.attend(&scores, rows, len, &[], &values, d,
                                 &mut fused_out);
                    });
                },
                reps,
            );
            let two_step = bench(
                || {
                    with_cached_plane(bits, c, |p| {
                        p.attend_two_step(&scores, rows, len, &[],
                                          &values, d, &mut two_out);
                    });
                },
                reps,
            );
            let streaming = bench(
                || {
                    stream.attend_scores(&scores, rows, len, &[],
                                         &values, d, &mut stream_out);
                },
                reps,
            );
            let qkv = bench(
                || {
                    stream.attend(&q, rows, len, &[], &k, &values, d,
                                  scale, &mut qkv_out);
                },
                reps,
            );

            let (group, plane_bytes, threads, level) =
                with_cached_plane(bits, c, |p| {
                    (p.group(), p.plane_bytes(), p.threads(),
                     p.simd_level())
                });
            let packed = packed_plane_bytes(rows, len, bits);
            assert_eq!(plane_bytes, packed,
                       "live plane footprint disagrees with the \
                        layout helper at bits={bits}");
            assert_eq!(stream.plane_bytes(), packed,
                       "streaming packed footprint drifted from the \
                        fused plane at bits={bits}");
            let dense = dense_plane_bytes(rows, len);
            assert!(packed < dense,
                    "packed plane must be smaller than dense");
            // the headline claim: peak f32 score storage on the
            // streaming path is one strip, independent of len
            let strip = streaming_strip_bytes();
            assert!(strip < dense,
                    "streaming strip must beat the dense plane");
            let cycles = CycleTable::default();
            let machine = MachineModel::default();
            let workers = pool::default_threads();
            let model_speedup = machine
                .attention_plane_cycles(rows, len, d, bits, workers,
                                        false)
                / machine
                    .attention_plane_cycles(rows, len, d, bits,
                                            workers, true)
                    .max(1e-12);
            t.row(&[
                format!("{rows}x{len}x{d}"),
                bits.to_string(),
                fnum(fused * 1e6, 1),
                fnum(two_step * 1e6, 1),
                fnum(streaming * 1e6, 1),
                fnum(qkv * 1e6, 1),
                format!("{:.2}x", two_step / fused.max(1e-12)),
                packed.to_string(),
                dense.to_string(),
                strip.to_string(),
                format!("{model_speedup:.2}x"),
            ]);
            out.result(&[
                ("rows", jnum(rows as f64)),
                ("len", jnum(len as f64)),
                ("d_head", jnum(d as f64)),
                ("bits", jnum(bits as f64)),
                ("group", jnum(group as f64)),
                ("fused_us", jnum(fused * 1e6)),
                ("two_step_us", jnum(two_step * 1e6)),
                ("streaming_us", jnum(streaming * 1e6)),
                ("streaming_qkv_us", jnum(qkv * 1e6)),
                // guarded: a coarse timer at EXAQ_BENCH_REPS=1 could
                // report 0, and inf would not serialise as valid JSON
                ("fused_speedup", jnum(two_step / fused.max(1e-12))),
                ("streaming_speedup",
                 jnum(two_step / streaming.max(1e-12))),
                ("streaming_vs_fused",
                 jnum(fused / streaming.max(1e-12))),
                ("plane_bytes", jnum(packed as f64)),
                ("dense_plane_bytes", jnum(dense as f64)),
                ("streaming_score_bytes", jnum(strip as f64)),
                ("fused_cycles", jnum(cycles.attention_plane_fused(
                    rows, len, d, bits, workers))),
                ("two_step_cycles",
                 jnum(cycles.attention_plane_two_step(
                     rows, len, d, bits, workers))),
                ("streaming_cycles",
                 jnum(cycles.attention_plane_streaming(
                     rows, len, d, bits, workers))),
                ("streaming_machine_cycles",
                 jnum(machine.attention_streaming_cycles(
                     rows, len, d, bits, workers))),
                ("simd", jstr(level.name())),
                ("threads", jnum(threads as f64)),
                ("kernel", jstr("attend")),
            ]);
        }
    }
    // cache counters go into meta after the sweep so the JSON records
    // the real hit/miss history of the run
    let (phits, pmisses) = plane_cache_stats();
    out.meta("plane_cache_hits", jnum(phits as f64));
    out.meta("plane_cache_misses", jnum(pmisses as f64));
    let (ehits, emisses) = batched::cache_stats();
    out.meta("engine_cache_hits", jnum(ehits as f64));
    out.meta("engine_cache_misses", jnum(emisses as f64));
    println!("{}", t.to_markdown());
    println!("fused keeps the score plane packed end to end; two-step \
              writes and re-reads the f32 probability plane; \
              streaming never materializes it — peak score scratch \
              is one {} B strip at every len.",
             streaming_strip_bytes());
    let _ = exaq_repro::report::write_csv(
        "reports/attention_plane.csv", &t);
    match out.write() {
        Ok(path) => println!("bench telemetry -> {path}"),
        Err(e) => eprintln!("failed to write bench json: {e}"),
    }
}
