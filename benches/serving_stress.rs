//! Serving stress bench — drives the real continuous-batching
//! scheduler through the deterministic SimBackend across the scenario
//! mixes, reporting simulated latency percentiles plus host-side
//! scheduler throughput (ticks of pure coordinator work per second),
//! and compares the decode softmax kernel modes (per-row scalar vs
//! batched bit-packed plane) at M ∈ {2, 3, 4}. A third section runs
//! the mixed-tenant workload through the router + N-replica fabric at
//! 1/2/4 replicas and emits per-replica occupancy/TTFT columns.
//!
//!     cargo bench --bench serving_stress
//!
//! No artifacts required; numbers are reproducible per seed (the two
//! kernel modes are bit-identical, so they serve byte-identical token
//! streams — only host time differs). `EXAQ_BENCH_REQUESTS` overrides
//! the per-scenario request count (CI smoke uses a small value).
//! Emits `BENCH_serving.json` for the perf trajectory.

use std::rc::Rc;

use exaq_repro::coordinator::{serve_trace, workload, Fabric,
                              FabricConfig, RouterConfig, Scenario,
                              ServeConfig, WorkloadSpec};
use exaq_repro::report::{f as fnum, jnum, jstr, BenchJson, Table};
use exaq_repro::runtime::{QuantMode, SimBackend, SimConfig};
use exaq_repro::util::clock::{Stopwatch, VirtualClock};
use exaq_repro::util::error::Result;

fn env_requests(default: usize) -> usize {
    std::env::var("EXAQ_BENCH_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&n| n > 0)
        .unwrap_or(default)
}

/// Run one scenario; returns (total tokens, sim seconds, host seconds,
/// p50 ttft, p99 ttft, p99 latency, occupancy).
fn run_scenario(
    scenario: Scenario, n: usize, sim_cfg: SimConfig,
) -> Result<(usize, f64, f64, f64, f64, f64, f64)> {
    let clock = Rc::new(VirtualClock::new());
    let spec = WorkloadSpec::new(scenario, n, 7, sim_cfg.vocab,
                                 sim_cfg.max_seq);
    let mut sim = SimBackend::new(sim_cfg, clock.clone());
    let cfg = ServeConfig {
        model: "sim".into(),
        quant: QuantMode::None,
        c_vec: None,
        decode_batch: 8,
    };
    let trace = workload::generate(&spec);
    let host0 = Stopwatch::start();
    let (resps, sim_secs, sched) =
        serve_trace(&mut sim, &cfg, trace, clock)?;
    let host = host0.seconds();
    assert_eq!(resps.len(), n, "lost requests");
    let toks: usize = resps.iter().map(|r| r.tokens.len()).sum();
    let m = sched.metrics();
    Ok((toks, sim_secs, host, m.ttft.quantile(0.5),
        m.ttft.quantile(0.99), m.total_latency.quantile(0.99),
        m.mean_occupancy()))
}

fn main() -> Result<()> {
    let n = env_requests(2000);
    let mut out = BenchJson::new("serving");
    out.meta("requests", jnum(n as f64));
    out.meta("decode_batch", jnum(8.0));
    out.meta("simd",
             jstr(exaq_repro::exaq::simd::default_level().name()));
    out.meta("threads",
             jnum(exaq_repro::util::pool::default_threads() as f64));

    // ---- scenario sweep (batched kernel, the serving default) ------
    let mut t = Table::new(
        &format!("Serving stress — {n} simulated requests per \
                  scenario, decode batch 8, batched softmax"),
        &["scenario", "sim s", "sim tok/s", "p50 ttft", "p99 ttft",
          "p99 latency", "occupancy", "host s", "host tok/s"]);
    for (name, scenario, eos_bias) in [
        ("steady", Scenario::Steady { rate: 400.0 }, 0.0),
        ("burst", Scenario::Burst { n_bursts: 8, gap: 0.2 }, 0.0),
        ("long-tail", Scenario::LongPromptTail { rate: 400.0 }, 0.0),
        ("mixed", Scenario::MixedLengths { rate: 400.0 }, 0.0),
        ("chat", Scenario::ChatEarlyEos { rate: 400.0 }, 0.2),
    ] {
        let sim_cfg = SimConfig { eos_bias, ..SimConfig::default() };
        let (toks, sim_secs, host, p50, p99, lat99, occ) =
            run_scenario(scenario, n, sim_cfg)?;
        t.row(&[
            name.to_string(),
            fnum(sim_secs, 3),
            fnum(toks as f64 / sim_secs.max(1e-12), 0),
            fnum(p50, 4),
            fnum(p99, 4),
            fnum(lat99, 4),
            fnum(occ, 2),
            fnum(host, 3),
            fnum(toks as f64 / host.max(1e-12), 0),
        ]);
        out.result(&[
            ("kind", jstr("scenario")),
            ("scenario", jstr(name)),
            ("tokens", jnum(toks as f64)),
            ("sim_s", jnum(sim_secs)),
            ("host_s", jnum(host)),
            ("p99_ttft", jnum(p99)),
            ("occupancy", jnum(occ)),
        ]);
    }
    println!("{}", t.to_markdown());

    // ---- decode softmax kernel: scalar vs batched, M ∈ {2,3,4} -----
    let n_kernel = n / 4 + 1;
    let mut k = Table::new(
        &format!("Decode softmax kernel — per-row scalar vs batched \
                  bit-packed plane ({n_kernel} steady requests)"),
        &["bits", "scalar host s", "batched host s", "speedup",
          "tokens (equal by construction)"]);
    for bits in [2u32, 3, 4] {
        let mut host = [0.0f64; 2];
        let mut toks = [0usize; 2];
        for (i, batched) in [(0usize, false), (1usize, true)] {
            let sim_cfg = SimConfig {
                shape_bits: bits,
                batched_softmax: batched,
                ..SimConfig::default()
            };
            let (tk, _sim, h, ..) = run_scenario(
                Scenario::Steady { rate: 400.0 }, n_kernel, sim_cfg)?;
            host[i] = h;
            toks[i] = tk;
        }
        assert_eq!(toks[0], toks[1],
                   "kernel modes must serve identical tokens");
        k.row(&[
            bits.to_string(),
            fnum(host[0], 3),
            fnum(host[1], 3),
            format!("{:.2}x", host[0] / host[1].max(1e-12)),
            toks[0].to_string(),
        ]);
        out.result(&[
            ("kind", jstr("kernel_mode")),
            ("bits", jnum(bits as f64)),
            ("scalar_host_s", jnum(host[0])),
            ("batched_host_s", jnum(host[1])),
            ("batched_speedup",
             jnum(host[0] / host[1].max(1e-12))),
            ("tokens", jnum(toks[0] as f64)),
        ]);
    }
    println!("{}", k.to_markdown());

    // ---- multi-replica fabric: router + N replicas, 4 tenants ------
    // mixed-tier workload through the fabric at 1/2/4 replicas; the
    // per-replica rows land in BENCH_serving.json so the baseline
    // compare pins fleet coverage (a vanished replica column fails
    // the gate)
    let n_fab = (n / 2).max(8);
    let mut fb = Table::new(
        &format!("Serving fabric — {n_fab} mixed requests, 4 \
                  tenants, decode batch 8"),
        &["replicas", "sim s", "sim tok/s", "p99 ttft", "occupancy",
          "preempts", "host s"]);
    for replicas in [1usize, 2, 4] {
        let sim_cfg = SimConfig::default();
        let spec = WorkloadSpec::new(
            Scenario::MixedLengths { rate: 400.0 }, n_fab, 7,
            sim_cfg.vocab, sim_cfg.max_seq)
            .with_tenants(4);
        let trace = workload::generate(&spec);
        let fab_cfg = FabricConfig {
            serve: ServeConfig {
                model: "sim".into(),
                quant: QuantMode::None,
                c_vec: None,
                decode_batch: 8,
            },
            router: RouterConfig::default(),
            collect_stream: false,
        };
        let mk_cfg = sim_cfg.clone();
        let mut fab =
            Fabric::new(replicas, fab_cfg, |_, clock| {
                Ok(SimBackend::new(mk_cfg.clone(), clock))
            })?;
        let host0 = Stopwatch::start();
        let (resps, sim_secs) = fab.run_trace(trace)?;
        let host = host0.seconds();
        assert_eq!(resps.len(), n_fab, "fabric lost requests");
        let toks: usize =
            resps.iter().map(|r| r.tokens.len()).sum();
        let fleet = fab.fleet_metrics();
        fb.row(&[
            replicas.to_string(),
            fnum(sim_secs, 3),
            fnum(toks as f64 / sim_secs.max(1e-12), 0),
            fnum(fleet.ttft.quantile(0.99), 4),
            fnum(fleet.mean_occupancy(), 2),
            fleet.preemptions.to_string(),
            fnum(host, 3),
        ]);
        out.result(&[
            ("kind", jstr("fabric")),
            ("scenario", jstr("mixed")),
            ("replicas", jnum(replicas as f64)),
            ("tokens", jnum(toks as f64)),
            ("sim_s", jnum(sim_secs)),
            ("host_s", jnum(host)),
            ("p99_ttft", jnum(fleet.ttft.quantile(0.99))),
            ("occupancy", jnum(fleet.mean_occupancy())),
        ]);
        for i in 0..fab.n_replicas() {
            let m = fab.replica(i).metrics();
            out.result(&[
                ("kind", jstr("replica")),
                ("scenario", jstr("mixed")),
                ("replicas", jnum(replicas as f64)),
                ("replica", jnum(i as f64)),
                ("requests_done",
                 jnum(m.requests_done as f64)),
                ("prefills", jnum(m.prefills as f64)),
                ("occupancy", jnum(m.mean_occupancy())),
                ("p99_ttft", jnum(m.ttft.quantile(0.99))),
            ]);
        }
    }
    println!("{}", fb.to_markdown());

    let _ = exaq_repro::report::write_csv(
        "reports/serving_stress.csv", &t);
    let _ = exaq_repro::report::write_csv(
        "reports/serving_fabric.csv", &fb);
    let _ = exaq_repro::report::write_csv(
        "reports/serving_kernel_modes.csv", &k);
    match out.write() {
        Ok(path) => println!("bench telemetry -> {path}"),
        Err(e) => eprintln!("failed to write bench json: {e}"),
    }
    Ok(())
}
