//! Serving stress bench — drives the real continuous-batching
//! scheduler through the deterministic SimBackend across the scenario
//! mixes, reporting simulated latency percentiles plus host-side
//! scheduler throughput (ticks of pure coordinator work per second).
//!
//!     cargo bench --bench serving_stress
//!
//! No artifacts required; numbers are reproducible per seed.

use std::rc::Rc;
use std::time::Instant;

use exaq_repro::coordinator::{serve_trace, workload, Scenario,
                              ServeConfig, WorkloadSpec};
use exaq_repro::report::{f as fnum, Table};
use exaq_repro::runtime::{QuantMode, SimBackend, SimConfig};
use exaq_repro::util::clock::VirtualClock;
use exaq_repro::util::error::Result;

fn main() -> Result<()> {
    let n = 2000usize;
    let mut t = Table::new(
        &format!("Serving stress — {n} simulated requests per \
                  scenario, decode batch 8"),
        &["scenario", "sim s", "sim tok/s", "p50 ttft", "p99 ttft",
          "p99 latency", "occupancy", "host s", "host tok/s"]);
    for (name, scenario, eos_bias) in [
        ("steady", Scenario::Steady { rate: 400.0 }, 0.0),
        ("burst", Scenario::Burst { n_bursts: 8, gap: 0.2 }, 0.0),
        ("long-tail", Scenario::LongPromptTail { rate: 400.0 }, 0.0),
        ("mixed", Scenario::MixedLengths { rate: 400.0 }, 0.0),
        ("chat", Scenario::ChatEarlyEos { rate: 400.0 }, 0.2),
    ] {
        let clock = Rc::new(VirtualClock::new());
        let sim_cfg = SimConfig { eos_bias, ..SimConfig::default() };
        let spec = WorkloadSpec::new(scenario, n, 7, sim_cfg.vocab,
                                     sim_cfg.max_seq);
        let mut sim = SimBackend::new(sim_cfg, clock.clone());
        let cfg = ServeConfig {
            model: "sim".into(),
            quant: QuantMode::None,
            c_vec: None,
            decode_batch: 8,
        };
        let trace = workload::generate(&spec);
        let host0 = Instant::now();
        let (resps, sim_secs, sched) =
            serve_trace(&mut sim, &cfg, trace, clock)?;
        let host = host0.elapsed().as_secs_f64();
        assert_eq!(resps.len(), n, "{name}: lost requests");
        let toks: usize = resps.iter().map(|r| r.tokens.len()).sum();
        let m = &sched.metrics;
        t.row(&[
            name.to_string(),
            fnum(sim_secs, 3),
            fnum(toks as f64 / sim_secs.max(1e-12), 0),
            fnum(m.ttft.quantile(0.5), 4),
            fnum(m.ttft.quantile(0.99), 4),
            fnum(m.total_latency.quantile(0.99), 4),
            fnum(m.mean_occupancy(), 2),
            fnum(host, 3),
            fnum(toks as f64 / host.max(1e-12), 0),
        ]);
    }
    println!("{}", t.to_markdown());
    let _ = exaq_repro::report::write_csv(
        "reports/serving_stress.csv", &t);
    Ok(())
}
