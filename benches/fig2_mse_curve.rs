//! Fig. 2 bench — the distortion decomposition: MSE_quant grows and
//! MSE_clip shrinks as C moves negative; the total has an interior
//! optimum. Emits the CSV series for plotting.

use exaq_repro::exaq::mse::MseModel;
use exaq_repro::exaq::solver::minimise_clip;
use exaq_repro::report::{f as fnum, Table};

fn main() {
    let sigma = 1.0;
    let bits = 2;
    let model = MseModel::max_shifted(sigma, bits);
    let mut t = Table::new(
        "Fig. 2 — MSE components vs clip threshold (sigma=1, M=2)",
        &["C", "MSE_quant", "MSE_clip", "MSE_total"]);
    for p in model.curve(-10.0, -0.3, 80) {
        t.row(&[fnum(p.c, 3), format!("{:.4e}", p.quant),
                format!("{:.4e}", p.clip), format!("{:.4e}", p.total)]);
    }
    println!("{}", t.to_markdown());
    let cstar = minimise_clip(&model);
    println!("optimal C* = {cstar:.3} \
              (paper Table 1 line at sigma=1: -3.51)");
    let _ =
        exaq_repro::report::write_csv("reports/fig2_mse_curve.csv", &t);
}
