//! Fig. 3 bench — optimal clipping value vs sigma: analytic model vs
//! Monte-Carlo simulation, for M = 2 and 3 (+ our M = 4 extension),
//! against the paper's Table 1 lines.

use exaq_repro::exaq::mc::simulated_optimal_clip;
use exaq_repro::exaq::solver::optimal_clip;
use exaq_repro::report::{f as fnum, Table};

fn main() {
    let mut t = Table::new(
        "Fig. 3 — C*(sigma): analysis vs simulation vs paper line",
        &["sigma", "M", "analytic", "simulation", "paper line"]);
    let paper = |bits: u32, s: f64| match bits {
        2 => -1.66 * s - 1.85,
        3 => -1.75 * s - 2.06,
        _ => f64::NAN,
    };
    for bits in [2u32, 3, 4] {
        for i in 0..9 {
            let sigma = 0.5 + 0.5 * i as f64;
            let a = optimal_clip(sigma, bits);
            let sim = simulated_optimal_clip(sigma, bits, 12,
                                             42 + i as u64);
            let p = paper(bits, sigma);
            t.row(&[fnum(sigma, 2), bits.to_string(), fnum(a, 3),
                    fnum(sim, 3),
                    if p.is_nan() { "-".into() } else { fnum(p, 3) }]);
        }
    }
    println!("{}", t.to_markdown());
    let _ = exaq_repro::report::write_csv(
        "reports/fig3_optimal_clip.csv", &t);
}
