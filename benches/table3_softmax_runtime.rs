//! Table 3 bench — softmax runtime: Algorithm 1 (original) vs
//! Algorithm 2 (EXAQ LUT) wall-clock on the Rust hot path, plus the
//! cycle-model accounting. Regenerates the paper's 3.274ms -> 2.066ms
//! (36.9%) comparison in shape.
//!
//! Hand-rolled harness (the image has no criterion): warmup + N timed
//! repetitions, median-of-means reporting.

use std::time::Instant;

use exaq_repro::cost::CycleTable;
use exaq_repro::exaq::lut::{LutExp, LutSum};
use exaq_repro::exaq::quant::Quantizer;
use exaq_repro::exaq::softmax::{softmax_algo1, softmax_algo2,
                                Algo2Scratch};
use exaq_repro::report::{f as fnum, pct, Table};
use exaq_repro::util::rng::SplitMix64;

fn bench<F: FnMut()>(mut f: F, reps: usize) -> f64 {
    for _ in 0..3 {
        f(); // warmup
    }
    let mut best = f64::INFINITY;
    for _ in 0..5 {
        let t0 = Instant::now();
        for _ in 0..reps {
            f();
        }
        best = best.min(t0.elapsed().as_secs_f64() / reps as f64);
    }
    best
}

fn main() {
    let mut rng = SplitMix64::new(1);
    let c = -6.0f32;

    let mut t = Table::new(
        "Table 3 — softmax runtime, Algo.1 vs Algo.2 (wall-clock, Rust)",
        &["rows x len", "bits", "algo1 (us)", "algo2 (us)", "saving",
          "cycle-model saving", "accum speedup (model)"]);

    for (rows, len) in [(32usize, 2048usize), (64, 1024), (256, 256)] {
        let base: Vec<f32> = (0..rows * len)
            .map(|_| rng.normal() as f32 * 2.0)
            .collect();
        for bits in [2u32, 3, 4] {
            let q = Quantizer::new(bits, c);
            let le = LutExp::build(&q);
            let ls = LutSum::build(&q);
            let mut scratch = Algo2Scratch::default();

            let mut buf = base.clone();
            let a1 = bench(
                || {
                    buf.copy_from_slice(&base);
                    for r in buf.chunks_mut(len) {
                        softmax_algo1(r, len);
                    }
                },
                8,
            );
            let a2 = bench(
                || {
                    buf.copy_from_slice(&base);
                    for r in buf.chunks_mut(len) {
                        softmax_algo2(r, len, &q, &le, &ls, &mut scratch);
                    }
                },
                8,
            );
            let cycles = CycleTable::default();
            t.row(&[
                format!("{rows}x{len}"),
                bits.to_string(),
                fnum(a1 * 1e6, 1),
                fnum(a2 * 1e6, 1),
                pct((a1 - a2) / a1),
                pct(cycles.softmax_saving(len, bits)),
                fnum(cycles.accumulation_speedup(len, bits), 1),
            ]);
        }
    }
    println!("{}", t.to_markdown());
    println!("paper reference: 3.274 ms -> 2.066 ms = 36.9% saving; \
              accumulation ~4x at 2 bits.");
    let _ = exaq_repro::report::write_csv(
        "reports/table3_softmax_runtime.csv", &t);
}
