//! Table 3 bench — softmax runtime: Algorithm 1 (original), per-row
//! scalar Algorithm 2, and the batched bit-packed plane kernel
//! (`BatchSoftmax::softmax_rows`) wall-clock on the Rust hot path,
//! plus the cycle-model accounting. Regenerates the paper's
//! 3.274ms -> 2.066ms (36.9%) comparison in shape and measures the
//! packed-plane speedup over the scalar path (acceptance floor: 1.5x
//! at M = 2 on 256x256).
//!
//! Hand-rolled harness (the image has no criterion): warmup + N timed
//! repetitions, best-of-5 reporting. `EXAQ_BENCH_REPS` overrides the
//! rep count (CI smoke runs with 1). Emits `BENCH_softmax.json` for
//! the perf trajectory (`EXAQ_BENCH_COMMIT=1` also snapshots it to
//! `BENCH_baseline/` for the `repro compare` gate). `baseline_us` is
//! the same kernel pinned to scalar lanes + one worker — the
//! pre-SIMD/pool configuration the fast path must keep beating.

use exaq_repro::cost::CycleTable;
use exaq_repro::exaq::batched;
use exaq_repro::exaq::batched::BatchSoftmax;
use exaq_repro::exaq::footprint;
use exaq_repro::exaq::simd;
use exaq_repro::exaq::softmax::{softmax_algo1, softmax_algo2,
                                Algo2Scratch};
use exaq_repro::report::{f as fnum, jnum, jstr, pct, BenchJson, Table};
use exaq_repro::util::clock::Stopwatch;
use exaq_repro::util::pool;
use exaq_repro::util::rng::SplitMix64;

fn bench<F: FnMut()>(mut f: F, reps: usize) -> f64 {
    for _ in 0..3 {
        f(); // warmup
    }
    let mut best = f64::INFINITY;
    for _ in 0..5 {
        let t0 = Stopwatch::start();
        for _ in 0..reps {
            f();
        }
        best = best.min(t0.seconds() / reps as f64);
    }
    best
}

fn env_reps(default: usize) -> usize {
    std::env::var("EXAQ_BENCH_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&r| r > 0)
        .unwrap_or(default)
}

fn main() {
    let mut rng = SplitMix64::new(1);
    let c = -6.0f32;
    let reps = env_reps(8);

    let mut t = Table::new(
        "Table 3 — softmax runtime, Algo.1 vs Algo.2 scalar vs batched \
         bit-packed (wall-clock, Rust)",
        &["rows x len", "bits", "algo1 (us)", "scalar a2 (us)",
          "baseline a2 (us)", "batched a2 (us)", "batched/scalar",
          "vs baseline", "saving vs a1", "cycle-model saving",
          "accum speedup (model)"]);
    let mut out = BenchJson::new("softmax");
    out.meta("reps", jnum(reps as f64));
    out.meta("clip", jnum(c as f64));
    out.meta("simd", jstr(simd::default_level().name()));
    out.meta("threads", jnum(pool::default_threads() as f64));

    for (rows, len) in [(32usize, 2048usize), (64, 1024), (256, 256)] {
        let base: Vec<f32> = (0..rows * len)
            .map(|_| rng.normal() as f32 * 2.0)
            .collect();
        for bits in [2u32, 3, 4] {
            let mut engine = BatchSoftmax::new(bits, c);
            let (q, le, ls) = {
                let (q, le, ls) = engine.tables();
                (q.clone(), le.clone(), ls.clone())
            };
            let mut scratch = Algo2Scratch::default();

            // Each variant re-softmaxes its own output: the kernels
            // are branch-free over lane values, so per-call work is
            // data-independent and the timed region is pure kernel
            // (no plane memcpy diluting the comparison).
            let mut buf = base.clone();
            let a1 = bench(
                || {
                    for r in buf.chunks_mut(len) {
                        softmax_algo1(r, len);
                    }
                },
                reps,
            );
            buf.copy_from_slice(&base);
            let scalar = bench(
                || {
                    for r in buf.chunks_mut(len) {
                        softmax_algo2(r, len, &q, &le, &ls, &mut scratch);
                    }
                },
                reps,
            );
            // the PR-5 configuration pinned as regression baseline:
            // scalar lanes, one worker — what `batched` was before
            // the SIMD + row-pool work landed
            let mut base_engine = BatchSoftmax::new(bits, c);
            base_engine
                .set_simd_level(simd::Level::Scalar)
                .set_threads(1);
            buf.copy_from_slice(&base);
            let baseline = bench(
                || {
                    base_engine.softmax_rows(&mut buf, rows, len,
                                             &[]);
                },
                reps,
            );
            buf.copy_from_slice(&base);
            let batched = bench(
                || {
                    engine.softmax_rows(&mut buf, rows, len, &[]);
                },
                reps,
            );
            // every Algo-2 path must agree bit-for-bit (the bench
            // would otherwise compare different arithmetic)
            {
                let mut sb = base.clone();
                for r in sb.chunks_mut(len) {
                    softmax_algo2(r, len, &q, &le, &ls, &mut scratch);
                }
                let mut bb = base.clone();
                engine.softmax_rows(&mut bb, rows, len, &[]);
                assert_eq!(sb, bb,
                           "scalar/batched mismatch at bits={bits}");
                let mut pb = base.clone();
                base_engine.softmax_rows(&mut pb, rows, len, &[]);
                assert_eq!(pb, bb,
                           "baseline/fast mismatch at bits={bits}");
            }
            let cycles = CycleTable::default();
            t.row(&[
                format!("{rows}x{len}"),
                bits.to_string(),
                fnum(a1 * 1e6, 1),
                fnum(scalar * 1e6, 1),
                fnum(baseline * 1e6, 1),
                fnum(batched * 1e6, 1),
                format!("{:.2}x", scalar / batched.max(1e-12)),
                format!("{:.2}x", baseline / batched.max(1e-12)),
                pct((a1 - batched) / a1.max(1e-12)),
                pct(cycles.softmax_saving(len, bits)),
                fnum(cycles.accumulation_speedup_grouped(
                    len, engine.group()), 1),
            ]);
            out.result(&[
                ("rows", jnum(rows as f64)),
                ("len", jnum(len as f64)),
                ("bits", jnum(bits as f64)),
                ("group", jnum(engine.group() as f64)),
                ("algo1_us", jnum(a1 * 1e6)),
                ("scalar_us", jnum(scalar * 1e6)),
                ("baseline_us", jnum(baseline * 1e6)),
                ("batched_us", jnum(batched * 1e6)),
                // guarded: a coarse timer at EXAQ_BENCH_REPS=1 could
                // report 0, and inf would not serialise as valid JSON
                ("batched_speedup",
                 jnum(scalar / batched.max(1e-12))),
                ("speedup_vs_baseline",
                 jnum(baseline / batched.max(1e-12))),
                ("simd", jstr(engine.simd_level().name())),
                ("threads", jnum(engine.threads() as f64)),
                // packed-key footprint quoted from the shared layout
                // helper (byte keys at M = 2, u16 keys at M = 3/4);
                // asserted equal to the live plane so the accounting
                // in exaq::footprint can never drift from the engine
                ("plane_bytes", jnum({
                    let fp = footprint::packed_plane_bytes(
                        rows, len, bits);
                    assert_eq!(fp, engine.plane_bytes(),
                               "footprint helper drifted from the \
                                live plane at bits={bits}");
                    fp as f64
                })),
                ("kernel", jstr("softmax_rows")),
            ]);
        }
    }
    // thread-local engine-cache counters: zero for this bench's
    // directly-owned engines, but recorded so any future routing of
    // the bench through the cached path shows up in the telemetry
    let (hits, misses) = batched::cache_stats();
    out.meta("engine_cache_hits", jnum(hits as f64));
    out.meta("engine_cache_misses", jnum(misses as f64));
    println!("{}", t.to_markdown());
    println!("paper reference: 3.274 ms -> 2.066 ms = 36.9% saving; \
              accumulation ~4x at 2 bits.");
    let _ = exaq_repro::report::write_csv(
        "reports/table3_softmax_runtime.csv", &t);
    match out.write() {
        Ok(path) => println!("bench telemetry -> {path}"),
        Err(e) => eprintln!("failed to write bench json: {e}"),
    }
}
