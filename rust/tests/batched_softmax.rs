//! Integration suite for the batched bit-packed softmax kernel:
//! a property-style randomized sweep (hand-rolled; the image has no
//! proptest) asserting *bit-exact* agreement between
//! `BatchSoftmax::softmax_rows` and per-row scalar `softmax_algo2`
//! across rows / lens / masks / bit-widths / clips, plus hostile
//! inputs (all-`-inf` rows, `valid_len` > len, rows = 0, lens not
//! divisible by the packing group), SIMD-level and worker-count
//! invariance (every available lane width and thread count must be
//! bit-identical to the scalar inline path), and the batched-sampler
//! / per-row-sampler equivalence on full serving planes.

use exaq_repro::exaq::batched::BatchSoftmax;
use exaq_repro::exaq::lut::{LutExp, LutSum};
use exaq_repro::exaq::quant::Quantizer;
use exaq_repro::exaq::simd;
use exaq_repro::exaq::softmax::{softmax_algo2, Algo2Scratch};
use exaq_repro::model::sampling::{sample_with, BatchSampler,
                                  SamplerScratch, SamplingParams};
use exaq_repro::util::rng::SplitMix64;

fn random_plane(rows: usize, len: usize, seed: u64,
                scale: f32) -> Vec<f32> {
    let mut r = SplitMix64::new(seed);
    (0..rows * len).map(|_| (r.normal() as f32) * scale).collect()
}

/// Scalar reference: per-row Algorithm 2 with freshly built tables.
fn scalar_reference(plane: &mut [f32], len: usize,
                    valid_lens: &[usize], bits: u32, clip: f32) {
    let q = Quantizer::new(bits, clip);
    let le = LutExp::build(&q);
    let ls = LutSum::build(&q);
    let mut scratch = Algo2Scratch::default();
    for (r, row) in plane.chunks_exact_mut(len).enumerate() {
        let vlen = if valid_lens.is_empty() { len } else { valid_lens[r] };
        softmax_algo2(row, vlen, &q, &le, &ls, &mut scratch);
    }
}

fn assert_planes_bit_equal(a: &[f32], b: &[f32], tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(),
                   "{tag}: lane {i}: {x} vs {y}");
    }
}

#[test]
fn randomized_sweep_is_bit_exact_with_scalar_path() {
    // 150 random configurations: rows 0..8, len 1..120 (often not a
    // multiple of the group), hostile valid_lens (0, > len), bits 1-4,
    // random clips and scales — every lane must match bit-for-bit
    let mut meta = SplitMix64::new(0xBA7C);
    let mut engines: Vec<BatchSoftmax> = Vec::new();
    for trial in 0..150 {
        let rows = meta.below(8);
        let len = 1 + meta.below(120);
        let bits = 1 + meta.below(4) as u32;
        let clip = -1.0 - (meta.uniform() as f32) * 6.0;
        let scale = 0.5 + (meta.uniform() as f32) * 3.0;
        let valid_lens: Vec<usize> = match meta.below(3) {
            0 => Vec::new(), // empty = full rows
            1 => (0..rows).map(|_| meta.below(len + 1)).collect(),
            _ => (0..rows)
                .map(|_| meta.below(2 * len + 8)) // often > len
                .collect(),
        };
        let mut plane =
            random_plane(rows, len, 0x5EED + trial, scale);
        let mut reference = plane.clone();

        // reuse engines across trials the way serving does, to also
        // exercise plane-scratch reuse at changing shapes
        let engine = match engines
            .iter_mut()
            .position(|e| e.matches(bits, clip))
        {
            Some(i) => &mut engines[i],
            None => {
                engines.push(BatchSoftmax::new(bits, clip));
                engines.last_mut().unwrap()
            }
        };
        engine.softmax_rows(&mut plane, rows, len, &valid_lens);
        scalar_reference(&mut reference, len, &valid_lens, bits, clip);
        assert_planes_bit_equal(
            &plane, &reference,
            &format!("trial {trial} rows={rows} len={len} bits={bits} \
                      clip={clip}"));

        // masked lanes must be exactly zero, valid prefixes normalised
        for (r, row) in plane.chunks_exact(len).enumerate() {
            let n = if valid_lens.is_empty() { len } else { valid_lens[r] }
                .min(len);
            assert!(row[n..].iter().all(|&p| p == 0.0),
                    "trial {trial} row {r}: masked lanes leaked");
            if n > 0 {
                let s: f32 = row[..n].iter().sum();
                assert!((s - 1.0).abs() < 1e-3,
                        "trial {trial} row {r}: sum {s}");
            }
        }
    }
}

#[test]
fn hostile_inputs_match_scalar_semantics() {
    let mut engine = BatchSoftmax::new(2, -4.0);

    // rows = 0: a no-op on an empty plane
    let mut empty: Vec<f32> = Vec::new();
    engine.softmax_rows(&mut empty, 0, 64, &[]);

    // all -inf rows: NaN after the max shift collapses to code 0 and
    // the plane degrades to uniform, never NaN
    let (rows, len) = (4usize, 22usize); // 22 % 4 != 0
    let mut plane = vec![f32::NEG_INFINITY; rows * len];
    let vlens = [len, 3, 1000, 0];
    engine.softmax_rows(&mut plane, rows, len, &vlens);
    let mut reference = vec![f32::NEG_INFINITY; rows * len];
    scalar_reference(&mut reference, len, &vlens, 2, -4.0);
    assert_planes_bit_equal(&plane, &reference, "all -inf plane");
    for &p in &plane[..len] {
        assert!(p.is_finite());
        assert!((p - 1.0 / len as f32).abs() < 1e-5);
    }
    // valid_len = 0 row is all zeros
    assert!(plane[3 * len..].iter().all(|&p| p == 0.0));
    // valid_len > len behaves exactly like the full row (row 2)
    let full: Vec<f32> = {
        let mut one = vec![f32::NEG_INFINITY; len];
        let mut e = BatchSoftmax::new(2, -4.0);
        e.softmax_rows(&mut one, 1, len, &[]);
        one
    };
    assert_planes_bit_equal(&plane[2 * len..3 * len], &full,
                            "clamped valid_len");
}

#[test]
fn single_column_and_single_row_planes() {
    // len = 1 (every group is a tail group) and rows = 1
    for bits in [1u32, 2, 3, 4] {
        let mut col = random_plane(5, 1, 42, 2.0);
        let mut reference = col.clone();
        let mut engine = BatchSoftmax::new(bits, -5.0);
        engine.softmax_rows(&mut col, 5, 1, &[]);
        scalar_reference(&mut reference, 1, &[], bits, -5.0);
        assert_planes_bit_equal(&col, &reference,
                                &format!("len=1 bits={bits}"));
        for &p in &col {
            // a 1-lane row is a point mass (up to the padded-lane
            // correction's last-ulp rounding)
            assert!((p - 1.0).abs() < 1e-4, "{p}");
        }
        let mut row = random_plane(1, 77, 43, 2.0);
        let mut rref = row.clone();
        engine.softmax_rows(&mut row, 1, 77, &[33]);
        scalar_reference(&mut rref, 77, &[33], bits, -5.0);
        assert_planes_bit_equal(&row, &rref,
                                &format!("rows=1 bits={bits}"));
    }
}

#[test]
fn every_simd_level_is_bit_exact_with_the_scalar_engine() {
    // sweep every lane width the host offers against the scalar
    // reference across lane-tail lengths (len % 4, % 8 ∈ all
    // residues), every bit-width, and valid_len edge cases — the
    // kernel contract is bit-identical output at any level
    let levels = simd::available_levels();
    assert!(levels.contains(&simd::Level::Scalar));
    let lens = [1usize, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 33, 64,
                65];
    for &level in &levels {
        for bits in [2u32, 3, 4] {
            for (t, &len) in lens.iter().enumerate() {
                let rows = 3usize;
                let seed = 0xABCD + (bits as u64) * 131 + t as u64;
                let mut plane = random_plane(rows, len, seed, 2.5);
                // row 0 full, row 1 a mid cut, row 2 over-long
                let vlens = [len, len / 2, len + 9];
                let mut reference = plane.clone();
                let mut engine = BatchSoftmax::new(bits, -4.0);
                engine.set_simd_level(level);
                assert_eq!(engine.simd_level(), level);
                engine.softmax_rows(&mut plane, rows, len, &vlens);
                scalar_reference(&mut reference, len, &vlens, bits,
                                 -4.0);
                assert_planes_bit_equal(
                    &plane, &reference,
                    &format!("level={} bits={bits} len={len}",
                             level.name()));
            }
        }
    }
}

#[test]
fn worker_count_never_changes_the_plane() {
    // the scoped row pool must be invisible in the output: the same
    // plane through 1, 2, and 7 workers (and the auto heuristic) is
    // bit-identical, including ragged valid_lens
    let (rows, len) = (64usize, 96usize);
    for bits in [2u32, 3, 4] {
        let plane0 = random_plane(rows, len, 0xF00D + bits as u64,
                                  2.0);
        let vlens: Vec<usize> =
            (0..rows).map(|r| (r * 13) % (len + 2)).collect();
        let mut want = plane0.clone();
        scalar_reference(&mut want, len, &vlens, bits, -4.0);
        for threads in [1usize, 2, 7, 0] {
            let mut plane = plane0.clone();
            let mut engine = BatchSoftmax::new(bits, -4.0);
            engine.set_threads(threads);
            engine.softmax_rows(&mut plane, rows, len, &vlens);
            assert_planes_bit_equal(
                &plane, &want,
                &format!("bits={bits} threads={threads}"));
        }
    }
}

#[test]
fn batch_sampler_equals_per_row_sampler_on_serving_planes() {
    // a serving-shaped plane: decode_batch rows, mixed greedy / EXAQ
    // stochastic params, shared RNG — the batched sampler must emit
    // the identical token stream
    let vocab = 64usize;
    let rows = 8usize;
    for seed in 0..10u64 {
        let logits = random_plane(rows, vocab, 1000 + seed, 3.0);
        let sel: Vec<(usize, SamplingParams)> = (0..rows)
            .map(|r| {
                let p = match r % 4 {
                    0 => SamplingParams::greedy(),
                    1 => SamplingParams::exaq(0.9, 2, -4.0),
                    2 => SamplingParams { temperature: 0.8, top_k: 7,
                                          exaq: Some((2, -4.0)) },
                    _ => SamplingParams { temperature: 1.2, top_k: 0,
                                          exaq: None },
                };
                (r, p)
            })
            .collect();
        let mut sampler = BatchSampler::default();
        let mut batched = Vec::new();
        let mut rng_a = SplitMix64::new(777 + seed);
        sampler.sample_rows(&logits, vocab, &sel, &mut rng_a,
                            &mut batched);

        let mut rng_b = SplitMix64::new(777 + seed);
        let mut scratch = SamplerScratch::default();
        let scalar: Vec<i32> = sel
            .iter()
            .map(|(r, p)| {
                sample_with(&logits[r * vocab..(r + 1) * vocab], p,
                            &mut rng_b, &mut scratch)
            })
            .collect();
        assert_eq!(batched, scalar, "seed {seed}");
        for &(r, _) in &sel {
            let t = batched[r];
            assert!((0..vocab as i32).contains(&t),
                    "seed {seed}: token {t} out of vocabulary");
        }
    }
}
