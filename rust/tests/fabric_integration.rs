//! Fabric stress suite: the router + N-replica serving fabric driven
//! by the deterministic `SimBackend` on per-replica virtual clocks.
//!
//! The headline test (`million_request_storm_*`, `#[ignore]`d for
//! plain `cargo test`, run by CI's fabric-stress job via
//! `--include-ignored`) pushes one million simulated requests across
//! four replicas and asserts the run is bit-identical when repeated:
//! same response digest, same latency percentiles, same per-replica
//! and per-tenant counts. The always-on tests cover the same
//! invariants at smoke size plus the behavioural edges: preemption
//! without token loss, cancellation and deadline reconciliation,
//! admission control, tenant fairness, token streaming, and greedy
//! stream invariance across replica counts and host thread counts.
//!
//! `EXAQ_FABRIC_REQUESTS` overrides the storm size.

use std::collections::{BTreeMap, BTreeSet};
use std::rc::Rc;

use exaq_repro::coordinator::{
    workload, Assignment, Fabric, FabricConfig, FinishReason, Metrics,
    Priority, Replica, Request, Response, RouterConfig, Scenario,
    ServeConfig, TimedRequest, WorkloadSpec, NO_REPLICA,
};
use exaq_repro::model::SamplingParams;
use exaq_repro::runtime::{QuantMode, SimBackend, SimConfig};
use exaq_repro::util::clock::{Clock, VirtualClock};
use exaq_repro::util::error::Result;

const TENANTS: u32 = 4;

fn mk_fabric(
    replicas: usize, sim_cfg: &SimConfig, decode_batch: usize,
    router: RouterConfig, collect_stream: bool,
) -> Result<Fabric<SimBackend>> {
    let cfg = FabricConfig {
        serve: ServeConfig {
            model: "sim".into(),
            quant: QuantMode::None,
            c_vec: None,
            decode_batch,
        },
        router,
        collect_stream,
    };
    let mk = sim_cfg.clone();
    Fabric::new(replicas, cfg, move |_, clock| {
        Ok(SimBackend::new(mk.clone(), clock))
    })
}

/// Drain a fabric that has already been fed via `submit`.
fn drain(fab: &mut Fabric<SimBackend>, out: &mut Vec<Response>) {
    for _ in 0..100_000 {
        if !fab.has_work() {
            return;
        }
        fab.step(None, out).expect("fabric step");
    }
    panic!("fabric failed to drain");
}

// ---- deterministic response digest ------------------------------

fn fnv(h: &mut u64, x: u64) {
    let mut v = x;
    for _ in 0..8 {
        *h ^= v & 0xFF;
        *h = h.wrapping_mul(0x0000_0100_0000_01B3);
        v >>= 8;
    }
}

fn finish_code(f: FinishReason) -> u64 {
    match f {
        FinishReason::Done => 0,
        FinishReason::Cancelled => 1,
        FinishReason::TimedOut => 2,
    }
}

/// FNV-1a over every observable field of one response. The storm
/// folds these with a commutative sum, so the digest pins the full
/// response set without buffering a million responses for sorting.
fn response_hash(r: &Response) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    fnv(&mut h, r.id);
    fnv(&mut h, r.prompt_len as u64);
    fnv(&mut h, r.tokens.len() as u64);
    for &t in &r.tokens {
        fnv(&mut h, t as u64);
    }
    fnv(&mut h, r.ttft.to_bits());
    fnv(&mut h, r.total_latency.to_bits());
    fnv(&mut h, u64::from(r.tenant));
    fnv(&mut h, r.priority.index() as u64);
    fnv(&mut h, r.replica as u64);
    fnv(&mut h, finish_code(r.finish));
    fnv(&mut h, u64::from(r.preemptions));
    h
}

// ---- the storm --------------------------------------------------

/// Everything a storm run observes, floats pinned by bit pattern so
/// two runs can be compared with `assert_eq!` — any nondeterminism in
/// scheduling, sampling, preemption, or the clocks shows up here.
#[derive(Debug, PartialEq, Eq)]
struct StormStats {
    n: usize,
    digest: u64,
    tokens_total: u64,
    elapsed_bits: u64,
    p50_ttft_bits: u64,
    p99_ttft_bits: u64,
    p50_latency_bits: u64,
    p99_latency_bits: u64,
    occupancy_bits: u64,
    preemptions: u64,
    resumes: u64,
    per_replica_prefills: Vec<u64>,
    per_replica_done: Vec<u64>,
    per_replica_occupancy_bits: Vec<u64>,
    per_tenant_done: Vec<u64>,
}

/// Run a mixed-scenario storm of `n` requests through a fresh fabric
/// and fold every response into [`StormStats`], asserting the
/// conservation invariants along the way.
fn run_storm(
    n: usize, replicas: usize, threads: usize, seed0: u64,
) -> StormStats {
    let sim_cfg = SimConfig { threads, ..SimConfig::tiny() };
    let mut fab = mk_fabric(replicas, &sim_cfg, 8,
                            RouterConfig::default(), false)
        .expect("fabric builds");

    // phase mix: every workload generator, the stochastic all-tier
    // mixed scenario taking the largest share. Arrival rates are far
    // above fleet capacity so every replica stays saturated.
    let mut counts =
        [n * 2 / 5, n / 4, n / 8, n / 8, 0usize];
    counts[4] = n - counts[..4].iter().sum::<usize>();
    let scenarios = [
        Scenario::MixedLengths { rate: 10_000.0 },
        Scenario::Steady { rate: 10_000.0 },
        Scenario::Burst { n_bursts: 64, gap: 0.02 },
        Scenario::ChatEarlyEos { rate: 10_000.0 },
        Scenario::LongPromptTail { rate: 10_000.0 },
    ];

    let mut max_new = vec![0u8; n];
    let mut tenant_of = vec![0u8; n];
    let mut seen = vec![false; n];
    let mut per_tenant_done = vec![0u64; TENANTS as usize];
    let mut digest = 0u64;
    let mut tokens_total = 0u64;
    let mut elapsed = 0.0f64;
    let mut base = 0u64;

    for (phase, (scenario, &count)) in
        scenarios.iter().zip(&counts).enumerate()
    {
        if count == 0 {
            continue;
        }
        let spec = WorkloadSpec::new(
            *scenario, count, seed0 + phase as u64, sim_cfg.vocab,
            sim_cfg.max_seq,
        )
        .with_tenants(TENANTS);
        let mut trace = workload::generate(&spec);
        for tr in trace.iter_mut() {
            tr.req.id += base;
            let i = tr.req.id as usize;
            max_new[i] = tr.req.max_new_tokens.min(255) as u8;
            tenant_of[i] = tr.req.tenant as u8;
        }
        base += count as u64;

        elapsed += fab
            .run_trace_with(trace, |r| {
                let i = r.id as usize;
                assert!(!seen[i], "request {i} completed twice");
                seen[i] = true;
                assert_eq!(r.finish, FinishReason::Done,
                           "request {i} did not run to completion");
                assert!(!r.tokens.is_empty(),
                        "request {i} got no tokens");
                assert!(r.tokens.len() <= max_new[i] as usize,
                        "request {i} overshot its budget");
                assert_eq!(u64::from(r.tenant),
                           u64::from(tenant_of[i]));
                assert!(r.replica < replicas,
                        "request {i} on phantom replica {}",
                        r.replica);
                assert!(r.ttft > 0.0);
                assert!(r.total_latency >= r.ttft);
                per_tenant_done[r.tenant as usize] += 1;
                tokens_total += r.tokens.len() as u64;
                digest = digest.wrapping_add(response_hash(&r));
            })
            .expect("storm phase runs");
    }

    assert!(seen.iter().all(|&s| s), "requests went missing");
    let fleet = fab.fleet_metrics();
    assert_eq!(fleet.requests_in, n as u64);
    assert_eq!(fleet.requests_done, n as u64);
    assert_eq!(fleet.rejected, 0);
    assert_eq!(fleet.cancelled, 0);
    assert_eq!(fleet.timed_out, 0);
    assert_eq!(fleet.ttft.count(), n as u64);
    assert_eq!(fleet.total_latency.count(), n as u64);
    // token conservation: one token per prefill (fresh or resume),
    // everything else from batched decode steps; a lost preemption
    // or double-counted resume breaks one of these exactly
    assert_eq!(fleet.prefills, n as u64 + fleet.resumes);
    assert_eq!(tokens_total, fleet.decode_tokens + fleet.prefills);
    assert_eq!(fleet.preemptions, fleet.resumes,
               "evicted work must always resume");

    let mut per_replica_prefills = Vec::new();
    let mut per_replica_done = Vec::new();
    let mut per_replica_occupancy_bits = Vec::new();
    for i in 0..replicas {
        let rep = fab.replica(i);
        assert_eq!(rep.pool().in_use(), 0,
                   "replica {i} leaked KV slots");
        assert_eq!(rep.active_count(), 0);
        assert_eq!(rep.queue_len(), 0);
        assert!(rep.metrics().prefills > 0, "replica {i} never used");
        per_replica_prefills.push(rep.metrics().prefills);
        per_replica_done.push(rep.metrics().requests_done);
        per_replica_occupancy_bits
            .push(rep.metrics().mean_occupancy().to_bits());
    }
    let max_done = per_replica_done.iter().copied().max().unwrap_or(0);
    let min_done = per_replica_done.iter().copied().min().unwrap_or(0);
    assert!(max_done <= 4 * min_done + 64,
            "fleet imbalance: {per_replica_done:?}");

    let mean = n as f64 / f64::from(TENANTS);
    for (t, &c) in per_tenant_done.iter().enumerate() {
        assert!((c as f64 - mean).abs() <= 0.1 * mean + 64.0,
                "tenant {t} served {c}, expected ~{mean:.0} +/- 10%");
    }

    let p50_ttft = fleet.ttft.quantile(0.5);
    let p99_ttft = fleet.ttft.quantile(0.99);
    let p50_lat = fleet.total_latency.quantile(0.5);
    let p99_lat = fleet.total_latency.quantile(0.99);
    assert!(p50_ttft > 0.0 && p50_ttft <= p99_ttft);
    assert!(p50_lat > 0.0 && p50_lat <= p99_lat);
    assert!(p99_lat >= p99_ttft,
            "latency cannot be below ttft pointwise");
    assert!(fleet.mean_occupancy() > 0.0);
    assert!(elapsed > 0.0);

    StormStats {
        n,
        digest,
        tokens_total,
        elapsed_bits: elapsed.to_bits(),
        p50_ttft_bits: p50_ttft.to_bits(),
        p99_ttft_bits: p99_ttft.to_bits(),
        p50_latency_bits: p50_lat.to_bits(),
        p99_latency_bits: p99_lat.to_bits(),
        occupancy_bits: fleet.mean_occupancy().to_bits(),
        preemptions: fleet.preemptions,
        resumes: fleet.resumes,
        per_replica_prefills,
        per_replica_done,
        per_replica_occupancy_bits,
        per_tenant_done,
    }
}

fn storm_n() -> usize {
    std::env::var("EXAQ_FABRIC_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&n| n > 0)
        .unwrap_or(1_000_000)
}

/// The headline run: a million mixed requests across four replicas,
/// twice, compared field by field down to float bit patterns.
#[test]
#[ignore = "million-request storm; CI runs it via --include-ignored"]
fn million_request_storm_is_deterministic_across_runs() {
    let n = storm_n();
    let a = run_storm(n, 4, 0, 1);
    let b = run_storm(n, 4, 0, 1);
    assert_eq!(a, b, "the storm is not reproducible");
}

/// Always-on miniature of the storm: same invariants, smoke size.
#[test]
fn fabric_smoke_storm_is_deterministic() {
    let a = run_storm(12_000, 4, 0, 1);
    let b = run_storm(12_000, 4, 0, 1);
    assert_eq!(a, b, "the smoke storm is not reproducible");
}

#[test]
fn storms_reproduce_per_seed_and_diverge_across_seeds() {
    let a1 = run_storm(2_000, 2, 0, 1);
    let a2 = run_storm(2_000, 2, 0, 1);
    let b = run_storm(2_000, 2, 0, 77);
    assert_eq!(a1, a2);
    assert_ne!(a1.digest, b.digest,
               "different seeds produced identical storms");
}

/// `SimConfig::threads` moves host time only; every virtual-time
/// observable — tokens, latencies, placement — must be bit-equal.
#[test]
fn virtual_time_is_invariant_to_host_worker_threads() {
    let a = run_storm(3_000, 4, 1, 1);
    let b = run_storm(3_000, 4, 7, 1);
    assert_eq!(a, b, "worker threads leaked into virtual time");
}

// ---- replica-count invariance -----------------------------------

fn greedy_burst(replicas: usize) -> (BTreeMap<u64, Vec<i32>>, f64) {
    let sim_cfg = SimConfig::default();
    let n = 600;
    let spec = WorkloadSpec::new(
        Scenario::Burst { n_bursts: 4, gap: 0.05 }, n, 11,
        sim_cfg.vocab, sim_cfg.max_seq,
    )
    .with_tenants(3);
    let trace = workload::generate(&spec);
    let mut fab = mk_fabric(replicas, &sim_cfg, 8,
                            RouterConfig::default(), false)
        .expect("fabric builds");
    let (resps, elapsed) =
        fab.run_trace(trace).expect("burst runs");
    assert_eq!(resps.len(), n);
    (resps.into_iter().map(|r| (r.id, r.tokens)).collect(), elapsed)
}

/// Greedy sampling draws no randomness, so a request's token stream
/// may not depend on which replica served it or how the batch was
/// packed — while more replicas must still shorten simulated time.
#[test]
fn greedy_streams_are_invariant_across_replica_counts() {
    let (one, t1) = greedy_burst(1);
    let (four, t4) = greedy_burst(4);
    assert_eq!(one, four,
               "token streams depend on the replica count");
    assert!(t4 < t1,
            "4 replicas not faster than 1 ({t4} vs {t1})");
}

// ---- preemption -------------------------------------------------

fn preemption_trace() -> Vec<TimedRequest> {
    let mut trace = Vec::new();
    // a wall of long batch decodes saturating both slots from t=0
    for id in 0..8u64 {
        trace.push(TimedRequest {
            at: 0.0,
            req: Request::new(id, vec![4 + id as i32, 5, 6], 10,
                              SamplingParams::greedy())
                .with_priority(Priority::Batch),
        });
    }
    // interactive work lands just after the wall is in flight
    for id in 100..104u64 {
        trace.push(TimedRequest {
            at: 0.001,
            req: Request::new(id, vec![7, 8], 4,
                              SamplingParams::greedy())
                .with_priority(Priority::Interactive),
        });
    }
    trace
}

fn run_preemption(
    preemption: bool,
) -> (BTreeMap<u64, Response>, Metrics) {
    let sim_cfg = SimConfig::default();
    let mut fab = mk_fabric(
        1, &sim_cfg, 2,
        RouterConfig { preemption, ..RouterConfig::default() },
        false,
    )
    .expect("fabric builds");
    let (resps, _) =
        fab.run_trace(preemption_trace()).expect("trace runs");
    assert_eq!(resps.len(), 12);
    assert_eq!(fab.replica(0).pool().in_use(), 0);
    let fleet = fab.fleet_metrics();
    (resps.into_iter().map(|r| (r.id, r)).collect(), fleet)
}

#[test]
fn preemption_frees_interactive_capacity_without_losing_tokens() {
    let (on, m_on) = run_preemption(true);
    let (off, m_off) = run_preemption(false);

    assert!(m_on.preemptions >= 1, "nothing was preempted");
    assert_eq!(m_on.resumes, m_on.preemptions,
               "evicted work must always resume");
    assert_eq!(m_off.preemptions, 0);
    assert_eq!(m_off.resumes, 0);
    assert!(on.values().any(|r| r.preemptions > 0),
            "no response records its eviction");

    // resume correctness: greedy streams are bit-identical whether
    // or not the request was evicted and re-prefilled mid-decode
    for (id, r) in &on {
        let o = &off[id];
        assert_eq!(r.finish, FinishReason::Done);
        assert_eq!(o.finish, FinishReason::Done);
        assert_eq!(r.tokens, o.tokens,
                   "request {id} lost or changed tokens under \
                    preemption");
    }

    // and the point of it all: interactive TTFT improves
    let mean_ttft = |m: &BTreeMap<u64, Response>| {
        let xs: Vec<f64> = m
            .values()
            .filter(|r| r.priority == Priority::Interactive)
            .map(|r| r.ttft)
            .collect();
        assert_eq!(xs.len(), 4);
        xs.iter().sum::<f64>() / xs.len() as f64
    };
    assert!(mean_ttft(&on) < mean_ttft(&off),
            "preemption did not improve interactive TTFT");
}

// ---- cancellation -----------------------------------------------

#[test]
fn cancellation_reconciles_router_replica_and_kv_state() {
    let sim_cfg = SimConfig::default();
    let mut fab = mk_fabric(1, &sim_cfg, 2,
                            RouterConfig::default(), false)
        .expect("fabric builds");
    let mut out = Vec::new();
    for id in 0..12u64 {
        assert!(fab.submit(Request::new(
            id, vec![4 + id as i32, 5, 6], 12,
            SamplingParams::greedy(),
        )));
    }

    // cancel while still queued at the router: no replica, no tokens
    assert!(fab.cancel(3, &mut out).expect("cancel runs"));
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].id, 3);
    assert_eq!(out[0].finish, FinishReason::Cancelled);
    assert_eq!(out[0].replica, NO_REPLICA);
    assert!(out[0].tokens.is_empty());
    // unknown ids are reported, not silently swallowed
    assert!(!fab.cancel(999, &mut out).expect("cancel runs"));

    // run until something is in flight, then cancel it mid-decode
    for _ in 0..16 {
        fab.step(None, &mut out).expect("fabric step");
        if fab.replica(0).active_count() > 0 {
            break;
        }
    }
    assert!(fab.replica(0).active_count() > 0,
            "no in-flight work to cancel");
    // dispatch is FIFO, so the smallest unfinished (uncancelled) id
    // is in flight right now
    let done: BTreeSet<u64> = out.iter().map(|r| r.id).collect();
    let victim = (0..12u64)
        .find(|id| *id != 3 && !done.contains(id))
        .expect("someone is still running");
    let before = out.len();
    assert!(fab.cancel(victim, &mut out).expect("cancel runs"));
    let c = &out[before];
    assert_eq!(c.id, victim);
    assert_eq!(c.finish, FinishReason::Cancelled);
    assert_eq!(c.replica, 0);
    assert!(!c.tokens.is_empty(),
            "mid-decode cancel must keep the tokens so far");

    drain(&mut fab, &mut out);

    // exactly one terminal response per request; KV fully returned
    assert_eq!(out.len(), 12);
    let ids: BTreeSet<u64> = out.iter().map(|r| r.id).collect();
    assert_eq!(ids.len(), 12);
    let cancelled = out
        .iter()
        .filter(|r| r.finish == FinishReason::Cancelled)
        .count();
    assert_eq!(cancelled, 2);
    assert_eq!(fab.router_metrics().cancelled, 1);
    assert_eq!(fab.replica(0).metrics().cancelled, 1);
    let fleet = fab.fleet_metrics();
    assert_eq!(fleet.cancelled, 2);
    assert_eq!(fleet.requests_done, 10);
    // only clean completions feed the latency histograms
    assert_eq!(fleet.ttft.count(), 10);
    assert_eq!(fab.replica(0).pool().in_use(), 0);
    assert_eq!(fab.replica(0).active_count(), 0);
    assert_eq!(fab.router().queued_len(), 0);
}

/// Direct replica-level coverage: cancelling work that is assigned
/// but not yet admitted, and the fresh-vs-resume accounting split.
#[test]
fn replica_queue_cancel_and_resume_bookkeeping() {
    let sim_cfg = SimConfig::default();
    let clock: Rc<dyn Clock> = Rc::new(VirtualClock::new());
    let sim = SimBackend::new(sim_cfg, clock.clone());
    let mut rep = Replica::new(0, &sim, "sim", QuantMode::None, None,
                               2, clock)
        .expect("replica builds");
    rep.assign(Assignment::fresh(
        Request::new(7, vec![4, 5], 4, SamplingParams::greedy()),
        0.0,
    ));
    assert_eq!(rep.queue_len(), 1);
    assert_eq!(rep.metrics().requests_in, 1);

    let mut out = Vec::new();
    assert!(rep.cancel(7, &mut out).expect("cancel runs"));
    assert!(!rep.cancel(7, &mut out).expect("cancel runs"));
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].finish, FinishReason::Cancelled);
    assert_eq!(out[0].replica, 0);
    assert!(out[0].tokens.is_empty());
    assert!(!rep.has_work());
    assert_eq!(rep.metrics().cancelled, 1);
    assert_eq!(rep.pool().in_use(), 0);

    // a resumed assignment counts as a resume, not a fresh request
    let mut asg = Assignment::fresh(
        Request::new(8, vec![4, 5], 4, SamplingParams::greedy()),
        0.0,
    );
    asg.preemptions = 1;
    rep.assign(asg);
    assert_eq!(rep.metrics().requests_in, 1);
    assert_eq!(rep.metrics().resumes, 1);
}

// ---- deadlines --------------------------------------------------

#[test]
fn deadlines_expire_queued_and_in_flight_work() {
    // queued at the router: capacity 1 is taken by id 0, so id 1
    // expires at the front door without ever reaching a replica
    let sim_cfg = SimConfig::default();
    let mut fab = mk_fabric(1, &sim_cfg, 1,
                            RouterConfig::default(), false)
        .expect("fabric builds");
    let trace = vec![
        TimedRequest {
            at: 0.0,
            req: Request::new(0, vec![4, 5], 6,
                              SamplingParams::greedy()),
        },
        TimedRequest {
            at: 0.0,
            req: Request::new(1, vec![6, 7], 6,
                              SamplingParams::greedy())
                .with_timeout(1e-9),
        },
    ];
    let (resps, _) = fab.run_trace(trace).expect("trace runs");
    assert_eq!(resps.len(), 2);
    let r1 = resps.iter().find(|r| r.id == 1).expect("id 1 exits");
    assert_eq!(r1.finish, FinishReason::TimedOut);
    assert_eq!(r1.replica, NO_REPLICA);
    assert!(r1.tokens.is_empty());
    assert_eq!(r1.ttft, 0.0, "never produced a token");
    assert!(r1.total_latency > 0.0);
    assert_eq!(fab.router_metrics().timed_out, 1);
    assert_eq!(fab.replica(0).metrics().timed_out, 0);

    // in flight: a deadline shorter than any simulated step expires
    // every admitted request right after its prefill, keeping the
    // tokens sampled so far and returning the KV slot
    let mut fab = mk_fabric(1, &sim_cfg, 8,
                            RouterConfig::default(), false)
        .expect("fabric builds");
    let trace: Vec<TimedRequest> = (0..8u64)
        .map(|id| TimedRequest {
            at: 0.0,
            req: Request::new(id, vec![4 + id as i32, 5], 8,
                              SamplingParams::greedy())
                .with_timeout(1e-9),
        })
        .collect();
    let (resps, _) = fab.run_trace(trace).expect("trace runs");
    assert_eq!(resps.len(), 8);
    let timed: Vec<&Response> = resps
        .iter()
        .filter(|r| r.finish == FinishReason::TimedOut)
        .collect();
    // an organic early EOS may finish a request at its prefill, but
    // the deadline must catch (at least) the overwhelming rest
    assert!(timed.len() >= 4,
            "only {}/8 hit the in-flight deadline", timed.len());
    for r in &timed {
        assert_eq!(r.replica, 0);
        assert!(!r.tokens.is_empty(),
                "timed-out request {} lost its partial tokens",
                r.id);
        assert!(r.ttft > 0.0);
    }
    let fleet = fab.fleet_metrics();
    assert_eq!(fleet.timed_out, timed.len() as u64);
    assert_eq!(
        fleet.requests_done + fleet.timed_out,
        8,
        "every request exits exactly once"
    );
    assert_eq!(fleet.ttft.count(), fleet.requests_done);
    assert_eq!(fab.replica(0).pool().in_use(), 0);
}

// ---- admission control ------------------------------------------

#[test]
fn admission_control_rejects_when_the_router_is_full() {
    let sim_cfg = SimConfig::default();
    let mut fab = mk_fabric(
        1, &sim_cfg, 2,
        RouterConfig { max_queue: 2, ..RouterConfig::default() },
        false,
    )
    .expect("fabric builds");
    let mut accepted = 0;
    for id in 0..5u64 {
        if fab.submit(Request::new(id, vec![4, 5], 3,
                                   SamplingParams::greedy()))
        {
            accepted += 1;
        }
    }
    assert_eq!(accepted, 2);
    assert_eq!(fab.router_metrics().rejected, 3);

    let mut out = Vec::new();
    drain(&mut fab, &mut out);
    assert_eq!(out.len(), 2);
    let fleet = fab.fleet_metrics();
    assert_eq!(out.len() as u64 + fleet.rejected, 5,
               "accounting must cover every submit");
    assert_eq!(fleet.requests_done, 2);
}

// ---- token streaming --------------------------------------------

#[test]
fn token_stream_events_match_final_responses() {
    let sim_cfg = SimConfig::default();
    let spec = WorkloadSpec::new(
        Scenario::Steady { rate: 200.0 }, 40, 5, sim_cfg.vocab,
        sim_cfg.max_seq,
    );
    let trace = workload::generate(&spec);
    let arrivals: BTreeMap<u64, f64> =
        trace.iter().map(|t| (t.req.id, t.at)).collect();
    let mut fab = mk_fabric(1, &sim_cfg, 8,
                            RouterConfig::default(), true)
        .expect("fabric builds");
    let (resps, _) = fab.run_trace(trace).expect("trace runs");
    assert_eq!(resps.len(), 40);

    let events = fab.take_stream();
    let total: usize = resps.iter().map(|r| r.tokens.len()).sum();
    assert_eq!(events.len(), total,
               "one stream event per sampled token");
    let mut per_id: BTreeMap<u64, Vec<(f64, i32, usize)>> =
        BTreeMap::new();
    for ev in &events {
        per_id.entry(ev.id).or_default()
            .push((ev.t, ev.token, ev.replica));
    }
    for r in &resps {
        let evs = per_id.get(&r.id).expect("request streamed");
        let toks: Vec<i32> = evs.iter().map(|e| e.1).collect();
        assert_eq!(toks, r.tokens,
                   "stream diverged from final tokens on {}", r.id);
        assert!(evs.iter().all(|e| e.2 == r.replica));
        // the first event's clock second IS the ttft measurement
        let at = arrivals[&r.id];
        assert_eq!(evs[0].0 - at, r.ttft,
                   "first-token event disagrees with ttft on {}",
                   r.id);
        let mut prev = 0.0;
        for &(t, _, _) in evs {
            assert!(t >= prev, "stream went back in time on {}",
                    r.id);
            prev = t;
        }
    }
}

// ---- fairness and placement -------------------------------------

#[test]
fn tenant_round_robin_is_fair_within_a_tier() {
    // tenant 0 floods the router before tenants 1..3 show up; the
    // very first decode batch must still contain all four tenants
    let sim_cfg = SimConfig::default();
    let mut fab = mk_fabric(1, &sim_cfg, 4,
                            RouterConfig::default(), false)
        .expect("fabric builds");
    for id in 0..8u64 {
        assert!(fab.submit(Request::new(
            id, vec![4, 5], 4, SamplingParams::greedy(),
        )));
    }
    for (id, tenant) in [(100u64, 1u32), (101, 2), (102, 3)] {
        assert!(fab.submit(
            Request::new(id, vec![4, 5], 4,
                         SamplingParams::greedy())
                .with_tenant(tenant),
        ));
    }
    let mut out = Vec::new();
    drain(&mut fab, &mut out);
    assert_eq!(out.len(), 11);
    let mut first: Vec<u32> =
        out[..4].iter().map(|r| r.tenant).collect();
    first.sort_unstable();
    assert_eq!(first, vec![0, 1, 2, 3],
               "tenant 0's flood starved the others");
}

fn replica_map(n: usize) -> BTreeMap<u64, usize> {
    let sim_cfg = SimConfig::tiny();
    let spec = WorkloadSpec::new(
        Scenario::MixedLengths { rate: 10_000.0 }, n, 13,
        sim_cfg.vocab, sim_cfg.max_seq,
    )
    .with_tenants(TENANTS);
    let trace = workload::generate(&spec);
    let mut fab = mk_fabric(4, &sim_cfg, 8,
                            RouterConfig::default(), false)
        .expect("fabric builds");
    let (resps, _) = fab.run_trace(trace).expect("trace runs");
    assert_eq!(resps.len(), n);
    resps.into_iter().map(|r| (r.id, r.replica)).collect()
}

/// Placement is a pure function of (seed, arrival order): rebuilding
/// the fabric and replaying the identical trace lands every request
/// on the identical replica.
#[test]
fn replica_assignment_is_a_pure_function_of_the_trace() {
    let a = replica_map(1_500);
    let b = replica_map(1_500);
    assert_eq!(a, b, "replica placement is not reproducible");
    let used: BTreeSet<usize> = a.values().copied().collect();
    assert!(used.len() >= 2, "the fleet never spread out: {used:?}");
}
