//! Serving stress suite: thousands of simulated requests through the
//! REAL `Scheduler`/serve loops via the deterministic `SimBackend` on a
//! `VirtualClock`. No artifact bundle, no skips — this is the
//! always-on counterpart of `engine_integration.rs` (which needs the
//! PJRT bundle and skips without it).
//!
//! Covered here: slot accounting, FIFO admission, batch occupancy,
//! determinism across reruns (byte-identical token streams), early-EOS
//! chat behaviour, long-prompt truncation, and percentile latency
//! under the virtual clock.

use std::collections::HashSet;
use std::rc::Rc;

use exaq_repro::coordinator::{serve_trace, workload, Request, Response,
                              Scenario, ServeConfig, WorkloadSpec};
use exaq_repro::model::SamplingParams;
use exaq_repro::runtime::{QuantMode, SimBackend, SimConfig};
use exaq_repro::util::clock::VirtualClock;

fn serve_cfg(decode_batch: usize) -> ServeConfig {
    ServeConfig {
        model: "sim".into(),
        quant: QuantMode::None,
        c_vec: None,
        decode_batch,
    }
}

/// Run one scenario end to end on a fresh backend + virtual clock.
fn run(scenario: Scenario, n: usize, workload_seed: u64, eos_bias: f64,
       decode_batch: usize)
       -> (Vec<Response>, f64, exaq_repro::coordinator::Scheduler) {
    let clock = Rc::new(VirtualClock::new());
    let sim_cfg = SimConfig { eos_bias, ..SimConfig::default() };
    let spec = WorkloadSpec::new(scenario, n, workload_seed,
                                 sim_cfg.vocab, sim_cfg.max_seq);
    let mut sim = SimBackend::new(sim_cfg, clock.clone());
    let trace = workload::generate(&spec);
    serve_trace(&mut sim, &serve_cfg(decode_batch), trace, clock)
        .expect("serve_trace must not fail")
}

#[test]
fn steady_thousand_requests_complete_with_clean_accounting() {
    let n = 1000;
    let (resps, wall, sched) =
        run(Scenario::Steady { rate: 500.0 }, n, 11, 0.0, 8);

    assert_eq!(resps.len(), n, "every request must complete");
    let ids: HashSet<u64> = resps.iter().map(|r| r.id).collect();
    assert_eq!(ids.len(), n, "response ids must be unique");
    for r in &resps {
        assert!(!r.tokens.is_empty(), "request {} got no tokens", r.id);
        assert!(r.tokens.len() <= 17, "request {} overshot", r.id);
        assert!(r.total_latency >= r.ttft, "latency < ttft on {}", r.id);
        assert!(r.ttft > 0.0);
    }
    assert!(wall > 0.0, "virtual time must have advanced");

    let m = sched.metrics();
    assert_eq!(m.requests_in, n as u64);
    assert_eq!(m.requests_done, n as u64);
    assert_eq!(m.prefills, n as u64, "batch-1 prefill per request");
    assert_eq!(m.ttft.count(), n as u64);
    assert_eq!(m.total_latency.count(), n as u64);
    let toks: u64 = resps.iter().map(|r| r.tokens.len() as u64).sum();
    // decode produces every token except each request's first
    assert_eq!(m.decode_tokens, toks - n as u64);

    // slot accounting: pool fully drained, nothing leaked
    assert_eq!(sched.active_count(), 0);
    assert_eq!(sched.pending_count(), 0);
    assert_eq!(sched.pool().in_use(), 0);
    assert_eq!(sched.pool().available(), 8);
}

#[test]
fn same_seed_runs_are_byte_identical() {
    // stochastic EXAQ-sampled workload — the hardest determinism case
    let scenario = Scenario::MixedLengths { rate: 400.0 };
    let (mut a, wall_a, _) = run(scenario, 300, 21, 0.05, 8);
    let (mut b, wall_b, _) = run(scenario, 300, 21, 0.05, 8);
    a.sort_by_key(|r| r.id);
    b.sort_by_key(|r| r.id);
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.tokens, y.tokens,
                   "token stream diverged on request {}", x.id);
        assert_eq!(x.ttft, y.ttft, "ttft diverged on request {}", x.id);
        assert_eq!(x.total_latency, y.total_latency);
    }
    assert_eq!(wall_a, wall_b, "virtual wall time must be exact");

    // a different workload seed must actually change the streams
    let (mut c, _, _) = run(scenario, 300, 22, 0.05, 8);
    c.sort_by_key(|r| r.id);
    assert!(a.iter().zip(&c).any(|(x, y)| x.tokens != y.tokens),
            "different seeds produced identical streams");
}

#[test]
fn burst_admission_is_fifo_and_saturates_the_batch() {
    let n = 128;
    let (mut resps, _, sched) =
        run(Scenario::Burst { n_bursts: 1, gap: 0.0 }, n, 31, 0.0, 8);
    assert_eq!(resps.len(), n);
    resps.sort_by_key(|r| r.id);

    // FIFO admission: all requests arrive at t=0, so first-token times
    // must be non-decreasing in submission order (each simulated
    // prefill strictly advances the clock)
    let mut prev = 0.0;
    for r in &resps {
        assert!(r.ttft >= prev,
                "request {} admitted out of FIFO order: ttft {} < {}",
                r.id, r.ttft, prev);
        prev = r.ttft;
    }

    // with 128 pending and 8 slots, decode must run near-full
    let occ = sched.metrics().mean_occupancy();
    assert!(occ > 5.0, "mean occupancy {occ} too low under burst");
    assert!(occ <= 8.0);
}

#[test]
fn virtual_clock_latency_percentiles_are_coherent() {
    let (_, _, sched) =
        run(Scenario::Burst { n_bursts: 4, gap: 0.05 }, 256, 41, 0.0,
            8);
    for h in [&sched.metrics().ttft, &sched.metrics().total_latency] {
        let mut prev = 0.0;
        for q in [0.1, 0.5, 0.9, 0.99, 1.0] {
            let v = h.quantile(q);
            assert!(v >= prev, "quantile({q}) = {v} < {prev}");
            assert!(v > 0.0, "quantile({q}) must be positive");
            prev = v;
        }
        assert!(h.mean() > 0.0);
        assert!(h.max() >= h.mean());
    }
    // queueing must be visible: the p99 TTFT of a 64-deep burst is far
    // above the unqueued prefill latency (~6 ms simulated)
    assert!(sched.metrics().ttft.quantile(0.99)
            > sched.metrics().ttft.quantile(0.1));
}

#[test]
fn chat_scenario_stops_early_on_eos() {
    let n = 200;
    let (resps, _, _) =
        run(Scenario::ChatEarlyEos { rate: 1000.0 }, n, 51, 0.25, 8);
    assert_eq!(resps.len(), n);
    let budget = 32; // max_seq / 2 from the workload generator
    let eos_ended = resps
        .iter()
        .filter(|r| r.tokens.last() == Some(&2))
        .count();
    assert!(eos_ended > n / 4,
            "only {eos_ended}/{n} chats ended on EOS");
    for r in &resps {
        assert!(r.tokens.len() <= budget);
    }
    let mean_len: f64 = resps.iter().map(|r| r.tokens.len() as f64)
        .sum::<f64>() / n as f64;
    assert!(mean_len < budget as f64 * 0.75,
            "chat turns are not stopping early (mean {mean_len})");
}

#[test]
fn long_prompts_are_truncated_not_crashed() {
    let n = 150;
    let (resps, _, sched) =
        run(Scenario::LongPromptTail { rate: 300.0 }, n, 61, 0.0, 8);
    assert_eq!(resps.len(), n);
    let max_seq = SimConfig::default().max_seq;
    let mut over_context = 0;
    for r in &resps {
        assert!(!r.tokens.is_empty());
        if r.prompt_len >= max_seq - 1 {
            over_context += 1;
            // the KV is full after the clamped prefill: exactly the
            // first sampled token comes back
            assert_eq!(r.tokens.len(), 1,
                       "over-context request {} decoded past the \
                        context", r.id);
        }
    }
    assert!(over_context > 0,
            "workload should contain over-context prompts");
    assert_eq!(sched.pool().in_use(), 0);
}

#[test]
fn sparse_arrivals_idle_the_scheduler_between_requests() {
    let n = 40;
    let rate = 5.0; // one request every 200 simulated ms
    let (resps, wall, sched) =
        run(Scenario::Steady { rate }, n, 71, 0.0, 8);
    assert_eq!(resps.len(), n);
    // the clock must have skipped across the idle gaps
    assert!(wall >= (n - 1) as f64 / rate,
            "wall {wall} shorter than the arrival span");
    // no queueing: every request is prefilled right after it arrives
    let p99 = sched.metrics().ttft.quantile(0.99);
    assert!(p99 < 0.05, "unqueued p99 ttft {p99} too high");
    // and the decode batch stays mostly empty
    let occ = sched.metrics().mean_occupancy();
    assert!(occ < 2.0, "sparse arrivals should not batch up ({occ})");
}

#[test]
fn slot_accounting_holds_on_every_tick() {
    let clock = Rc::new(VirtualClock::new());
    let sim_cfg = SimConfig::default();
    let mut sim = SimBackend::new(sim_cfg, clock.clone());
    let mut sched = exaq_repro::coordinator::Scheduler::new(
        &sim, "sim", QuantMode::None, None, 8, clock.clone())
        .unwrap();
    for id in 0..50u64 {
        sched.submit(Request::new(
            id,
            vec![4 + (id % 13) as i32; 3 + (id % 5) as usize],
            2 + (id % 7) as usize,
            if id % 2 == 0 {
                SamplingParams::greedy()
            } else {
                SamplingParams::exaq(0.9, 2, -4.0)
            },
        ));
    }
    let mut done = 0usize;
    let mut ticks = 0usize;
    while sched.has_work() {
        done += sched.tick(&mut sim).unwrap().len();
        ticks += 1;
        assert!(ticks < 10_000, "scheduler stopped making progress");
        let pool = sched.pool();
        assert_eq!(pool.in_use(), sched.active_count(),
                   "tick {ticks}: pool/active divergence");
        assert_eq!(pool.in_use() + pool.available(), pool.capacity(),
                   "tick {ticks}: slots leaked");
    }
    assert_eq!(done, 50);
    assert_eq!(sched.metrics().requests_done, 50);
}
