//! End-to-end exercise of `repro compare` through the real binary:
//! the exit-code contract (0 pass / 1 regression / 2 broken input)
//! must be identical with and without `--markdown`, and the markdown
//! mode must emit the per-cell table instead of the plain report.

use std::path::PathBuf;
use std::process::Command;

fn fixture_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "exaq-compare-cli-{tag}-{}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).expect("mkdir fixture");
    dir
}

fn write_doc(dir: &PathBuf, name: &str, rows: &str) -> String {
    let body = format!(
        "{{\"bench\":\"attention\",\"meta\":{{}},\
         \"results\":[{rows}]}}"
    );
    let path = dir.join(name);
    std::fs::write(&path, body).expect("write bench doc");
    path.to_string_lossy().into_owned()
}

fn run(args: &[&str]) -> (Option<i32>, String, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(args)
        .env_remove("EXAQ_BENCH_GATE")
        .output()
        .expect("repro compare runs");
    (
        out.status.code(),
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

const BASE_ROW: &str = "{\"rows\":64,\"len\":1024,\"bits\":2,\
                        \"kernel\":\"attend\",\"fused_us\":10.0,\
                        \"streaming_us\":8.0}";
const SLOW_ROW: &str = "{\"rows\":64,\"len\":1024,\"bits\":2,\
                        \"kernel\":\"attend\",\"fused_us\":15.0,\
                        \"streaming_us\":7.0}";

#[test]
fn markdown_flag_swaps_the_report_but_not_the_exit_code() {
    let dir = fixture_dir("swap");
    let base = write_doc(&dir, "base.json", BASE_ROW);
    let slow = write_doc(&dir, "slow.json", SLOW_ROW);

    // plain mode: regression -> exit 1, plain-text report
    let (code, stdout, _) = run(&["compare", &base, &slow]);
    assert_eq!(code, Some(1), "plain gate must fail:\n{stdout}");
    assert!(stdout.contains("REGRESSION"), "plain report:\n{stdout}");
    assert!(!stdout.contains("| cell |"), "no table in plain mode");

    // markdown mode (flag trails the positionals): same exit code,
    // table output with one row per metric and the verdict line
    let (code, stdout, _) =
        run(&["compare", &base, &slow, "--markdown"]);
    assert_eq!(code, Some(1), "markdown gate must fail:\n{stdout}");
    assert!(stdout.contains(
        "| cell | metric | baseline | current | delta | status |"
    ), "missing table header:\n{stdout}");
    assert!(stdout.contains(
        "| rows=64 len=1024 bits=2 kernel=attend | fused_us | \
         10.000 | 15.000 | +50.0% | **REGRESSION** |"
    ), "missing regression row:\n{stdout}");
    assert!(stdout.contains(
        "| rows=64 len=1024 bits=2 kernel=attend | streaming_us | \
         8.000 | 7.000 | -12.5% | faster |"
    ), "missing faster row:\n{stdout}");
    assert!(stdout.contains("verdict: **FAIL**"), "{stdout}");

    // identical documents: exit 0 and a PASS verdict
    let (code, stdout, _) =
        run(&["compare", &base, &base, "--markdown"]);
    assert_eq!(code, Some(0), "identical docs pass:\n{stdout}");
    assert!(stdout.contains("verdict: **PASS**"), "{stdout}");

    // soft gate downgrades the markdown failure to exit 0 too
    // (--markdown goes last: the `--key value` parser would
    // otherwise swallow the next flag as its value)
    let (code, stdout, _) = run(&[
        "compare", &base, &slow, "--gate", "soft", "--markdown",
    ]);
    assert_eq!(code, Some(0), "soft gate passes:\n{stdout}");
    assert!(stdout.contains("verdict: **FAIL**"),
            "soft gate still reports the failure:\n{stdout}");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn broken_inputs_exit_two_in_both_modes() {
    let dir = fixture_dir("broken");
    let base = write_doc(&dir, "base.json", BASE_ROW);
    let bad = dir.join("bad.json");
    std::fs::write(&bad, "{\"bench\":\"attention\"}")
        .expect("write bad doc");
    let bad = bad.to_string_lossy().into_owned();

    for tail in [&[][..], &["--markdown"][..]] {
        let mut args = vec!["compare", base.as_str(), bad.as_str()];
        args.extend_from_slice(tail);
        let (code, stdout, stderr) = run(&args);
        assert_eq!(code, Some(2),
                   "invalid current doc is exit 2 \
                    (args {args:?}):\n{stdout}\n{stderr}");
    }

    // a missing *baseline* passes with a note in either mode — the
    // note path never reaches the renderer, so markdown is a no-op
    let gone = dir.join("nope.json").to_string_lossy().into_owned();
    for tail in [&[][..], &["--markdown"][..]] {
        let mut args = vec!["compare", gone.as_str(), base.as_str()];
        args.extend_from_slice(tail);
        let (code, stdout, _) = run(&args);
        assert_eq!(code, Some(0), "missing baseline passes:\n{stdout}");
        assert!(stdout.contains("nothing to gate against"));
    }

    std::fs::remove_dir_all(&dir).ok();
}
