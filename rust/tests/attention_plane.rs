//! Integration suite for the cache-blocked packed attention plane:
//! a property-style randomized sweep (hand-rolled; the image has no
//! proptest) asserting *bit-exact* agreement between the fused
//! pipeline (`AttentionPlane::attend` — scores stay packed from QK^T
//! through the weighted-value pass) and the two-step reference
//! (quantize -> `softmax_rows` -> dense PV over the f32 plane) across
//! rows / lens / head dims / bit-widths / clips / masks, plus hostile
//! inputs (NaN / ±inf rows, all-clipped rows, zero-length tails),
//! SIMD-level and worker-count invariance with lens straddling the
//! `TILE_LANES` seam, the sampler's packed-plane entry point, the
//! thread-local plane cache, and the packed-footprint accounting.

use exaq_repro::exaq::plane::{dense_plane_bytes, packed_plane_bytes,
                              with_cached_plane, AttentionPlane,
                              TILE_LANES, TILE_ROWS};
use exaq_repro::exaq::simd;
use exaq_repro::exaq::softmax::softmax_algo2_once;
use exaq_repro::model::sampling::BatchSampler;
use exaq_repro::util::rng::SplitMix64;

fn random(n: usize, seed: u64, scale: f32) -> Vec<f32> {
    let mut r = SplitMix64::new(seed);
    (0..n).map(|_| (r.normal() as f32) * scale).collect()
}

fn assert_bits_equal(a: &[f32], b: &[f32], tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(),
                   "{tag}: lane {i}: {x} vs {y}");
    }
}

/// Plain-loop reference: scalar Algorithm-2 softmax per row, then the
/// canonical `out[j] += p * v[j]` triple loop — no SIMD, no tiling,
/// no packing.
fn reference(scores: &[f32], rows: usize, len: usize,
             valid_lens: &[usize], values: &[f32], d: usize,
             bits: u32, clip: f32) -> Vec<f32> {
    let mut probs = scores.to_vec();
    let mut out = vec![0.0f32; rows * d];
    for r in 0..rows {
        let n = if valid_lens.is_empty() {
            len
        } else {
            valid_lens[r].min(len)
        };
        if n == 0 {
            continue;
        }
        let row = &mut probs[r * len..(r + 1) * len];
        softmax_algo2_once(row, n, bits, clip);
        for k in 0..n {
            let p = row[k];
            for j in 0..d {
                out[r * d + j] += p * values[k * d + j];
            }
        }
    }
    out
}

#[test]
fn randomized_sweep_fused_matches_two_step_and_reference() {
    // 120 random configurations: rows 0..10, len 1..300 (often not a
    // multiple of the packing group), d_head 1..40, hostile
    // valid_lens (0, > len), bits 1-5, random clips and scales —
    // every output lane must match bit-for-bit
    let mut meta = SplitMix64::new(0xA77E);
    let mut planes: Vec<AttentionPlane> = Vec::new();
    for trial in 0..120 {
        let rows = meta.below(10);
        let len = 1 + meta.below(300);
        let d = 1 + meta.below(40);
        let bits = 1 + meta.below(5) as u32;
        let clip = -1.0 - (meta.uniform() as f32) * 6.0;
        let scale = 0.5 + (meta.uniform() as f32) * 3.0;
        let valid_lens: Vec<usize> = match meta.below(3) {
            0 => Vec::new(), // empty = full rows
            1 => (0..rows).map(|_| meta.below(len + 1)).collect(),
            _ => (0..rows)
                .map(|_| meta.below(2 * len + 8)) // often > len
                .collect(),
        };
        let scores = random(rows * len, 0x5EED + trial, scale);
        let values = random(len * d, 0xFEED + trial, 1.0);

        // reuse planes across trials the way serving does, to also
        // exercise packed-plane scratch reuse at changing shapes
        let plane = match planes
            .iter_mut()
            .position(|p| p.matches(bits, clip))
        {
            Some(i) => &mut planes[i],
            None => {
                planes.push(AttentionPlane::new(bits, clip));
                planes.last_mut().expect("just pushed")
            }
        };
        let tag = format!(
            "trial {trial}: rows={rows} len={len} d={d} bits={bits}");
        let mut fused = vec![0.0f32; rows * d];
        plane.attend(&scores, rows, len, &valid_lens, &values, d,
                     &mut fused);
        let mut two = vec![0.0f32; rows * d];
        plane.attend_two_step(&scores, rows, len, &valid_lens,
                              &values, d, &mut two);
        assert_bits_equal(&fused, &two, &format!("{tag} (two-step)"));
        let want = reference(&scores, rows, len, &valid_lens, &values,
                             d, bits, clip);
        assert_bits_equal(&fused, &want, &format!("{tag} (reference)"));
    }
}

#[test]
fn simd_levels_and_workers_are_invariant_across_tile_seams() {
    // lens straddling the TILE_LANES seam and the packing-group tail,
    // at every available lane level and worker counts {1, 2, 7, auto}:
    // all outputs must be bit-identical to scalar/one-worker
    let lens = [TILE_LANES - 1, TILE_LANES, TILE_LANES + 1,
                TILE_LANES + 2, 2 * TILE_LANES + 3, 5, 1];
    let rows = TILE_ROWS + 3; // one full row block plus a partial one
    let d = 9; // off the 4/8-lane SIMD widths, exercises axpy tails
    for bits in [2u32, 3, 4] {
        for (li, &len) in lens.iter().enumerate() {
            let scores = random(rows * len, 31 + li as u64, 2.0);
            let values = random(len * d, 67 + li as u64, 1.0);
            let vlens: Vec<usize> =
                (0..rows).map(|r| (r * len).div_ceil(rows)).collect();
            let mut want = vec![0.0f32; rows * d];
            let mut plane = AttentionPlane::new(bits, -4.0);
            plane.set_simd_level(simd::Level::Scalar).set_threads(1);
            plane.attend(&scores, rows, len, &vlens, &values, d,
                         &mut want);
            for level in simd::available_levels() {
                for workers in [1usize, 2, 7, 0] {
                    let mut got = vec![0.0f32; rows * d];
                    plane.set_simd_level(level).set_threads(workers);
                    plane.attend(&scores, rows, len, &vlens, &values,
                                 d, &mut got);
                    assert_bits_equal(
                        &got, &want,
                        &format!("bits={bits} len={len} \
                                  level={} workers={workers}",
                                 level.name()));
                }
            }
        }
    }
}

#[test]
fn hostile_planes_stay_bit_stable() {
    // NaN rows, +inf rows, all--inf (fully clipped) rows, and a row
    // masked to zero length: fused and two-step must still agree
    // bit-for-bit, and unmasked-lane outputs must stay finite
    let (rows, len, d) = (5usize, 67usize, 7usize);
    let mut scores = random(rows * len, 13, 2.0);
    scores[3] = f32::NAN;
    for x in &mut scores[len..2 * len] {
        *x = f32::INFINITY;
    }
    for x in &mut scores[2 * len..3 * len] {
        *x = f32::NEG_INFINITY;
    }
    let values = random(len * d, 14, 1.0);
    let vlens = [len, len, len, 0, 19];
    for bits in [1u32, 2, 3, 4] {
        let mut plane = AttentionPlane::new(bits, -5.0);
        let mut fused = vec![0.0f32; rows * d];
        plane.attend(&scores, rows, len, &vlens, &values, d,
                     &mut fused);
        let mut two = vec![0.0f32; rows * d];
        plane.attend_two_step(&scores, rows, len, &vlens, &values, d,
                              &mut two);
        assert_bits_equal(&fused, &two, &format!("M={bits}"));
        // the masked row is exactly zero
        assert!(fused[3 * d..4 * d].iter().all(|&x| x == 0.0),
                "masked row leaked at M={bits}");
        // rows 2 (all clipped) and 4 (short mask) stay finite
        for &i in &[2usize, 4] {
            for (j, x) in fused[i * d..(i + 1) * d].iter().enumerate()
            {
                assert!(x.is_finite(),
                        "M={bits} row {i} lane {j} = {x}");
            }
        }
    }
}

#[test]
fn zero_length_tails_and_empty_planes_are_no_ops() {
    let mut plane = AttentionPlane::new(2, -4.0);
    let mut out: Vec<f32> = Vec::new();
    plane.attend(&[], 0, 0, &[], &[], 0, &mut out);
    plane.attend_two_step(&[], 0, 0, &[], &[], 0, &mut out);
    // len == 0 with live rows: out comes back zeroed, not stale
    let mut out = vec![9.0f32; 4 * 3];
    plane.attend(&[], 4, 0, &[], &[], 3, &mut out);
    assert!(out.iter().all(|&x| x == 0.0));
    // d_head == 0 is a no-op on an empty out
    let scores = random(4 * 8, 1, 1.0);
    let mut empty: Vec<f32> = Vec::new();
    plane.attend(&scores, 4, 8, &[], &[], 0, &mut empty);
}

#[test]
fn sampler_entry_and_cached_plane_agree_with_direct_use() {
    let (rows, len, d) = (6usize, 129usize, 8usize);
    let scores = random(rows * len, 91, 2.0);
    let values = random(len * d, 92, 1.0);
    let vlens: Vec<usize> = (0..rows).map(|r| r * 25 + 1).collect();
    for bits in [2u32, 3, 4] {
        let mut want = vec![0.0f32; rows * d];
        AttentionPlane::new(bits, -4.5).attend(
            &scores, rows, len, &vlens, &values, d, &mut want);

        let mut sampler_out = vec![0.0f32; rows * d];
        let mut sampler = BatchSampler::default();
        sampler.attend_rows(&scores, rows, len, &vlens, &values, d,
                            bits, -4.5, &mut sampler_out);
        assert_bits_equal(&sampler_out, &want,
                          &format!("sampler M={bits}"));

        let mut cached_out = vec![0.0f32; rows * d];
        with_cached_plane(bits, -4.5, |p| {
            p.attend(&scores, rows, len, &vlens, &values, d,
                     &mut cached_out);
        });
        assert_bits_equal(&cached_out, &want,
                          &format!("cached M={bits}"));
    }
}

#[test]
fn packed_footprint_is_honest_for_both_key_widths() {
    // M = 2 packs 4 codes/byte; M = 3/4 pack 2 codes per u16; the
    // live plane must report exactly what the layout helper predicts,
    // and always less than the dense f32 plane it replaces
    for (rows, len) in [(1usize, 1usize), (4, 64), (7, 129),
                        (16, 2048)] {
        for bits in [1u32, 2, 3, 4, 5] {
            let scores = random(rows * len, 3, 1.0);
            let values = random(len * 4, 4, 1.0);
            let mut plane = AttentionPlane::new(bits, -4.0);
            let mut out = vec![0.0f32; rows * 4];
            plane.attend(&scores, rows, len, &[], &values, 4,
                         &mut out);
            let predicted = packed_plane_bytes(rows, len, bits);
            assert_eq!(plane.plane_bytes(), predicted,
                       "rows={rows} len={len} bits={bits}");
            if len >= 8 {
                assert!(predicted < dense_plane_bytes(rows, len),
                        "rows={rows} len={len} bits={bits}: packed \
                         {predicted} not below dense");
            }
        }
    }
    // exact layout pins
    assert_eq!(packed_plane_bytes(4, 64, 2), 4 * 16); // 4 codes/byte
    assert_eq!(packed_plane_bytes(4, 64, 3), 4 * 32 * 2); // 2/u16
    assert_eq!(packed_plane_bytes(4, 64, 4), 4 * 32 * 2);
    assert_eq!(packed_plane_bytes(1, 5, 2), 2); // tail group rounds up
}
