//! Integration suite for the streaming one-pass attention kernel
//! (`StreamingAttention`): a hand-rolled randomized sweep asserting
//! *bit-exact* agreement with the fused packed plane
//! (`AttentionPlane::attend`) across rows / lens / head dims /
//! bit-widths / clips / masks, SIMD-level and worker-count invariance
//! with lens straddling the `TILE_LANES` seam (len % TILE_LANES in
//! {0, 1, group-1} included by construction), the fused-QK^T front
//! against the caller-materialized-scores front, hostile inputs
//! (NaN / ±inf rows, all-clipped rows, zero-length tails), the
//! sampler's streaming entry point, and the O(1) peak-score-memory
//! accounting. Mirrors `rust/tests/attention_plane.rs` — the
//! streaming kernel inherits the exact same contract, minus the
//! dense plane.

use exaq_repro::exaq::footprint::{dense_plane_bytes,
                                  packed_plane_bytes,
                                  streaming_strip_bytes};
use exaq_repro::exaq::plane::{AttentionPlane, TILE_LANES, TILE_ROWS};
use exaq_repro::exaq::simd;
use exaq_repro::exaq::stream::StreamingAttention;
use exaq_repro::model::sampling::BatchSampler;
use exaq_repro::util::rng::SplitMix64;

fn random(n: usize, seed: u64, scale: f32) -> Vec<f32> {
    let mut r = SplitMix64::new(seed);
    (0..n).map(|_| (r.normal() as f32) * scale).collect()
}

fn assert_bits_equal(a: &[f32], b: &[f32], tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(),
                   "{tag}: lane {i}: {x} vs {y}");
    }
}

#[test]
fn randomized_sweep_streaming_matches_fused_bitwise() {
    // 120 random configurations: rows 0..10, len 1..300 (often not a
    // multiple of the packing group or the tile width), d_head 1..40,
    // hostile valid_lens (0, > len), bits 1-5, random clips and
    // scales — the streaming kernel must reproduce the fused plane
    // bit-for-bit while never holding more than one score strip
    let mut meta = SplitMix64::new(0xA77E);
    let mut streams: Vec<StreamingAttention> = Vec::new();
    for trial in 0..120 {
        let rows = meta.below(10);
        let len = 1 + meta.below(300);
        let d = 1 + meta.below(40);
        let bits = 1 + meta.below(5) as u32;
        let clip = -1.0 - (meta.uniform() as f32) * 6.0;
        let scale = 0.5 + (meta.uniform() as f32) * 3.0;
        let valid_lens: Vec<usize> = match meta.below(3) {
            0 => Vec::new(), // empty = full rows
            1 => (0..rows).map(|_| meta.below(len + 1)).collect(),
            _ => (0..rows)
                .map(|_| meta.below(2 * len + 8)) // often > len
                .collect(),
        };
        let scores = random(rows * len, 0x5EED + trial, scale);
        let values = random(len * d, 0xFEED + trial, 1.0);

        // reuse kernels across trials the way serving does, to also
        // exercise packed-scratch reuse at changing shapes
        let stream = match streams
            .iter_mut()
            .position(|s| s.matches(bits, clip))
        {
            Some(i) => &mut streams[i],
            None => {
                streams.push(StreamingAttention::new(bits, clip));
                streams.last_mut().expect("just pushed")
            }
        };
        let tag = format!(
            "trial {trial}: rows={rows} len={len} d={d} bits={bits}");
        let mut want = vec![0.0f32; rows * d];
        AttentionPlane::new(bits, clip).attend(
            &scores, rows, len, &valid_lens, &values, d, &mut want);
        let mut got = vec![0.0f32; rows * d];
        stream.attend_scores(&scores, rows, len, &valid_lens, &values,
                             d, &mut got);
        assert_bits_equal(&got, &want, &tag);
        // packed scratch stays in lockstep with the fused layout
        assert_eq!(stream.plane_bytes(),
                   packed_plane_bytes(rows, len, bits), "{tag}");
    }
}

#[test]
fn simd_levels_and_workers_are_invariant_across_tile_seams() {
    // lens straddling the TILE_LANES seam and the packing-group tail
    // (len % TILE_LANES covers 0, 1, 2, TILE_LANES - 1, 3, 5, 1), at
    // every available lane level and worker counts {1, 2, 7, auto}:
    // every output must be bit-identical to the fused plane at
    // scalar / one worker
    let lens = [TILE_LANES - 1, TILE_LANES, TILE_LANES + 1,
                TILE_LANES + 2, 2 * TILE_LANES + 3, 5, 1];
    let rows = TILE_ROWS + 3; // one full row block plus a partial one
    let d = 9; // off the 4/8-lane SIMD widths, exercises axpy tails
    for bits in [2u32, 3, 4] {
        for (li, &len) in lens.iter().enumerate() {
            let scores = random(rows * len, 31 + li as u64, 2.0);
            let values = random(len * d, 67 + li as u64, 1.0);
            let vlens: Vec<usize> =
                (0..rows).map(|r| (r * len).div_ceil(rows)).collect();
            let mut want = vec![0.0f32; rows * d];
            let mut plane = AttentionPlane::new(bits, -4.0);
            plane.set_simd_level(simd::Level::Scalar).set_threads(1);
            plane.attend(&scores, rows, len, &vlens, &values, d,
                         &mut want);
            let mut stream = StreamingAttention::new(bits, -4.0);
            for level in simd::available_levels() {
                for workers in [1usize, 2, 7, 0] {
                    let mut got = vec![0.0f32; rows * d];
                    stream.set_simd_level(level).set_threads(workers);
                    stream.attend_scores(&scores, rows, len, &vlens,
                                         &values, d, &mut got);
                    assert_bits_equal(
                        &got, &want,
                        &format!("bits={bits} len={len} \
                                  level={} workers={workers}",
                                 level.name()));
                }
            }
        }
    }
}

#[test]
fn qkv_front_matches_the_scores_front_at_every_level() {
    // the fused QK^T front must agree bit-for-bit with feeding the
    // same kernel a caller-materialized score plane, and hence with
    // the fused packed plane — at every lane level and across the
    // tile seam
    let (rows, d) = (TILE_ROWS + 1, 13usize);
    for (li, &len) in
        [TILE_LANES + 5, TILE_LANES, 39, 1].iter().enumerate()
    {
        let q = random(rows * d, 0x0_51 + li as u64, 1.0);
        let k = random(len * d, 0x0_52 + li as u64, 1.0);
        let values = random(len * d, 0x0_53 + li as u64, 1.0);
        let scale = 1.0 / (d as f32).sqrt();
        // qk_strip is bit-identical across levels by construction,
        // so one scalar-derived plane serves as the reference input
        let mut scores = vec![0.0f32; rows * len];
        for (r, row) in scores.chunks_exact_mut(len).enumerate() {
            simd::qk_strip(simd::Level::Scalar,
                           &q[r * d..(r + 1) * d], &k, d, scale, row);
        }
        let vlens: Vec<usize> =
            (0..rows).map(|r| (r * len).div_ceil(rows) + 1).collect();
        for bits in [2u32, 3, 4] {
            let mut want = vec![0.0f32; rows * d];
            AttentionPlane::new(bits, -4.5).attend(
                &scores, rows, len, &vlens, &values, d, &mut want);
            let mut stream = StreamingAttention::new(bits, -4.5);
            for level in simd::available_levels() {
                stream.set_simd_level(level).set_threads(1);
                let mut qkv = vec![0.0f32; rows * d];
                stream.attend(&q, rows, len, &vlens, &k, &values, d,
                              scale, &mut qkv);
                assert_bits_equal(
                    &qkv, &want,
                    &format!("qkv bits={bits} len={len} level={}",
                             level.name()));
                let mut via_scores = vec![0.0f32; rows * d];
                stream.attend_scores(&scores, rows, len, &vlens,
                                     &values, d, &mut via_scores);
                assert_bits_equal(
                    &via_scores, &qkv,
                    &format!("fronts bits={bits} len={len} level={}",
                             level.name()));
            }
        }
    }
}

#[test]
fn hostile_streams_stay_bit_stable() {
    // NaN lanes, +inf rows, all--inf (fully clipped) rows, and a row
    // masked to zero length: streaming and fused must still agree
    // bit-for-bit, and unmasked-lane outputs must stay finite
    let (rows, len, d) = (5usize, 67usize, 7usize);
    let mut scores = random(rows * len, 13, 2.0);
    scores[3] = f32::NAN;
    for x in &mut scores[len..2 * len] {
        *x = f32::INFINITY;
    }
    for x in &mut scores[2 * len..3 * len] {
        *x = f32::NEG_INFINITY;
    }
    let values = random(len * d, 14, 1.0);
    let vlens = [len, len, len, 0, 19];
    for bits in [1u32, 2, 3, 4] {
        let mut want = vec![0.0f32; rows * d];
        AttentionPlane::new(bits, -5.0).attend(
            &scores, rows, len, &vlens, &values, d, &mut want);
        let mut got = vec![0.0f32; rows * d];
        StreamingAttention::new(bits, -5.0).attend_scores(
            &scores, rows, len, &vlens, &values, d, &mut got);
        assert_bits_equal(&got, &want, &format!("M={bits}"));
        // the masked row is exactly zero
        assert!(got[3 * d..4 * d].iter().all(|&x| x == 0.0),
                "masked row leaked at M={bits}");
        // rows 2 (all clipped) and 4 (short mask) stay finite
        for &i in &[2usize, 4] {
            for (j, x) in got[i * d..(i + 1) * d].iter().enumerate() {
                assert!(x.is_finite(),
                        "M={bits} row {i} lane {j} = {x}");
            }
        }
    }
}

#[test]
fn zero_length_tails_and_empty_streams_are_no_ops() {
    let mut stream = StreamingAttention::new(2, -4.0);
    let mut out: Vec<f32> = Vec::new();
    stream.attend_scores(&[], 0, 0, &[], &[], 0, &mut out);
    stream.attend(&[], 0, 0, &[], &[], &[], 0, 1.0, &mut out);
    // len == 0 with live rows: out comes back zeroed, not stale
    let mut out = vec![9.0f32; 4 * 3];
    stream.attend_scores(&[], 4, 0, &[], &[], 3, &mut out);
    assert!(out.iter().all(|&x| x == 0.0));
    let mut out = vec![9.0f32; 4 * 3];
    stream.attend(&random(4 * 3, 2, 1.0), 4, 0, &[], &[], &[], 3,
                  1.0, &mut out);
    assert!(out.iter().all(|&x| x == 0.0));
    // d_head == 0 is a no-op on an empty out
    let scores = random(4 * 8, 1, 1.0);
    let mut empty: Vec<f32> = Vec::new();
    stream.attend_scores(&scores, 4, 8, &[], &[], 0, &mut empty);
}

#[test]
fn sampler_streaming_entry_agrees_with_direct_use() {
    let (rows, len, d) = (6usize, 129usize, 8usize);
    let scores = random(rows * len, 91, 2.0);
    let values = random(len * d, 92, 1.0);
    let vlens: Vec<usize> = (0..rows).map(|r| r * 25 + 1).collect();
    for bits in [2u32, 3, 4] {
        let mut want = vec![0.0f32; rows * d];
        StreamingAttention::new(bits, -4.5).attend_scores(
            &scores, rows, len, &vlens, &values, d, &mut want);
        let mut sampler_out = vec![0.0f32; rows * d];
        let mut sampler = BatchSampler::default();
        sampler.attend_streaming(&scores, rows, len, &vlens, &values,
                                 d, bits, -4.5, &mut sampler_out);
        assert_bits_equal(&sampler_out, &want,
                          &format!("sampler M={bits}"));
    }
}

#[test]
fn peak_score_memory_is_one_strip_at_every_len() {
    // the headline claim, pinned as an accounting contract: the
    // streaming path's peak f32 score scratch is TILE_ROWS x
    // TILE_LANES x 4 bytes — a constant — while the dense plane the
    // two-step path writes grows linearly with len
    assert_eq!(streaming_strip_bytes(), TILE_ROWS * TILE_LANES * 4);
    for len in [TILE_LANES, 1024, 4096, 65_536] {
        assert!(streaming_strip_bytes()
                <= dense_plane_bytes(TILE_ROWS, len),
                "strip must not exceed the dense plane at len={len}");
    }
    // and the packed key scratch matches the fused plane exactly
    let (rows, len, d) = (TILE_ROWS, 2 * TILE_LANES + 7, 4usize);
    let scores = random(rows * len, 3, 1.0);
    let values = random(len * d, 4, 1.0);
    for bits in [2u32, 3, 4] {
        let mut stream = StreamingAttention::new(bits, -4.0);
        let mut out = vec![0.0f32; rows * d];
        stream.attend_scores(&scores, rows, len, &[], &values, d,
                             &mut out);
        assert_eq!(stream.plane_bytes(),
                   packed_plane_bytes(rows, len, bits),
                   "bits={bits}");
    }
}
