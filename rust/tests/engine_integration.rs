//! End-to-end runtime tests: AOT bundle -> PJRT -> numerics vs the JAX
//! golden outputs (`artifacts/golden_s.json`, written by
//! `python -m compile.golden`).
//!
//! These tests are skipped (not failed) when the artifact bundle has not
//! been built — run `make artifacts` first for full coverage.

use std::path::{Path, PathBuf};

use exaq_repro::runtime::{Engine, HostTensor, QuantMode};
use exaq_repro::util::json::Json;

fn artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_bundle() -> bool {
    artifacts_dir().join("manifest.json").exists()
        && artifacts_dir().join("golden_s.json").exists()
}

fn load_golden() -> Json {
    let text =
        std::fs::read_to_string(artifacts_dir().join("golden_s.json"))
            .unwrap();
    Json::parse(&text).unwrap()
}

fn max_abs_diff(a: &[f32], b: &[f64]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (*x as f64 - y).abs())
        .fold(0.0, f64::max)
}

#[test]
fn prefill_matches_jax_golden_none_and_q2() {
    if !have_bundle() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let golden = load_golden();
    let mut engine = Engine::load(&artifacts_dir()).unwrap();
    let toks: Vec<i32> = golden.get("tokens").unwrap().as_f64_vec()
        .unwrap().iter().map(|&x| x as i32).collect();
    let seq = toks.len();
    let tokens = HostTensor::i32(toks.clone(), &[1, seq]);

    // NONE
    let (logits, state) =
        engine.prefill("s", QuantMode::None, &tokens, None).unwrap();
    assert_eq!(logits.shape[0], 1);
    assert_eq!(logits.shape[1], seq);
    let v = logits.shape[2];
    let want = golden.get("logits_none_last").unwrap().as_f64_vec()
        .unwrap();
    let last = &logits.as_f32().unwrap()[(seq - 1) * v..seq * v];
    let d = max_abs_diff(last, &want);
    assert!(d < 1e-3, "NONE prefill logits drift {d}");
    // KV caches came back with the right shape
    assert_eq!(state.kc.shape.len(), 5);
    assert_eq!(state.kc.shape[3], seq);

    // static 2-bit with the golden clip vector
    let c_vec: Vec<f32> = golden.get("c_vec").unwrap().as_f64_vec()
        .unwrap().iter().map(|&x| x as f32).collect();
    let (lq, _) = engine
        .prefill("s", QuantMode::Static { bits: 2 }, &tokens,
                 Some(&c_vec))
        .unwrap();
    let want_q = golden.get("logits_q2_last").unwrap().as_f64_vec()
        .unwrap();
    let last_q = &lq.as_f32().unwrap()[(seq - 1) * v..seq * v];
    let dq = max_abs_diff(last_q, &want_q);
    assert!(dq < 1e-3, "q2 prefill logits drift {dq}");

    // quantization actually changed the numbers (not a no-op path)
    let d_none_vs_q = max_abs_diff(last_q, &want);
    assert!(d_none_vs_q > 1e-4, "q2 path identical to NONE?");
}

#[test]
fn decode_step_matches_jax_golden() {
    if !have_bundle() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let golden = load_golden();
    let mut engine = Engine::load(&artifacts_dir()).unwrap();
    let toks: Vec<i32> = golden.get("tokens").unwrap().as_f64_vec()
        .unwrap().iter().map(|&x| x as i32).collect();
    let pos = golden.get("decode_pos").unwrap().as_usize().unwrap();

    // prefill the first `pos` tokens at batch 1
    let prompt = HostTensor::i32(toks[..pos].to_vec(), &[1, pos])
        // prefill artifacts are fixed at seq=64: pad with PAD (0); the
        // causal mask makes the tail irrelevant for positions < pos.
        ;
    let mut padded = toks[..pos].to_vec();
    padded.resize(64, engine.manifest.pad as i32);
    let tokens = HostTensor::i32(padded, &[1, 64]);
    drop(prompt);

    let (_, mut state) =
        engine.prefill("s", QuantMode::None, &tokens, None).unwrap();
    // zero out cache rows >= pos (they hold garbage from PAD positions;
    // decode only attends to < pos+1 so only position `pos` write
    // matters, but keep the fixture exact).
    let ld = engine
        .decode("s", QuantMode::None, &[toks[pos]], &[pos as i32],
                &mut state, None)
        .unwrap();
    let want = golden.get("logits_decode32").unwrap().as_f64_vec()
        .unwrap();
    let d = max_abs_diff(ld.as_f32().unwrap(), &want);
    assert!(d < 1e-3, "decode logits drift {d}");
}

#[test]
fn decode_chain_matches_full_prefill() {
    if !have_bundle() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    // prefill(t[0..48]) then decode t[48], t[49] should equal the
    // logits of prefill(t[0..51]) at position 50.
    let golden = load_golden();
    let mut engine = Engine::load(&artifacts_dir()).unwrap();
    let toks: Vec<i32> = golden.get("tokens").unwrap().as_f64_vec()
        .unwrap().iter().map(|&x| x as i32).collect();

    let mut padded = toks[..48].to_vec();
    padded.resize(64, engine.manifest.pad as i32);
    let tokens = HostTensor::i32(padded, &[1, 64]);
    let (_, mut state) =
        engine.prefill("s", QuantMode::None, &tokens, None).unwrap();
    let _ = engine
        .decode("s", QuantMode::None, &[toks[48]], &[48], &mut state,
                None)
        .unwrap();
    let l2 = engine
        .decode("s", QuantMode::None, &[toks[49]], &[49], &mut state,
                None)
        .unwrap();

    let full = HostTensor::i32(toks.clone(), &[1, 64]);
    let (lf, _) =
        engine.prefill("s", QuantMode::None, &full, None).unwrap();
    let v = lf.shape[2];
    let want = &lf.as_f32().unwrap()[49 * v..50 * v];
    let got = l2.as_f32().unwrap();
    let d = got
        .iter()
        .zip(want)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(d < 1e-3, "decode chain drift {d}");
}

#[test]
fn calibration_stats_artifact_runs() {
    if !have_bundle() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut engine = Engine::load(&artifacts_dir()).unwrap();
    let tokens = HostTensor::i32(vec![1; 4 * 64], &[4, 64]);
    let (logits, stats) =
        engine.prefill_stats("s", &tokens, &[64, 64, 64, 64]).unwrap();
    assert_eq!(logits.shape, vec![4, 64, engine.manifest.vocab.len()]);
    assert_eq!(stats.shape[1], 4);
    let s = stats.as_f32().unwrap();
    // count > 0, min <= 0, M2 >= 0 per layer
    for row in s.chunks(4) {
        assert!(row[0] > 0.0);
        assert!(row[2] >= 0.0);
        assert!(row[3] <= 0.0);
    }
}
