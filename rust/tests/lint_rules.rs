//! Fixture suite for the determinism lint (`exaq_repro::lint`): one
//! violating snippet per rule asserting rule name + file:line:col
//! span, `lint:allow` suppression, rule scoping, the real repo tree
//! staying clean, and the `repro lint` CLI exit-code contract
//! (0 clean / 1 violations / 2 internal error).

use std::path::Path;
use std::process::Command;

use exaq_repro::lint::{lint_source, run_tree, Violation, RULES};
use exaq_repro::util::json::Json;

/// Lint one snippet and require exactly one violation.
fn single(rel: &str, src: &str) -> Violation {
    let r = lint_source(rel, src);
    assert_eq!(r.violations.len(), 1, "{rel}: {:?}", r.violations);
    r.violations.into_iter().next().expect("one violation")
}

/// Lint one snippet and require zero violations.
fn clean(rel: &str, src: &str) {
    let r = lint_source(rel, src);
    assert!(r.is_clean(), "{rel}: {:?}", r.violations);
}

// ---- one violating fixture per rule, with spans -----------------

#[test]
fn clock_discipline_flags_raw_instant() {
    let v = single("rust/src/coordinator/workload.rs",
                   "use std::time::Instant;\n");
    assert_eq!(v.rule, "clock-discipline");
    assert_eq!((v.line, v.col), (1, 16));
    let v = single("rust/src/report/mod.rs",
                   "use std::time::SystemTime;\n");
    assert_eq!(v.rule, "clock-discipline");
    assert_eq!((v.line, v.col), (1, 16));
}

#[test]
fn seeded_rng_flags_ambient_randomness() {
    let v = single("rust/src/exaq/quant.rs",
                   "fn f() -> u64 { thread_rng().gen() }\n");
    assert_eq!(v.rule, "seeded-rng");
    assert_eq!((v.line, v.col), (1, 17));
    let v = single("rust/src/eval/world.rs",
                   "fn f() -> u8 { rand::random() }\n");
    assert_eq!(v.rule, "seeded-rng");
    assert_eq!(v.line, 1);
}

#[test]
fn deterministic_iteration_flags_hashmap_in_scope() {
    let v = single("rust/src/runtime/x.rs",
                   "use std::collections::HashMap;\n");
    assert_eq!(v.rule, "deterministic-iteration");
    assert_eq!((v.line, v.col), (1, 23));
    let v = single("rust/src/coordinator/x.rs",
                   "type S = std::collections::HashSet<u32>;\n");
    assert_eq!(v.rule, "deterministic-iteration");
}

#[test]
fn no_panic_hot_path_flags_unwrap_and_macros() {
    let v = single("rust/src/runtime/sim.rs",
                   "pub fn f(x: Option<u32>) -> u32 {\n\
                    \x20   x.unwrap()\n}\n");
    assert_eq!(v.rule, "no-panic-hot-path");
    assert_eq!((v.line, v.col), (2, 7));
    let v = single("rust/src/coordinator/batcher.rs",
                   "fn f(x: Option<u32>) -> u32 {\n\
                    \x20   x.expect(\"boom\")\n}\n");
    assert_eq!(v.rule, "no-panic-hot-path");
    assert_eq!(v.line, 2);
    let v = single("rust/src/exaq/lut.rs",
                   "fn f() {\n    unreachable!()\n}\n");
    assert_eq!(v.rule, "no-panic-hot-path");
    assert_eq!((v.line, v.col), (2, 5));
}

#[test]
fn float_reduction_flags_iterator_sums_and_accumulators() {
    let v = single("rust/src/exaq/batched.rs",
                   "fn d(xs: &[f32]) -> f32 {\n\
                    \x20   xs.iter().sum()\n}\n");
    assert_eq!(v.rule, "float-reduction-discipline");
    assert_eq!((v.line, v.col), (2, 15));
    let v = single("rust/src/exaq/softmax.rs",
                   "fn d(xs: &[f32]) -> f32 {\n\
                    \x20   let mut sum = 0.0f32;\n\
                    \x20   for &x in xs {\n\
                    \x20       sum += x;\n\
                    \x20   }\n\
                    \x20   sum\n}\n");
    assert_eq!(v.rule, "float-reduction-discipline");
    assert_eq!((v.line, v.col), (4, 9));
    let v = single("rust/src/exaq/batched.rs",
                   "fn d(xs: &[f32]) -> f32 {\n\
                    \x20   xs.iter().fold(0.0, |a, b| a + b)\n}\n");
    assert_eq!(v.rule, "float-reduction-discipline");
    assert_eq!(v.line, 2);
}

#[test]
fn thread_discipline_flags_raw_threads_and_arch_gates() {
    let v = single("rust/src/coordinator/batcher.rs",
                   "fn f() { std::thread::spawn(|| {}); }\n");
    assert_eq!(v.rule, "thread-discipline");
    assert_eq!((v.line, v.col), (1, 15));
    let v = single("rust/src/exaq/batched.rs",
                   "fn f() { std::thread::scope(|_| {}); }\n");
    assert_eq!(v.rule, "thread-discipline");
    assert_eq!((v.line, v.col), (1, 15));
    let v = single("rust/src/exaq/lut.rs",
                   "#[cfg(target_arch = \"x86_64\")]\nfn f() {}\n");
    assert_eq!(v.rule, "thread-discipline");
    assert_eq!((v.line, v.col), (1, 7));
    let v = single("rust/src/exaq/quant.rs",
                   "fn f() -> bool { is_x86_feature_detected!(\"avx2\") \
                    }\n");
    assert_eq!(v.rule, "thread-discipline");
    assert_eq!(v.line, 1);
}

#[test]
fn attention_plane_is_inside_the_kernel_scopes() {
    // the fused attention plane is hot-path kernel code: raw thread
    // primitives, arch gates, panics, and ad-hoc float reductions are
    // all flagged there exactly like in the batched kernel
    let v = single("rust/src/exaq/plane.rs",
                   "fn f() { std::thread::scope(|_| {}); }\n");
    assert_eq!(v.rule, "thread-discipline");
    assert_eq!((v.line, v.col), (1, 15));
    let v = single("rust/src/exaq/plane.rs",
                   "#[cfg(target_arch = \"x86_64\")]\nfn f() {}\n");
    assert_eq!(v.rule, "thread-discipline");
    assert_eq!((v.line, v.col), (1, 7));
    let v = single("rust/src/exaq/plane.rs",
                   "fn d(xs: &[f32]) -> f32 {\n\
                    \x20   xs.iter().sum()\n}\n");
    assert_eq!(v.rule, "float-reduction-discipline");
    assert_eq!((v.line, v.col), (2, 15));
    let v = single("rust/src/exaq/plane.rs",
                   "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n");
    assert_eq!(v.rule, "no-panic-hot-path");
    assert_eq!(v.line, 1);
}

#[test]
fn streaming_kernel_is_inside_the_kernel_scopes() {
    // the streaming one-pass kernel carries the same bit-exactness
    // contract as the fused plane, so it sits in the same three
    // scopes: no panics, no ad-hoc float reductions, no raw thread
    // primitives or arch gates
    let v = single("rust/src/exaq/stream.rs",
                   "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n");
    assert_eq!(v.rule, "no-panic-hot-path");
    assert_eq!(v.line, 1);
    let v = single("rust/src/exaq/stream.rs",
                   "fn d(xs: &[f32]) -> f32 {\n\
                    \x20   xs.iter().sum()\n}\n");
    assert_eq!(v.rule, "float-reduction-discipline");
    assert_eq!((v.line, v.col), (2, 15));
    let v = single("rust/src/exaq/stream.rs",
                   "fn f() { std::thread::scope(|_| {}); }\n");
    assert_eq!(v.rule, "thread-discipline");
    assert_eq!((v.line, v.col), (1, 15));
    let v = single("rust/src/exaq/stream.rs",
                   "#[cfg(target_arch = \"x86_64\")]\nfn f() {}\n");
    assert_eq!(v.rule, "thread-discipline");
    assert_eq!((v.line, v.col), (1, 7));
    // the fixed-tree accumulators the kernel actually uses stay legal
    clean("rust/src/exaq/stream.rs",
          "fn d(xs: &[f32; 4]) -> f32 {\n\
           \x20   let a0 = xs[0] + xs[1];\n\
           \x20   let a1 = xs[2] + xs[3];\n\
           \x20   a0 + a1\n}\n");
}

#[test]
fn fabric_router_and_replica_are_hot_path_scoped() {
    // the serving fabric's router + replica layers sit on the decode
    // tick: panics are banned there exactly like in the batcher...
    let v = single("rust/src/coordinator/router.rs",
                   "fn f(x: Option<u32>) -> u32 {\n\
                    \x20   x.unwrap()\n}\n");
    assert_eq!(v.rule, "no-panic-hot-path");
    assert_eq!((v.line, v.col), (2, 7));
    let v = single("rust/src/coordinator/replica.rs",
                   "fn f(x: Option<u32>) -> u32 {\n\
                    \x20   x.expect(\"boom\")\n}\n");
    assert_eq!(v.rule, "no-panic-hot-path");
    assert_eq!(v.line, 2);
    // ...and the coordinator/ prefix scope already bans unordered
    // maps in any new fabric file (replica assignment must iterate
    // deterministically)
    let v = single("rust/src/coordinator/router.rs",
                   "use std::collections::HashMap;\n");
    assert_eq!(v.rule, "deterministic-iteration");
    assert_eq!((v.line, v.col), (1, 23));
    let v = single("rust/src/coordinator/replica.rs",
                   "type S = std::collections::HashSet<u64>;\n");
    assert_eq!(v.rule, "deterministic-iteration");
}

#[test]
fn thread_discipline_spares_the_sanctioned_homes() {
    // util::pool is the one place allowed to spawn scoped threads
    clean("rust/src/util/pool.rs",
          "fn f() { std::thread::scope(|s| { s.spawn(|| {}); }); }\n");
    // exaq::simd owns every cfg(target_arch) lane
    clean("rust/src/exaq/simd.rs",
          "#[cfg(target_arch = \"x86_64\")]\nfn f() {}\n");
    // thread::sleep is not a parallelism primitive (util::clock)
    clean("rust/src/util/clock.rs",
          "fn f() { std::thread::sleep(\
           std::time::Duration::from_millis(1)); }\n");
}

// ---- suppression ------------------------------------------------

#[test]
fn standalone_allow_suppresses_next_code_line() {
    let src = "pub fn f(x: Option<u32>) -> u32 {\n\
               \x20   // lint:allow(no-panic-hot-path): fixture\n\
               \x20   x.unwrap()\n}\n";
    let r = lint_source("rust/src/runtime/sim.rs", src);
    assert!(r.is_clean(), "{:?}", r.violations);
    assert_eq!(r.suppressed, 1);
}

#[test]
fn trailing_allow_suppresses_its_own_line() {
    let src = "fn d(xs: &[f32]) -> f32 {\n\
               \x20   xs.iter().sum() \
               // lint:allow(float-reduction-discipline): fixture\n}\n";
    let r = lint_source("rust/src/exaq/batched.rs", src);
    assert!(r.is_clean(), "{:?}", r.violations);
    assert_eq!(r.suppressed, 1);
}

#[test]
fn allow_for_the_wrong_rule_does_not_suppress() {
    let src = "pub fn f(x: Option<u32>) -> u32 {\n\
               \x20   // lint:allow(clock-discipline): wrong rule\n\
               \x20   x.unwrap()\n}\n";
    let r = lint_source("rust/src/runtime/sim.rs", src);
    assert_eq!(r.violations.len(), 1);
    assert_eq!(r.violations[0].rule, "no-panic-hot-path");
    assert_eq!(r.suppressed, 0);
}

#[test]
fn malformed_and_unknown_allows_are_violations() {
    let v = single("rust/src/util/json.rs",
                   "// lint:allow(no-panic-hot-path)\nfn f() {}\n");
    assert_eq!(v.rule, "lint-allow-syntax");
    assert_eq!(v.line, 1);
    let v = single("rust/src/util/json.rs",
                   "// lint:allow(bogus-rule): whatever\nfn f() {}\n");
    assert_eq!(v.rule, "lint-allow-syntax");
    assert!(v.message.contains("bogus-rule"), "{}", v.message);
}

// ---- scoping ----------------------------------------------------

#[test]
fn rules_stay_inside_their_scopes() {
    // HashMap outside coordinator/runtime/model is fine
    clean("rust/src/eval/world.rs",
          "use std::collections::HashMap;\n");
    // unwrap off the hot path is fine
    clean("rust/src/report/mod.rs",
          "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n");
    // .sum() outside the kernel files is fine
    clean("rust/src/cost/mod.rs",
          "fn d(xs: &[f32]) -> f32 { xs.iter().sum() }\n");
    // util::clock itself may hold Instant; util::rng is exempt
    clean("rust/src/util/clock.rs", "use std::time::Instant;\n");
    clean("rust/src/util/rng.rs",
          "fn f() -> u64 { getrandom() }\n");
}

#[test]
fn test_code_is_exempt() {
    clean("rust/src/runtime/sim.rs",
          "#[cfg(test)]\nmod tests {\n\
           \x20   fn f(x: Option<u32>) -> u32 { x.unwrap() }\n}\n");
    clean("rust/tests/whatever.rs",
          "use std::time::Instant;\n\
           fn f(x: Option<u32>) -> u32 { x.unwrap() }\n");
}

#[test]
fn comments_and_strings_never_trigger_rules() {
    clean("rust/src/runtime/x.rs",
          "// HashMap in a comment\n\
           fn f() -> &'static str { \"Instant::now() unwrap()\" }\n");
}

// ---- the real tree ----------------------------------------------

#[test]
fn repo_tree_is_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let r = run_tree(root).expect("tree lint runs");
    assert!(r.is_clean(), "violations in the repo tree:\n{}",
            r.violations
                .iter()
                .map(|v| v.to_string())
                .collect::<Vec<_>>()
                .join("\n"));
    assert!(r.files >= 30, "only {} files scanned", r.files);
    // the three sanctioned scalar-baseline accumulations in
    // exaq/softmax.rs ride on lint:allow
    assert!(r.suppressed >= 3, "suppressed {}", r.suppressed);
}

#[test]
fn rule_registry_is_complete() {
    let names: Vec<&str> = RULES.iter().map(|r| r.name).collect();
    for expected in ["clock-discipline", "seeded-rng",
                     "deterministic-iteration", "no-panic-hot-path",
                     "float-reduction-discipline",
                     "thread-discipline", "lint-allow-syntax"] {
        assert!(names.contains(&expected), "missing rule {expected}");
    }
}

// ---- CLI exit-code contract -------------------------------------

#[test]
fn cli_exits_zero_on_the_repo_tree() {
    let out = Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(["lint", "--root", env!("CARGO_MANIFEST_DIR")])
        .output()
        .expect("repro lint runs");
    assert_eq!(out.status.code(), Some(0), "stdout: {}\nstderr: {}",
               String::from_utf8_lossy(&out.stdout),
               String::from_utf8_lossy(&out.stderr));
}

#[test]
fn cli_exits_one_with_span_on_a_violating_tree() {
    let tmp = std::env::temp_dir()
        .join(format!("exaq-lint-fixture-{}", std::process::id()));
    let src_dir = tmp.join("rust/src/runtime");
    std::fs::create_dir_all(&src_dir).expect("mkdir fixture");
    std::fs::write(
        src_dir.join("sim.rs"),
        "pub fn f(x: Option<u32>) -> u32 { x.unwrap() }\n",
    ).expect("write fixture");

    let out = Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(["lint", "--root", &tmp.to_string_lossy()])
        .output()
        .expect("repro lint runs");
    assert_eq!(out.status.code(), Some(1));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains(
        "rust/src/runtime/sim.rs:1:37: no-panic-hot-path"),
        "missing named rule + span in:\n{stdout}");

    // --json emits a parseable report through util::json
    let jpath = tmp.join("lint.json");
    let out = Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(["lint", "--root", &tmp.to_string_lossy(), "--json",
               &jpath.to_string_lossy()])
        .output()
        .expect("repro lint runs");
    assert_eq!(out.status.code(), Some(1));
    let body = std::fs::read_to_string(&jpath).expect("json written");
    let j = Json::parse(&body).expect("valid json");
    let vs = j.get("violations").and_then(Json::as_arr)
        .expect("violations array");
    assert_eq!(vs.len(), 1);
    assert_eq!(vs[0].get("rule").and_then(Json::as_str),
               Some("no-panic-hot-path"));
    assert_eq!(vs[0].get("line").and_then(Json::as_f64), Some(1.0));

    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn cli_exits_two_on_a_broken_root() {
    let out = Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(["lint", "--root", "/definitely/not/a/repo"])
        .output()
        .expect("repro lint runs");
    assert_eq!(out.status.code(), Some(2));
}
