//! Cycle-accurate cost model — the accounting substrate for paper Fig. 1
//! (runtime share by layer type) and Table 3 (softmax runtime).
//!
//! The paper measures on Gaudi-2; we reproduce the *accounting structure*
//! with a configurable cycle table (paper §4.1: direct exponent 5–12
//! cycles, LUT access 1 cycle, quantize 3 cycles) plus a simple
//! vector-width/MXU throughput model for the surrounding transformer ops.
//! Absolute numbers are not the target — the claims are ratios (softmax
//! ~39% of BF16 inference, Algo. 2 ≈ 36.9% faster softmax) and those are
//! structural.

/// The one table of machine constants every charge path reads.
///
/// [`CycleTable::default`] and [`MachineModel::default`] are both
/// built from these names — and the runtime's `SimBackend` latency
/// charge-back constructs its model through `MachineModel::default`,
/// so the cost CLI, the benches, and the simulated clock can never
/// quote different machines (ROADMAP: "one shared constants table").
/// Tests pin both paths to this module.
pub mod constants {
    /// Direct exponent, cycles (paper §4.1: 5–12; 8 is the middle).
    pub const EXP_CYCLES: f64 = 8.0;
    /// One LUT access / load-class op, cycles (paper §4.1).
    pub const LUT_CYCLES: f64 = 1.0;
    /// One quantize, cycles (paper §4.1).
    pub const QUANT_CYCLES: f64 = 3.0;
    /// One vector add / MAC-class op, cycles.
    pub const ADD_CYCLES: f64 = 1.0;
    /// One divide, cycles.
    pub const DIV_CYCLES: f64 = 4.0;
    /// MAC/cycle for BF16 matmuls — fitted so LLaMA-2-7B/BF16/Algo-1
    /// reproduces the paper's Fig. 1 shares (~39% softmax, ~24% GEMM).
    pub const MXU_BF16_MACS: f64 = 27_000.0;
    /// MAC/cycle for FP8 matmuls (modern accelerators: 2x BF16).
    pub const MXU_FP8_MACS: f64 = 54_000.0;
    /// Vector lanes per cycle for the softmax cycle program.
    pub const VPU_LANES: f64 = 64.0;
    /// HBM bytes per cycle for memory-bound element-wise ops.
    pub const HBM_BYTES_PER_CYCLE: f64 = 57.0;
}

/// Per-operation cycle costs. Defaults come from the shared
/// [`constants`] table: exp = 8 (mid of 5–12), LUT = 1, quantize = 3,
/// add = 1, div = 4.
#[derive(Clone, Copy, Debug)]
pub struct CycleTable {
    pub exp: f64,
    pub lut: f64,
    pub quant: f64,
    pub add: f64,
    pub div: f64,
}

impl Default for CycleTable {
    fn default() -> Self {
        Self {
            exp: constants::EXP_CYCLES,
            lut: constants::LUT_CYCLES,
            quant: constants::QUANT_CYCLES,
            add: constants::ADD_CYCLES,
            div: constants::DIV_CYCLES,
        }
    }
}

/// Softmax cycle accounting for a row of `n` elements.
impl CycleTable {
    /// Algorithm 1: per-element exp, N accumulations, N divides.
    pub fn algo1_softmax(&self, n: usize) -> f64 {
        let n = n as f64;
        n * self.exp + n * self.add + n * self.div
    }

    /// Algorithm 2 at `bits`: per-element quantize + LUT_exp, N/group
    /// LUT_sum accumulations, N divides. group = 4 at 2 bits, 2 at 3/4
    /// — derived from the same [`crate::exaq::lut::lut_group`] table
    /// the kernels build with (pinned by a test against
    /// [`crate::exaq::BatchSoftmax::group`]).
    pub fn algo2_softmax(&self, n: usize, bits: u32) -> f64 {
        self.algo2_softmax_grouped(n, crate::exaq::lut::lut_group(bits))
    }

    /// [`Self::algo2_softmax`] with an explicit codes-per-key group —
    /// callers holding a live kernel pass `BatchSoftmax::group()` so
    /// the accounting can never drift from the packed layout in use.
    pub fn algo2_softmax_grouped(&self, n: usize, group: usize) -> f64 {
        let group = group as f64;
        let n = n as f64;
        n * self.quant + n * self.lut + (n / group) * self.lut
            + n * self.div
    }

    /// Critical-path cycles of an `[rows × n]` Algo-1 plane split over
    /// `threads` deterministic row-pool workers: the longest worker
    /// owns `ceil(rows / threads)` rows. `threads = 0` is treated as 1
    /// (the pool's inline path).
    pub fn algo1_softmax_plane(&self, rows: usize, n: usize,
                               threads: usize) -> f64 {
        rows.div_ceil(threads.max(1)) as f64 * self.algo1_softmax(n)
    }

    /// Critical-path cycles of an `[rows × n]` Algo-2 plane over the
    /// row pool. `group` comes from the live kernel
    /// (`BatchSoftmax::group()`) and `threads` from
    /// `BatchSoftmax::threads()` so the accounting tracks what the
    /// pooled kernel actually executes.
    pub fn algo2_softmax_plane(&self, rows: usize, n: usize,
                               group: usize, threads: usize) -> f64 {
        rows.div_ceil(threads.max(1)) as f64
            * self.algo2_softmax_grouped(n, group)
    }

    /// Critical-path cycles of one *fused* packed attention-plane row
    /// ([`crate::exaq::plane::AttentionPlane::attend`]): quantize+pack
    /// every lane, one LUT_sum load per key group plus a single
    /// divide for the denominator, then the PV pass decodes each code
    /// once through the premultiplied table (a LUT-class load) and
    /// spends a multiply + add per `(lane, d_head)` element. The f32
    /// probability plane is never written or re-read.
    pub fn attention_plane_fused(&self, rows: usize, len: usize,
                                 d_head: usize, bits: u32,
                                 threads: usize) -> f64 {
        self.attention_plane_fused_grouped(
            rows, len, d_head, crate::exaq::lut::lut_group(bits),
            threads)
    }

    /// [`Self::attention_plane_fused`] from an explicit kernel group —
    /// callers holding a live plane pass `AttentionPlane::group()` /
    /// `AttentionPlane::threads()` so the accounting can never drift
    /// from the configuration in use.
    pub fn attention_plane_fused_grouped(&self, rows: usize,
                                         len: usize, d_head: usize,
                                         group: usize,
                                         threads: usize) -> f64 {
        let (n, d, g) = (len as f64, d_head as f64, group as f64);
        let per_row = n * self.quant + (n / g) * self.lut + self.div
            + n * self.lut + 2.0 * n * d * self.add;
        rows.div_ceil(threads.max(1)) as f64 * per_row
    }

    /// The two-step reference
    /// ([`crate::exaq::plane::AttentionPlane::attend_two_step`]): the
    /// full Algo-2 softmax (which normalizes and *writes* every f32
    /// probability — `n` divides) plus a dense PV pass that re-reads
    /// each probability (a load-class `lut` charge per lane) before
    /// the same multiply + add accumulation. Strictly dearer than the
    /// fused row by `n*lut + (n-1)*div` — the round trip.
    pub fn attention_plane_two_step(&self, rows: usize, len: usize,
                                    d_head: usize, bits: u32,
                                    threads: usize) -> f64 {
        self.attention_plane_two_step_grouped(
            rows, len, d_head, crate::exaq::lut::lut_group(bits),
            threads)
    }

    /// [`Self::attention_plane_two_step`] from an explicit group.
    pub fn attention_plane_two_step_grouped(&self, rows: usize,
                                            len: usize, d_head: usize,
                                            group: usize,
                                            threads: usize) -> f64 {
        let (n, d) = (len as f64, d_head as f64);
        let per_row = self.algo2_softmax_grouped(len, group)
            + n * self.lut + 2.0 * n * d * self.add;
        rows.div_ceil(threads.max(1)) as f64 * per_row
    }

    /// The streaming one-pass kernel
    /// ([`crate::exaq::StreamingAttention`]): the fused row program
    /// plus one extra load-class pass over the `n` scores, because
    /// Algorithm 2 max-shifts against the *final* row max and the
    /// kernel therefore produces every score strip twice (max pass +
    /// encode pass) instead of holding a dense plane.
    pub fn attention_plane_streaming(&self, rows: usize, len: usize,
                                     d_head: usize, bits: u32,
                                     threads: usize) -> f64 {
        self.attention_plane_streaming_grouped(
            rows, len, d_head, crate::exaq::lut::lut_group(bits),
            threads)
    }

    /// [`Self::attention_plane_streaming`] from an explicit kernel
    /// group (`StreamingAttention::group()`).
    pub fn attention_plane_streaming_grouped(&self, rows: usize,
                                             len: usize,
                                             d_head: usize,
                                             group: usize,
                                             threads: usize) -> f64 {
        let n = len as f64;
        self.attention_plane_fused_grouped(rows, len, d_head, group,
                                           threads)
            + rows.div_ceil(threads.max(1)) as f64 * n * self.lut
    }

    /// Fractional runtime saving of Algo. 2 over Algo. 1 (Table 3's
    /// 36.9% figure is (3.274 − 2.066) / 3.274).
    pub fn softmax_saving(&self, n: usize, bits: u32) -> f64 {
        let a1 = self.algo1_softmax(n);
        let a2 = self.algo2_softmax(n, bits);
        (a1 - a2) / a1
    }

    /// Speedup of the *accumulation phase* alone (paper §4.2: ~4x at
    /// 2 bits, 2x at 4 bits).
    pub fn accumulation_speedup(&self, n: usize, bits: u32) -> f64 {
        self.accumulation_speedup_grouped(
            n, crate::exaq::lut::lut_group(bits))
    }

    /// [`Self::accumulation_speedup`] from an explicit kernel group
    /// (`BatchSoftmax::group()`): one LUT_sum load replaces `group`
    /// scalar adds.
    pub fn accumulation_speedup_grouped(&self, n: usize,
                                        group: usize) -> f64 {
        (n as f64 * self.add) / ((n as f64 / group as f64) * self.lut)
    }
}

/// GEMM precision scenarios for the Fig. 1 breakdown.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GemmPrecision {
    Bf16,
    Fp8,
}

/// Simple accelerator throughput model: MXU-style matmul engine, a vector
/// unit running the softmax cycle program, and an HBM byte budget for the
/// memory-bound element-wise bucket, in abstract "cycles".
///
/// Default constants are *fitted* so that the LLaMA-2-7B/BF16/Algo-1
/// scenario reproduces the paper's measured Fig. 1 shares (~39% softmax,
/// ~24% GEMM); everything else (FP8 scenario, Algo-2 scenario, other
/// shapes) is then prediction, not fit.
#[derive(Clone, Copy, Debug)]
pub struct MachineModel {
    /// MAC/cycle for BF16 matmuls (systolic array).
    pub mxu_bf16_macs: f64,
    /// MAC/cycle for FP8 matmuls (modern accelerators: 2x BF16).
    pub mxu_fp8_macs: f64,
    /// Vector lanes per cycle for the softmax cycle program.
    pub vpu_lanes: f64,
    /// HBM bytes per cycle for memory-bound element-wise ops.
    pub hbm_bytes_per_cycle: f64,
    pub cycles: CycleTable,
}

impl Default for MachineModel {
    fn default() -> Self {
        Self {
            mxu_bf16_macs: constants::MXU_BF16_MACS,
            mxu_fp8_macs: constants::MXU_FP8_MACS,
            vpu_lanes: constants::VPU_LANES,
            hbm_bytes_per_cycle: constants::HBM_BYTES_PER_CYCLE,
            cycles: CycleTable::default(),
        }
    }
}

/// One transformer-op bucket of the Fig. 1 pie.
#[derive(Clone, Debug)]
pub struct OpShare {
    pub name: &'static str,
    pub cycles: f64,
    pub share: f64,
}

/// Transformer shape for the breakdown (decoder inference, one step over
/// a sequence of length `s` with batch `b`).
#[derive(Clone, Copy, Debug)]
pub struct TransformerShape {
    pub layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq: usize,
    pub batch: usize,
    pub vocab: usize,
}

impl MachineModel {
    fn gemm_cycles(&self, macs: f64, prec: GemmPrecision) -> f64 {
        match prec {
            GemmPrecision::Bf16 => macs / self.mxu_bf16_macs,
            GemmPrecision::Fp8 => macs / self.mxu_fp8_macs,
        }
    }

    /// Fig. 1: per-op-type cycle shares for a full prefill pass.
    /// `softmax_algo2_bits = None` -> original softmax (Algo. 1).
    pub fn breakdown(
        &self,
        shape: TransformerShape,
        prec: GemmPrecision,
        softmax_algo2_bits: Option<u32>,
    ) -> Vec<OpShare> {
        let TransformerShape { layers, d_model, n_heads, d_ff, seq, batch,
                               vocab } = shape;
        let (l, d, f, s, b) = (layers as f64, d_model as f64, d_ff as f64,
                               seq as f64, batch as f64);
        let hd = d / n_heads as f64;

        // GEMMs: qkv+o projections, attention score/value matmuls, MLP.
        let proj = 4.0 * b * s * d * d;
        let attn_mm = 2.0 * b * n_heads as f64 * s * s * hd;
        let mlp = 3.0 * b * s * d * f;
        let head = b * s * d * vocab as f64;
        let gemm = self.gemm_cycles(l * (proj + attn_mm + mlp) + head, prec);

        // softmax: one row of length `s` per (batch, head, query)
        let rows = b * n_heads as f64 * s;
        let softmax = l * rows
            * match softmax_algo2_bits {
                None => self.cycles.algo1_softmax(seq),
                Some(bits) => self.cycles.algo2_softmax(seq, bits),
            }
            / self.vpu_lanes;

        // element-wise bucket is memory-bound: norms (2/layer), rope,
        // residuals, KV writes, activation traffic — modelled as HBM
        // bytes moved (f32): ~20 d-wide accesses + ~6 ff-wide accesses
        // per token per layer.
        let elemwise = l * (b * s * d * 20.0 + b * s * f * 6.0) * 4.0
            / self.hbm_bytes_per_cycle;

        let total = gemm + softmax + elemwise;
        vec![
            OpShare { name: "gemm", cycles: gemm, share: gemm / total },
            OpShare { name: "softmax", cycles: softmax,
                      share: softmax / total },
            OpShare { name: "elementwise", cycles: elemwise,
                      share: elemwise / total },
        ]
    }

    /// Total modeled cycles of one batched prefill pass (the Fig. 1
    /// accounting summed). This is the latency model the simulation
    /// backend charges per admission.
    pub fn prefill_cycles(
        &self,
        shape: TransformerShape,
        prec: GemmPrecision,
        softmax_algo2_bits: Option<u32>,
    ) -> f64 {
        self.breakdown(shape, prec, softmax_algo2_bits)
            .iter()
            .map(|o| o.cycles)
            .sum()
    }

    /// Modeled cycles of one batched decode step: `active` sequences,
    /// one query token each, attending over `kv_len` cached positions.
    /// Same accounting buckets as [`Self::breakdown`] specialised to a
    /// single query per sequence.
    pub fn decode_step_cycles(
        &self,
        shape: TransformerShape,
        prec: GemmPrecision,
        softmax_algo2_bits: Option<u32>,
        active: usize,
        kv_len: usize,
    ) -> f64 {
        let TransformerShape { layers, d_model, n_heads, d_ff, vocab,
                               .. } = shape;
        let (l, d, f, b) = (layers as f64, d_model as f64, d_ff as f64,
                            active as f64);
        let hd = d / n_heads as f64;
        let s = kv_len as f64;

        let proj = 4.0 * b * d * d;
        let attn_mm = 2.0 * b * n_heads as f64 * s * hd;
        let mlp = 3.0 * b * d * f;
        let head = b * d * vocab as f64;
        let gemm = self.gemm_cycles(l * (proj + attn_mm + mlp) + head,
                                    prec);

        // one softmax row of length kv_len per (sequence, head)
        let rows = b * n_heads as f64;
        let softmax = l * rows
            * match softmax_algo2_bits {
                None => self.cycles.algo1_softmax(kv_len),
                Some(bits) => self.cycles.algo2_softmax(kv_len, bits),
            }
            / self.vpu_lanes;

        let elemwise = l * (b * d * 20.0 + b * f * 6.0) * 4.0
            / self.hbm_bytes_per_cycle;

        gemm + softmax + elemwise
    }

    /// Modeled cycles of one `[rows × len] × [len × d_head]` attention
    /// plane including its score-plane memory traffic — the quantity
    /// `BENCH_attention.json` claims the fused layout wins. Compute
    /// runs the [`CycleTable`] attention variants over `vpu_lanes`;
    /// traffic charges HBM bytes: both paths write + re-read the
    /// packed key plane and stream the value matrix (the fused path
    /// refetches V once per `TILE_ROWS` row block), but only the
    /// two-step path also writes and re-reads the f32 probability
    /// plane. Tile, group, and worker constants come from
    /// `exaq::plane` so the model is pinned to the live kernel.
    pub fn attention_plane_cycles(&self, rows: usize, len: usize,
                                  d_head: usize, bits: u32,
                                  threads: usize, fused: bool) -> f64 {
        use crate::exaq::plane::{
            dense_plane_bytes, packed_plane_bytes, TILE_ROWS,
        };
        let compute = if fused {
            self.cycles
                .attention_plane_fused(rows, len, d_head, bits,
                                       threads)
        } else {
            self.cycles
                .attention_plane_two_step(rows, len, d_head, bits,
                                          threads)
        } / self.vpu_lanes;
        let scores = dense_plane_bytes(rows, len);
        let packed = 2 * packed_plane_bytes(rows, len, bits);
        let v_bytes = 4 * len * d_head
            * if fused { rows.div_ceil(TILE_ROWS) } else { rows };
        let round_trip =
            if fused { 0 } else { 2 * dense_plane_bytes(rows, len) };
        let traffic = (scores + packed + v_bytes + round_trip) as f64
            / self.hbm_bytes_per_cycle;
        compute + traffic
    }

    /// Device cycles of the streaming one-pass kernel
    /// ([`crate::exaq::StreamingAttention`]) over the same geometry:
    /// the [`CycleTable::attention_plane_streaming`] row program over
    /// `vpu_lanes`, and — the whole point — the f32 score traffic is
    /// the **real strip size**
    /// ([`crate::exaq::footprint::streaming_strip_bytes`], a constant
    /// independent of `len`), not a `[rows × len]` dense plane. The
    /// packed key plane and the blocked value stream are charged
    /// exactly as in the fused path.
    pub fn attention_streaming_cycles(&self, rows: usize, len: usize,
                                      d_head: usize, bits: u32,
                                      threads: usize) -> f64 {
        self.attention_streaming_grouped(
            rows, len, d_head, bits,
            crate::exaq::lut::lut_group(bits), threads)
    }

    /// [`Self::attention_streaming_cycles`] from an explicit kernel
    /// group (`StreamingAttention::group()`), so callers holding a
    /// live kernel can never drift from its packing.
    pub fn attention_streaming_grouped(&self, rows: usize, len: usize,
                                       d_head: usize, bits: u32,
                                       group: usize,
                                       threads: usize) -> f64 {
        use crate::exaq::footprint::{packed_plane_bytes,
                                     streaming_strip_bytes};
        use crate::exaq::plane::TILE_ROWS;
        let compute = self
            .cycles
            .attention_plane_streaming_grouped(rows, len, d_head,
                                               group, threads)
            / self.vpu_lanes;
        let scores = streaming_strip_bytes();
        let packed = 2 * packed_plane_bytes(rows, len, bits);
        let v_bytes = 4 * len * d_head * rows.div_ceil(TILE_ROWS);
        let traffic = (scores + packed + v_bytes) as f64
            / self.hbm_bytes_per_cycle;
        compute + traffic
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_read_the_shared_constants_table() {
        // both charge paths — the cycle program and the machine
        // throughput model — must quote the one constants table, so
        // the cost CLI and the sim charge-back can never diverge
        let t = CycleTable::default();
        assert_eq!(t.exp, constants::EXP_CYCLES);
        assert_eq!(t.lut, constants::LUT_CYCLES);
        assert_eq!(t.quant, constants::QUANT_CYCLES);
        assert_eq!(t.add, constants::ADD_CYCLES);
        assert_eq!(t.div, constants::DIV_CYCLES);
        let m = MachineModel::default();
        assert_eq!(m.mxu_bf16_macs, constants::MXU_BF16_MACS);
        assert_eq!(m.mxu_fp8_macs, constants::MXU_FP8_MACS);
        assert_eq!(m.vpu_lanes, constants::VPU_LANES);
        assert_eq!(m.hbm_bytes_per_cycle,
                   constants::HBM_BYTES_PER_CYCLE);
        assert_eq!(m.cycles.quant, constants::QUANT_CYCLES);
    }

    #[test]
    fn streaming_cycles_quote_the_constant_strip() {
        use crate::exaq::footprint::{packed_plane_bytes,
                                     streaming_strip_bytes};
        use crate::exaq::plane::TILE_ROWS;
        let m = MachineModel::default();
        let (rows, d, bits, threads) = (64usize, 64usize, 2u32, 1);
        // isolate the f32-score traffic term: it must be the fixed
        // strip, independent of context length
        let strip_term = |len: usize| {
            m.attention_streaming_cycles(rows, len, d, bits, threads)
                - m.cycles
                    .attention_plane_streaming(rows, len, d, bits,
                                               threads)
                    / m.vpu_lanes
                - (2 * packed_plane_bytes(rows, len, bits)
                   + 4 * len * d * rows.div_ceil(TILE_ROWS))
                    as f64
                    / m.hbm_bytes_per_cycle
        };
        let want =
            streaming_strip_bytes() as f64 / m.hbm_bytes_per_cycle;
        for len in [256usize, 2048, 65_536] {
            assert!((strip_term(len) - want).abs() < 1e-6,
                    "len {len}: {} vs {want}", strip_term(len));
        }
        // never holding the dense plane beats re-reading it: the
        // extra fill pass costs less than the plane's HBM round trip
        for len in [512usize, 4096] {
            let fused = m.attention_plane_cycles(rows, len, d, bits,
                                                 threads, true);
            let streaming = m.attention_streaming_cycles(rows, len, d,
                                                         bits,
                                                         threads);
            assert!(streaming < fused,
                    "len {len}: streaming {streaming} >= fused {fused}");
        }
    }

    #[test]
    fn default_cycles_reproduce_table3_magnitude() {
        // Table 3: 3.274ms -> 2.066ms is a 36.9% saving. Our default
        // cycle table should land in the same regime at 2 bits.
        let t = CycleTable::default();
        let saving = t.softmax_saving(2048, 2);
        assert!((saving - 0.369).abs() < 0.05,
                "saving {saving:.4} vs paper 0.369");
    }

    #[test]
    fn accumulation_speedup_matches_paper_claims() {
        let t = CycleTable::default();
        // §4.2: ~4x at 2 bits (byte packs 4 codes)…
        let s2 = t.accumulation_speedup(4096, 2);
        assert!((s2 - 4.0).abs() < 1e-9, "{s2}");
        // …and 2x at 4 bits (byte packs 2 codes).
        let s4 = t.accumulation_speedup(4096, 4);
        assert!((s4 - 2.0).abs() < 1e-9, "{s4}");
    }

    #[test]
    fn accounting_group_matches_the_live_kernel() {
        // the speedup constant must come from the same packing the
        // batched kernel actually executes with — build one and check
        use crate::exaq::BatchSoftmax;
        let t = CycleTable::default();
        for bits in [1u32, 2, 3, 4] {
            let eng = BatchSoftmax::new(bits, -4.0);
            let via_bits = t.accumulation_speedup(1024, bits);
            let via_kernel =
                t.accumulation_speedup_grouped(1024, eng.group());
            assert!((via_bits - via_kernel).abs() < 1e-12,
                    "bits={bits}: accounting drifted from the kernel");
            assert!((t.algo2_softmax(1024, bits)
                     - t.algo2_softmax_grouped(1024, eng.group()))
                        .abs() < 1e-12);
        }
    }

    #[test]
    fn plane_accounting_tracks_the_live_kernel_knobs() {
        use crate::exaq::BatchSoftmax;
        let t = CycleTable::default();
        let mut eng = BatchSoftmax::new(2, -4.0);
        eng.set_threads(4);
        let (rows, n) = (64usize, 256usize);
        // the plane variants take group/threads straight off the engine
        let plane = t.algo2_softmax_plane(rows, n, eng.group(),
                                          eng.threads());
        let per_row = t.algo2_softmax_grouped(n, eng.group());
        assert!((plane - 16.0 * per_row).abs() < 1e-9,
                "64 rows on 4 workers = 16 rows critical path");
        // threads = 0 (auto sentinel upstream) accounts as inline
        let inline = t.algo1_softmax_plane(rows, n, 0);
        assert!((inline - rows as f64 * t.algo1_softmax(n)).abs()
                    < 1e-9);
        // uneven split charges the longest worker
        let uneven = t.algo1_softmax_plane(10, n, 4);
        assert!((uneven - 3.0 * t.algo1_softmax(n)).abs() < 1e-9);
        // parallel Algo-2 still beats parallel Algo-1 cell-for-cell
        assert!(plane < t.algo1_softmax_plane(rows, n, eng.threads()));
    }

    #[test]
    fn fused_attention_plane_is_strictly_cheaper() {
        let t = CycleTable::default();
        let m = MachineModel::default();
        for bits in [1u32, 2, 3, 4, 5] {
            for (rows, len, d) in
                [(1usize, 1usize, 1usize), (8, 64, 16), (64, 2048, 64)]
            {
                let fused =
                    t.attention_plane_fused(rows, len, d, bits, 1);
                let two =
                    t.attention_plane_two_step(rows, len, d, bits, 1);
                assert!(fused < two,
                        "bits={bits} rows={rows} len={len}: \
                         fused {fused} !< two-step {two}");
                // the gap is exactly the round trip the fused path
                // deletes: n probability re-reads + (n-1) divides
                let n = len as f64;
                let want = rows as f64
                    * (n * t.lut + (n - 1.0) * t.div);
                assert!(((two - fused) - want).abs() < 1e-6,
                        "bits={bits} len={len}");
                // and the machine model (compute + HBM traffic)
                // agrees once the f32 plane traffic is charged
                let mf = m.attention_plane_cycles(rows, len, d, bits,
                                                  1, true);
                let mt = m.attention_plane_cycles(rows, len, d, bits,
                                                  1, false);
                assert!(mf < mt, "bits={bits} machine model");
            }
        }
    }

    #[test]
    fn attention_plane_accounting_tracks_the_live_plane() {
        use crate::exaq::AttentionPlane;
        let t = CycleTable::default();
        let (rows, len, d) = (64usize, 256usize, 32usize);
        for bits in [2u32, 3, 4] {
            let mut plane = AttentionPlane::new(bits, -4.0);
            plane.set_threads(4);
            // the grouped variants take group/threads straight off
            // the live plane and must agree with the bits variants
            let via_bits =
                t.attention_plane_fused(rows, len, d, bits, 4);
            let via_plane = t.attention_plane_fused_grouped(
                rows, len, d, plane.group(), plane.threads());
            assert!((via_bits - via_plane).abs() < 1e-9,
                    "bits={bits}: accounting drifted from the plane");
            let two_bits =
                t.attention_plane_two_step(rows, len, d, bits, 4);
            let two_plane = t.attention_plane_two_step_grouped(
                rows, len, d, plane.group(), plane.threads());
            assert!((two_bits - two_plane).abs() < 1e-9, "bits={bits}");
            // worker split charges the longest worker, like the
            // softmax plane variants
            let one = t.attention_plane_fused(1, len, d, bits, 1);
            assert!((via_bits - 16.0 * one).abs() < 1e-6,
                    "64 rows on 4 workers = 16 rows critical path");
        }
    }

    #[test]
    fn algo2_cheaper_for_all_row_sizes() {
        let t = CycleTable::default();
        for n in [16usize, 64, 256, 2048, 8192] {
            for bits in [2, 3, 4] {
                assert!(t.algo2_softmax(n, bits) < t.algo1_softmax(n),
                        "n={n} bits={bits}");
            }
        }
    }

    #[test]
    fn fig1_softmax_dominates_in_bf16() {
        // The motivation claim: with BF16 GEMMs, softmax is the largest
        // single op bucket (~39% on Gaudi-2 for LLaMA-2-7B).
        let m = MachineModel::default();
        let shape = TransformerShape {
            layers: 32, d_model: 4096, n_heads: 32, d_ff: 11008,
            seq: 2048, batch: 1, vocab: 32000,
        };
        let shares = m.breakdown(shape, GemmPrecision::Bf16, None);
        let softmax = shares.iter().find(|o| o.name == "softmax").unwrap();
        let gemm = shares.iter().find(|o| o.name == "gemm").unwrap();
        assert!(softmax.share > 0.25 && softmax.share < 0.55,
                "softmax share {:.3}", softmax.share);
        assert!(softmax.share > gemm.share * 0.8,
                "softmax {:.3} should rival gemm {:.3}",
                softmax.share, gemm.share);
    }

    #[test]
    fn fp8_inflates_softmax_share() {
        // §2: as GEMMs accelerate, softmax's share grows.
        let m = MachineModel::default();
        let shape = TransformerShape {
            layers: 32, d_model: 4096, n_heads: 32, d_ff: 11008,
            seq: 2048, batch: 1, vocab: 32000,
        };
        let bf16 = m.breakdown(shape, GemmPrecision::Bf16, None);
        let fp8 = m.breakdown(shape, GemmPrecision::Fp8, None);
        let s16 = bf16.iter().find(|o| o.name == "softmax").unwrap().share;
        let s8 = fp8.iter().find(|o| o.name == "softmax").unwrap().share;
        assert!(s8 > s16);
    }

    #[test]
    fn algo2_shrinks_softmax_share() {
        let m = MachineModel::default();
        let shape = TransformerShape {
            layers: 32, d_model: 4096, n_heads: 32, d_ff: 11008,
            seq: 2048, batch: 1, vocab: 32000,
        };
        let before = m.breakdown(shape, GemmPrecision::Bf16, None);
        let after = m.breakdown(shape, GemmPrecision::Bf16, Some(2));
        let sb = before.iter().find(|o| o.name == "softmax").unwrap();
        let sa = after.iter().find(|o| o.name == "softmax").unwrap();
        assert!(sa.cycles < sb.cycles * 0.75);
    }

    #[test]
    fn prefill_cycles_is_breakdown_total() {
        let m = MachineModel::default();
        let shape = TransformerShape {
            layers: 4, d_model: 128, n_heads: 4, d_ff: 352,
            seq: 64, batch: 8, vocab: 104,
        };
        let total: f64 = m.breakdown(shape, GemmPrecision::Bf16, None)
            .iter().map(|o| o.cycles).sum();
        let got = m.prefill_cycles(shape, GemmPrecision::Bf16, None);
        assert!((got - total).abs() < 1e-9);
        assert!(got > 0.0);
    }

    #[test]
    fn decode_step_scales_with_active_and_prefers_algo2() {
        let m = MachineModel::default();
        let shape = TransformerShape {
            layers: 2, d_model: 8, n_heads: 2, d_ff: 16,
            seq: 64, batch: 8, vocab: 64,
        };
        let one = m.decode_step_cycles(shape, GemmPrecision::Bf16, None,
                                       1, 64);
        let eight = m.decode_step_cycles(shape, GemmPrecision::Bf16,
                                         None, 8, 64);
        assert!(eight > one, "{eight} vs {one}");
        // batching amortises nothing in this model but must stay linear
        assert!((eight - 8.0 * one).abs() < 1e-6 * eight.max(1.0));
        let a2 = m.decode_step_cycles(shape, GemmPrecision::Bf16,
                                      Some(2), 8, 64);
        assert!(a2 < eight, "algo2 decode {a2} !< algo1 {eight}");
        // a decode step is much cheaper than a full prefill
        let pf = m.prefill_cycles(shape, GemmPrecision::Bf16, None);
        assert!(eight < pf, "decode {eight} !< prefill {pf}");
    }

    #[test]
    fn shares_sum_to_one() {
        let m = MachineModel::default();
        let shape = TransformerShape {
            layers: 4, d_model: 128, n_heads: 4, d_ff: 352,
            seq: 64, batch: 8, vocab: 104,
        };
        for prec in [GemmPrecision::Bf16, GemmPrecision::Fp8] {
            let total: f64 = m.breakdown(shape, prec, None)
                .iter().map(|o| o.share).sum();
            assert!((total - 1.0).abs() < 1e-9);
        }
    }
}
