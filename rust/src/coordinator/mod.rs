//! L3 serving coordinator: continuous batching over the PJRT engine.
//!
//! Shape: requests enter an admission queue; the scheduler claims a KV
//! slot per sequence, runs batch-1 prefill to fill the slot, then steps
//! ALL active slots together through the batch-8 decode executable
//! (inactive rows are padded and ignored) — the prefill/decode interleave
//! of vLLM-style continuous batching, scaled to this bundle's fixed
//! artifact batch sizes.

pub mod batcher;
pub mod kv;
pub mod metrics;
pub mod request;
pub mod server;

pub use batcher::Scheduler;
pub use kv::KvPool;
pub use metrics::Metrics;
pub use request::{Request, Response};
pub use server::{serve_until_drained, ServeConfig};
