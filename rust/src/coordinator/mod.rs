//! L3 serving coordinator: continuous batching over an
//! [`InferenceBackend`](crate::runtime::InferenceBackend).
//!
//! Shape: requests enter an admission queue; the scheduler claims a KV
//! slot per sequence, runs batch-1 prefill to fill the slot, then steps
//! ALL active slots together through the batched decode entry point
//! (inactive rows are padded and ignored) — the prefill/decode
//! interleave of vLLM-style continuous batching, scaled to this
//! bundle's fixed artifact batch sizes.
//!
//! Two abstractions make the layer testable at scale without any PJRT
//! artifacts:
//!
//! * the **`InferenceBackend` trait** (`runtime::backend`) — the
//!   scheduler and serve loops are generic over it, so the PJRT
//!   [`Engine`](crate::runtime::Engine) and the deterministic
//!   [`SimBackend`](crate::runtime::SimBackend) are interchangeable;
//! * the **`Clock` trait** (`util::clock`) — all timestamps (enqueue,
//!   first token, completion) are read from a shared wall or virtual
//!   clock; simulation backends *advance* the virtual clock by their
//!   modeled step latency, making TTFT/latency metrics exact.
//!
//! [`workload`] generates deterministic scenario mixes (steady, burst,
//! long-prompt tail, mixed lengths, early-EOS chat) that
//! `rust/tests/serving_integration.rs` replays through the real
//! scheduler by the thousands.

pub mod batcher;
pub mod kv;
pub mod metrics;
pub mod request;
pub mod server;
pub mod workload;

pub use batcher::Scheduler;
pub use kv::KvPool;
pub use metrics::Metrics;
pub use request::{Request, Response, TimedRequest};
pub use server::{serve_trace, serve_until_drained, ServeConfig};
pub use workload::{Scenario, WorkloadSpec};
