//! L3 serving coordinator: a multi-replica continuous-batching fabric
//! over [`InferenceBackend`](crate::runtime::InferenceBackend)s.
//!
//! Three layers, mirroring a cli/client/core/executor crate split:
//!
//! * **[`router`]** — the front door. Admission control, priority
//!   tiers, per-tenant round-robin fairness, queued-stage
//!   cancellation/timeouts. Never touches a backend.
//! * **[`replica`]** — the engine room. Each replica owns one
//!   backend's continuous batching: admission-queue → batch-1 prefill
//!   into a private [`kv::KvPool`] slot → batched decode stepping
//!   (the prefill/decode interleave of vLLM-style continuous
//!   batching), plus in-flight timeouts, cancellation, preemption
//!   hand-back, and token streaming.
//! * **[`server`]** — the drivers. Single-replica serve loops and the
//!   multi-replica [`Fabric`](server::Fabric) that advances one
//!   simulated timeline across N independently-clocked replicas.
//!   [`batcher::Scheduler`] remains as the one-replica facade.
//!
//! Two abstractions make the layer testable at scale without any PJRT
//! artifacts:
//!
//! * the **`InferenceBackend` trait** (`runtime::backend`) — replicas
//!   and serve loops are generic over it, so the PJRT
//!   [`Engine`](crate::runtime::Engine) and the deterministic
//!   [`SimBackend`](crate::runtime::SimBackend) are interchangeable;
//! * the **`Clock` trait** (`util::clock`) — all timestamps (enqueue,
//!   first token, completion) are read from a shared wall or virtual
//!   clock; simulation backends *advance* the virtual clock by their
//!   modeled step latency, making TTFT/latency metrics exact.
//!
//! [`workload`] generates deterministic scenario mixes (steady, burst,
//! long-prompt tail, mixed lengths, early-EOS chat) with tenant and
//! priority annotations; `rust/tests/serving_integration.rs` replays
//! them through the single-replica scheduler by the thousands and
//! `rust/tests/fabric_integration.rs` through the fabric by the
//! million.

pub mod batcher;
pub mod kv;
pub mod metrics;
pub mod replica;
pub mod request;
pub mod router;
pub mod server;
pub mod workload;

pub use batcher::Scheduler;
pub use kv::KvPool;
pub use metrics::Metrics;
pub use replica::{Assignment, Replica};
pub use request::{
    FinishReason, Priority, Request, Response, TimedRequest,
    TokenEvent, NO_REPLICA,
};
pub use router::{Router, RouterConfig};
pub use server::{
    serve_trace, serve_until_drained, Fabric, FabricConfig,
    ServeConfig,
};
pub use workload::{Scenario, WorkloadSpec};
