//! One worker replica: a continuous-batching engine room around a
//! private KV pool, driven by the front-door router (`super::router`)
//! or directly by the single-replica [`super::Scheduler`] facade.
//!
//! The replica owns admission (batch-1 prefill into a free KV slot),
//! the batched decode step, sampling, per-replica metrics, timeouts of
//! queued and in-flight requests, cancellation, and preemption
//! (evicting an in-flight request so its tokens-so-far travel back to
//! the router and the decode resumes later, bit-identically, possibly
//! on another replica).
//!
//! Resume correctness: a preempted request re-prefills the plane
//! `[bos, prompt, tokens-so-far]` and samples the logit row at
//! position `prompt_len + tokens_so_far` — exactly the row the decode
//! step would have seeded from `(last token, position)` — so greedy
//! streams are invariant under preemption and replica migration. The
//! row index stays in range because a preempted request was still
//! alive, i.e. its next write position was `< max_seq`.

use std::collections::VecDeque;
use std::rc::Rc;

use crate::model::sampling::{BatchSampler, SamplingParams};
use crate::runtime::backend::InferenceBackend;
use crate::runtime::{DecodeState, HostTensor, QuantMode};
use crate::util::clock::Clock;
use crate::util::error::{anyhow, bail, Result};
use crate::util::rng::SplitMix64;

use super::kv::{BatchedKv, KvPool};
use super::metrics::Metrics;
use super::request::{
    FinishReason, InFlight, Priority, Request, Response, TokenEvent,
};

/// Default seed of the sampling RNG (reproducible serving runs).
pub const DEFAULT_SAMPLER_SEED: u64 = 0xC0FFEE;

/// A unit of work travelling router -> replica (and back, on
/// preemption): the request plus everything needed to resume it
/// without losing tokens or latency accounting.
#[derive(Clone, Debug)]
pub struct Assignment {
    pub req: Request,
    /// Clock second the request first entered the fabric.
    pub enqueued: f64,
    /// Tokens generated in earlier episodes (empty when fresh).
    pub prior: Vec<i32>,
    /// Clock second of the first sampled token, if any episode
    /// produced one (preserved across preemptions so TTFT measures
    /// the *first* episode).
    pub first_token: Option<f64>,
    /// Times this request has been preempted so far.
    pub preemptions: u32,
}

impl Assignment {
    /// A fresh, never-scheduled assignment.
    pub fn fresh(req: Request, enqueued: f64) -> Self {
        Self {
            req,
            enqueued,
            prior: Vec::new(),
            first_token: None,
            preemptions: 0,
        }
    }

    /// Total tokens generated across all episodes so far.
    pub fn generated_total(&self) -> usize {
        self.prior.len()
    }
}

/// A worker replica: one backend's worth of continuous batching.
pub struct Replica {
    id: usize,
    model: String,
    quant: QuantMode,
    c_vec: Option<Vec<f32>>,
    queue: VecDeque<Assignment>,
    active: Vec<Option<InFlight>>, // indexed by slot
    pool: KvPool,
    kv: BatchedKv,
    metrics: Metrics,
    rng: SplitMix64,
    sampler: BatchSampler,
    /// (plane row, params) pairs for the current sampling call.
    sample_rows: Vec<(usize, SamplingParams)>,
    /// Token output of the current sampling call.
    sample_out: Vec<i32>,
    stream: Vec<TokenEvent>,
    collect_stream: bool,
    seq: usize,
    eos: i32,
    decode_batch: usize,
    clock: Rc<dyn Clock>,
}

impl Replica {
    pub fn new<B: InferenceBackend + ?Sized>(
        id: usize, backend: &B, model: &str, quant: QuantMode,
        c_vec: Option<Vec<f32>>, decode_batch: usize,
        clock: Rc<dyn Clock>,
    ) -> Result<Self> {
        let c = backend.model_config(model)?;
        Ok(Self {
            id,
            model: model.to_string(),
            quant,
            c_vec,
            queue: VecDeque::new(),
            active: (0..decode_batch).map(|_| None).collect(),
            pool: KvPool::new(decode_batch),
            kv: BatchedKv::new(c.n_layers, decode_batch, c.n_heads,
                               c.max_seq, c.head_dim),
            metrics: Metrics::default(),
            rng: SplitMix64::new(DEFAULT_SAMPLER_SEED),
            sampler: BatchSampler::default(),
            sample_rows: Vec::new(),
            sample_out: Vec::new(),
            stream: Vec::new(),
            collect_stream: false,
            seq: c.max_seq,
            eos: backend.eos_token(),
            decode_batch,
            clock,
        })
    }

    pub fn id(&self) -> usize {
        self.id
    }

    /// Reseed the sampling RNG (call before the first assign to get a
    /// different — still reproducible — stochastic-sampling stream).
    pub fn reseed_sampler(&mut self, seed: u64) {
        self.rng = SplitMix64::new(seed);
    }

    /// Toggle per-token [`TokenEvent`] collection (off by default;
    /// costs one Vec push per sampled token when on).
    pub fn set_collect_stream(&mut self, on: bool) {
        self.collect_stream = on;
    }

    /// Drain the collected token events.
    pub fn take_stream(&mut self) -> Vec<TokenEvent> {
        std::mem::take(&mut self.stream)
    }

    /// Hand this replica a unit of work. Fresh assignments count into
    /// `requests_in`; resumes of preempted work count into `resumes`.
    pub fn assign(&mut self, a: Assignment) {
        if a.preemptions == 0 {
            self.metrics.requests_in += 1;
        } else {
            self.metrics.resumes += 1;
        }
        self.queue.push_back(a);
    }

    pub fn has_work(&self) -> bool {
        !self.queue.is_empty()
            || self.active.iter().any(Option::is_some)
    }

    pub fn active_count(&self) -> usize {
        self.active.iter().filter(|s| s.is_some()).count()
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Free slots not already spoken for by this replica's own queue
    /// — what the router may still dispatch here this tick.
    pub fn capacity_left(&self) -> usize {
        self.pool.available().saturating_sub(self.queue.len())
    }

    /// Slot-pool view for accounting assertions.
    pub fn pool(&self) -> &KvPool {
        &self.pool
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Cancel a request queued or in flight on this replica. Returns
    /// `true` (with a `Cancelled` response pushed to `done`) if the
    /// request was found here.
    pub fn cancel(
        &mut self, id: u64, done: &mut Vec<Response>,
    ) -> Result<bool> {
        let now = self.clock.now();
        if let Some(i) = self.queue.iter().position(|a| a.req.id == id)
        {
            let a = self.queue.remove(i).ok_or_else(|| {
                anyhow!("queued assignment {id} vanished mid-cancel")
            })?;
            self.metrics.cancelled += 1;
            done.push(self.queue_exit(a, FinishReason::Cancelled, now));
            return Ok(true);
        }
        for s in 0..self.active.len() {
            let hit = self.active[s]
                .as_ref()
                .map(|inf| inf.req.id == id)
                .unwrap_or(false);
            if hit {
                let mut inf = self.active[s].take().ok_or_else(|| {
                    anyhow!("active slot {s} emptied mid-cancel")
                })?;
                done.push(
                    self.finish(&mut inf, FinishReason::Cancelled)?,
                );
                self.pool.release(s)?;
                return Ok(true);
            }
        }
        Ok(false)
    }

    /// Best preemption victim strictly less urgent than `than`:
    /// `(victim priority, tokens generated, slot)`, preferring the
    /// least urgent tier, then the longest-running decode, then the
    /// lowest slot (for determinism).
    pub fn preempt_candidate(
        &self, than: Priority,
    ) -> Option<(Priority, usize, usize)> {
        let mut best: Option<(Priority, usize, usize)> = None;
        for (s, slot) in self.active.iter().enumerate() {
            let Some(inf) = slot.as_ref() else { continue };
            if inf.req.priority <= than {
                continue;
            }
            let total = inf.prior.len() + inf.generated.len();
            let cand = (inf.req.priority, total, s);
            let better = match &best {
                None => true,
                Some(b) => {
                    (cand.0.index(), cand.1) > (b.0.index(), b.1)
                }
            };
            if better {
                best = Some(cand);
            }
        }
        best
    }

    /// Evict the request in `slot`, releasing its KV slot and
    /// returning an [`Assignment`] that resumes it without token
    /// loss. The caller (the router) decides where it resumes.
    pub fn preempt_slot(&mut self, slot: usize) -> Result<Assignment> {
        let mut inf = self.active.get_mut(slot).and_then(Option::take)
            .ok_or_else(|| {
                anyhow!("preempt of empty or out-of-range slot {slot}")
            })?;
        self.pool.release(slot)?;
        self.metrics.preemptions += 1;
        let mut prior = std::mem::take(&mut inf.prior);
        prior.append(&mut inf.generated);
        Ok(Assignment {
            req: inf.req,
            enqueued: inf.enqueued,
            prior,
            first_token: inf.first_token,
            preemptions: inf.preemptions + 1,
        })
    }

    /// One scheduling tick: expire deadlines, admit (prefill) while
    /// slots are free, then one batched decode step. Completed
    /// responses are appended to `done`.
    pub fn tick<B: InferenceBackend + ?Sized>(
        &mut self, backend: &mut B, done: &mut Vec<Response>,
    ) -> Result<()> {
        self.expire_queued(done)?;

        // ---- admission: prefill queued work into free slots (FIFO)
        while self.pool.available() > 0 && !self.queue.is_empty() {
            let Some(a) = self.queue.pop_front() else { break };
            self.admit(backend, a, done)?;
        }

        self.expire_active(done)?;
        self.decode_step(backend, done)
    }

    /// Expire queued assignments whose deadline has passed.
    fn expire_queued(&mut self, done: &mut Vec<Response>) -> Result<()> {
        let now = self.clock.now();
        let mut i = 0;
        while i < self.queue.len() {
            let expired = self.queue[i]
                .req
                .timeout
                .map(|dt| now >= self.queue[i].enqueued + dt)
                .unwrap_or(false);
            if expired {
                let a = self.queue.remove(i).ok_or_else(|| {
                    anyhow!("queued assignment vanished mid-expiry")
                })?;
                self.metrics.timed_out += 1;
                done.push(self.queue_exit(a, FinishReason::TimedOut,
                                          now));
            } else {
                i += 1;
            }
        }
        Ok(())
    }

    /// Expire in-flight requests whose deadline has passed; they keep
    /// the tokens generated so far.
    fn expire_active(&mut self, done: &mut Vec<Response>) -> Result<()> {
        let now = self.clock.now();
        for s in 0..self.active.len() {
            let expired = self.active[s]
                .as_ref()
                .and_then(|inf| inf.req.timeout.map(|dt| {
                    now >= inf.enqueued + dt
                }))
                .unwrap_or(false);
            if expired {
                let mut inf = self.active[s].take().ok_or_else(|| {
                    anyhow!("active slot {s} emptied mid-expiry")
                })?;
                done.push(
                    self.finish(&mut inf, FinishReason::TimedOut)?,
                );
                self.pool.release(s)?;
            }
        }
        Ok(())
    }

    /// Prefill one assignment into a free slot and sample its next
    /// token. For resumes the prompt plane is extended with the
    /// tokens generated so far, reproducing the interrupted decode
    /// exactly (see module docs).
    fn admit<B: InferenceBackend + ?Sized>(
        &mut self, backend: &mut B, a: Assignment,
        done: &mut Vec<Response>,
    ) -> Result<()> {
        let slot = self.pool.alloc().ok_or_else(|| {
            anyhow!("slot pool reported a free slot but alloc failed")
        })?;
        let prompt_len = a.req.prompt.len().min(self.seq - 1);
        let row_pos = prompt_len + a.prior.len();
        if row_pos >= self.seq {
            bail!("resume position {row_pos} out of range for \
                   max_seq {}", self.seq);
        }
        let mut padded = Vec::with_capacity(self.seq);
        padded.push(1); // <bos>
        padded.extend_from_slice(&a.req.prompt[..prompt_len]);
        padded.extend_from_slice(&a.prior);
        padded.resize(self.seq, 0); // <pad>
        let tokens = HostTensor::i32(padded, &[1, self.seq]);
        let (logits, state) = backend.prefill(
            &self.model, self.quant, &tokens,
            self.c_vec.as_deref())?;
        self.metrics.prefills += 1;
        self.kv.fill_slot(slot, &state.kc, &state.vc)?;

        // sample the next token from the logit row following the last
        // known token (prompt end, or last resumed token) through the
        // shared batched sampler
        let vocab = logits.shape[2];
        self.sample_rows.clear();
        self.sample_rows.push((row_pos, a.req.params));
        self.sampler.sample_rows(logits.as_f32()?, vocab,
                                 &self.sample_rows, &mut self.rng,
                                 &mut self.sample_out);
        let tok = self.sample_out.first().copied().ok_or_else(
            || anyhow!("sampler returned no token for the prefill \
                        row"))?;
        let now = self.clock.now();
        if self.collect_stream {
            self.stream.push(TokenEvent {
                id: a.req.id,
                token: tok,
                t: now,
                replica: self.id,
            });
        }
        let mut inf = InFlight {
            req: a.req,
            enqueued: a.enqueued,
            first_token: Some(a.first_token.unwrap_or(now)),
            prior: a.prior,
            generated: vec![tok],
            slot,
            pos: row_pos + 1, // next write position
            preemptions: a.preemptions,
        };
        let total = inf.prior.len() + inf.generated.len();
        if tok == self.eos || total >= inf.req.max_new_tokens
            || inf.pos >= self.seq
        {
            done.push(self.finish(&mut inf, FinishReason::Done)?);
            self.pool.release(slot)?;
        } else {
            self.active[slot] = Some(inf);
        }
        Ok(())
    }

    /// One batched decode step over all active slots.
    fn decode_step<B: InferenceBackend + ?Sized>(
        &mut self, backend: &mut B, done: &mut Vec<Response>,
    ) -> Result<()> {
        let active_slots: Vec<usize> = self
            .active
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.as_ref().map(|_| i))
            .collect();
        if active_slots.is_empty() {
            return Ok(());
        }
        let mut token = vec![0i32; self.decode_batch];
        let mut pos = vec![0i32; self.decode_batch];
        for &s in &active_slots {
            let inf = self.active[s].as_ref().ok_or_else(
                || anyhow!("active slot {s} emptied mid-tick"))?;
            token[s] = inf.generated.last().copied().ok_or_else(
                || anyhow!("slot {s} active with no generated \
                            token"))?;
            pos[s] = inf.pos as i32;
        }
        // move (not clone) the batched KV through the backend call;
        // the buffers are unconditionally replaced by the returned
        // state below, so cloning would be pure memcpy overhead
        let placeholder = || HostTensor::f32(Vec::new(), &[0]);
        let mut state = DecodeState {
            kc: std::mem::replace(&mut self.kv.kc, placeholder()),
            vc: std::mem::replace(&mut self.kv.vc, placeholder()),
        };
        let logits = backend.decode(&self.model, self.quant, &token,
                                    &pos, &mut state,
                                    self.c_vec.as_deref())?;
        self.kv.kc = state.kc;
        self.kv.vc = state.vc;
        self.metrics.decode_steps += 1;
        self.metrics.decode_tokens += active_slots.len() as u64;
        self.metrics.batch_occupancy_sum += active_slots.len() as u64;

        let vocab = logits.shape[1];
        let lg = logits.as_f32()?;
        // one batched sampling call over every active slot's row:
        // all EXAQ rows go through a single bit-packed plane kernel
        self.sample_rows.clear();
        for &s in &active_slots {
            let inf = self.active[s].as_ref().ok_or_else(
                || anyhow!("active slot {s} emptied mid-tick"))?;
            self.sample_rows.push((s, inf.req.params));
        }
        self.sampler.sample_rows(lg, vocab, &self.sample_rows,
                                 &mut self.rng,
                                 &mut self.sample_out);
        let now = self.clock.now();
        for (i, &s) in active_slots.iter().enumerate() {
            let tok = self.sample_out.get(i).copied().ok_or_else(
                || anyhow!("sampler produced {} tokens for {} \
                            active rows", self.sample_out.len(),
                           active_slots.len()))?;
            let mut finished = false;
            {
                let inf = self.active[s].as_mut().ok_or_else(
                    || anyhow!("active slot {s} emptied \
                                mid-tick"))?;
                inf.generated.push(tok);
                inf.pos += 1;
                let total = inf.prior.len() + inf.generated.len();
                if tok == self.eos
                    || total >= inf.req.max_new_tokens
                    || inf.pos >= self.seq
                {
                    finished = true;
                }
                if self.collect_stream {
                    self.stream.push(TokenEvent {
                        id: inf.req.id,
                        token: tok,
                        t: now,
                        replica: self.id,
                    });
                }
            }
            if finished {
                let mut inf = self.active[s].take().ok_or_else(
                    || anyhow!("finished slot {s} already empty"))?;
                done.push(self.finish(&mut inf, FinishReason::Done)?);
                self.pool.release(s)?;
            }
        }
        Ok(())
    }

    /// Response for work leaving from the replica queue (timed out or
    /// cancelled before ever claiming a slot here).
    fn queue_exit(
        &self, a: Assignment, finish: FinishReason, now: f64,
    ) -> Response {
        Response {
            id: a.req.id,
            prompt_len: a.req.prompt.len(),
            tokens: a.prior,
            ttft: a.first_token.map(|t| t - a.enqueued).unwrap_or(0.0),
            total_latency: now - a.enqueued,
            tenant: a.req.tenant,
            priority: a.req.priority,
            replica: self.id,
            finish,
            preemptions: a.preemptions,
        }
    }

    fn finish(
        &mut self, inf: &mut InFlight, finish: FinishReason,
    ) -> Result<Response> {
        let now = self.clock.now();
        let ttft = inf
            .first_token
            .map(|t| t - inf.enqueued)
            .unwrap_or(0.0);
        let total = now - inf.enqueued;
        match finish {
            FinishReason::Done => {
                self.metrics.ttft.record(ttft);
                self.metrics.total_latency.record(total);
                self.metrics.requests_done += 1;
            }
            FinishReason::Cancelled => self.metrics.cancelled += 1,
            FinishReason::TimedOut => self.metrics.timed_out += 1,
        }
        let mut tokens = std::mem::take(&mut inf.prior);
        tokens.append(&mut inf.generated);
        Ok(Response {
            id: inf.req.id,
            prompt_len: inf.req.prompt.len(),
            tokens,
            ttft,
            total_latency: total,
            tenant: inf.req.tenant,
            priority: inf.req.priority,
            replica: self.id,
            finish,
            preemptions: inf.preemptions,
        })
    }
}
