//! Request/response types of the serving API.
//!
//! All timestamps are seconds on the scheduler's [`Clock`]
//! (`crate::util::clock`) — wall time in production, virtual time in
//! the simulation harness — which is what makes TTFT/latency exactly
//! reproducible in tests.

use crate::model::SamplingParams;

/// Scheduling tier of a request. Lower value = stricter latency
/// target; the router always drains a stricter tier before touching
/// the next one, and preemption only ever evicts a *less* strict
/// victim to make room for a stricter arrival.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    /// Human-in-the-loop traffic (chat): lowest TTFT target.
    Interactive = 0,
    /// Default tier for API traffic.
    Standard = 1,
    /// Throughput-oriented offline work (long-prompt tails).
    Batch = 2,
}

impl Priority {
    /// All tiers, strictest first — the router's drain order.
    pub const ALL: [Priority; 3] =
        [Priority::Interactive, Priority::Standard, Priority::Batch];

    /// Dense tier index (0 = strictest).
    pub fn index(self) -> usize {
        self as usize
    }

    pub fn name(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Standard => "standard",
            Priority::Batch => "batch",
        }
    }
}

/// Why a response left the fabric.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Ran to EOS / token budget / sequence bound.
    Done,
    /// Explicitly cancelled by the client.
    Cancelled,
    /// Exceeded its deadline (queued or in flight).
    TimedOut,
}

/// `Response::replica` value for requests that never reached a
/// replica (cancelled or timed out while still queued at the router).
pub const NO_REPLICA: usize = usize::MAX;

/// An inference request (tokenized prompt).
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub params: SamplingParams,
    /// Fairness bucket: the router round-robins between tenants
    /// inside each priority tier.
    pub tenant: u32,
    pub priority: Priority,
    /// Deadline in seconds after enqueue; `None` = no deadline.
    pub timeout: Option<f64>,
}

impl Request {
    /// A standard-tier, tenant-0 request with no deadline — the shape
    /// every pre-fabric call site used.
    pub fn new(
        id: u64, prompt: Vec<i32>, max_new_tokens: usize,
        params: SamplingParams,
    ) -> Self {
        Self {
            id,
            prompt,
            max_new_tokens,
            params,
            tenant: 0,
            priority: Priority::Standard,
            timeout: None,
        }
    }

    pub fn with_tenant(mut self, tenant: u32) -> Self {
        self.tenant = tenant;
        self
    }

    pub fn with_priority(mut self, priority: Priority) -> Self {
        self.priority = priority;
        self
    }

    pub fn with_timeout(mut self, timeout: f64) -> Self {
        self.timeout = Some(timeout);
        self
    }
}

/// A request with a scheduled arrival time, as produced by the
/// workload generator and consumed by `server::serve_trace`.
#[derive(Clone, Debug)]
pub struct TimedRequest {
    /// Arrival offset in seconds from the start of the trace.
    pub at: f64,
    pub req: Request,
}

/// Completion of one request, with timing for the latency report.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    /// queue-in -> first token (seconds).
    pub ttft: f64,
    /// queue-in -> completion (seconds).
    pub total_latency: f64,
    pub tenant: u32,
    pub priority: Priority,
    /// Replica that served the final episode ([`NO_REPLICA`] if the
    /// request never left the router queue).
    pub replica: usize,
    pub finish: FinishReason,
    /// Times this request was evicted and later resumed.
    pub preemptions: u32,
}

/// One streamed token, tagged with the virtual second it was sampled.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TokenEvent {
    pub id: u64,
    pub token: i32,
    /// Clock second the token was sampled.
    pub t: f64,
    pub replica: usize,
}

/// Internal lifecycle record.
#[derive(Debug)]
pub struct InFlight {
    pub req: Request,
    /// Clock second the request entered the admission queue.
    pub enqueued: f64,
    /// Clock second the first token was sampled.
    pub first_token: Option<f64>,
    /// Tokens generated in earlier episodes (before a preemption).
    pub prior: Vec<i32>,
    pub generated: Vec<i32>,
    pub slot: usize,
    /// next decode position (= tokens written into the KV so far).
    pub pos: usize,
    /// Times this request has been preempted so far.
    pub preemptions: u32,
}
