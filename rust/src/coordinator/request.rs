//! Request/response types of the serving API.

use std::time::Instant;

use crate::model::SamplingParams;

/// An inference request (tokenized prompt).
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub params: SamplingParams,
}

/// Completion of one request, with timing for the latency report.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    /// queue-in -> first token (seconds).
    pub ttft: f64,
    /// queue-in -> completion (seconds).
    pub total_latency: f64,
}

/// Internal lifecycle record.
#[derive(Debug)]
pub struct InFlight {
    pub req: Request,
    pub enqueued: Instant,
    pub first_token: Option<Instant>,
    pub generated: Vec<i32>,
    pub slot: usize,
    /// next decode position (= tokens written into the KV so far).
    pub pos: usize,
}
