//! Request/response types of the serving API.
//!
//! All timestamps are seconds on the scheduler's [`Clock`]
//! (`crate::util::clock`) — wall time in production, virtual time in
//! the simulation harness — which is what makes TTFT/latency exactly
//! reproducible in tests.

use crate::model::SamplingParams;

/// An inference request (tokenized prompt).
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub params: SamplingParams,
}

/// A request with a scheduled arrival time, as produced by the
/// workload generator and consumed by `server::serve_trace`.
#[derive(Clone, Debug)]
pub struct TimedRequest {
    /// Arrival offset in seconds from the start of the trace.
    pub at: f64,
    pub req: Request,
}

/// Completion of one request, with timing for the latency report.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    /// queue-in -> first token (seconds).
    pub ttft: f64,
    /// queue-in -> completion (seconds).
    pub total_latency: f64,
}

/// Internal lifecycle record.
#[derive(Debug)]
pub struct InFlight {
    pub req: Request,
    /// Clock second the request entered the admission queue.
    pub enqueued: f64,
    /// Clock second the first token was sampled.
    pub first_token: Option<f64>,
    pub generated: Vec<i32>,
    pub slot: usize,
    /// next decode position (= tokens written into the KV so far).
    pub pos: usize,
}
