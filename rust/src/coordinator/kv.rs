//! KV-cache slot pool: fixed-capacity slot allocator plus the host-side
//! batched cache tensor that decode rows live in.

use crate::runtime::HostTensor;
use crate::util::error::{bail, Result};

/// Allocator over decode-batch rows.
#[derive(Debug)]
pub struct KvPool {
    free: Vec<usize>,
    capacity: usize,
    in_use: usize,
}

impl KvPool {
    pub fn new(capacity: usize) -> Self {
        Self {
            free: (0..capacity).rev().collect(),
            capacity,
            in_use: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn available(&self) -> usize {
        self.free.len()
    }

    pub fn in_use(&self) -> usize {
        self.in_use
    }

    pub fn alloc(&mut self) -> Option<usize> {
        let slot = self.free.pop()?;
        self.in_use += 1;
        Some(slot)
    }

    pub fn release(&mut self, slot: usize) -> Result<()> {
        if slot >= self.capacity {
            bail!("slot {slot} out of range");
        }
        if self.free.contains(&slot) {
            bail!("double free of slot {slot}");
        }
        self.free.push(slot);
        self.in_use -= 1;
        Ok(())
    }
}

/// The batched KV tensors for the decode executable, with row copy-in
/// from batch-1 prefill outputs.
#[derive(Debug)]
pub struct BatchedKv {
    pub kc: HostTensor,
    pub vc: HostTensor,
    pub layers: usize,
    pub batch: usize,
    pub row: usize, // H * S * hd elements per (layer, slot)
}

impl BatchedKv {
    pub fn new(layers: usize, batch: usize, heads: usize, seq: usize,
               head_dim: usize) -> Self {
        let shape = [layers, batch, heads, seq, head_dim];
        Self {
            kc: HostTensor::zeros_f32(&shape),
            vc: HostTensor::zeros_f32(&shape),
            layers,
            batch,
            row: heads * seq * head_dim,
        }
    }

    /// Copy a batch-1 prefill cache (shape [L,1,H,S,hd]) into `slot`.
    pub fn fill_slot(&mut self, slot: usize, kc1: &HostTensor,
                     vc1: &HostTensor) -> Result<()> {
        if slot >= self.batch {
            bail!("slot {slot} >= batch {}", self.batch);
        }
        let row = self.row;
        for (dst, src) in [(&mut self.kc, kc1), (&mut self.vc, vc1)] {
            let d = match &mut dst.data {
                crate::runtime::tensor::TensorData::F32(v) => v,
                _ => bail!("kv must be f32"),
            };
            let s = src.as_f32()?;
            if s.len() != self.layers * row {
                bail!("prefill cache size mismatch: {} vs {}",
                      s.len(), self.layers * row);
            }
            for l in 0..self.layers {
                let doff = (l * self.batch + slot) * row;
                d[doff..doff + row]
                    .copy_from_slice(&s[l * row..(l + 1) * row]);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    #[test]
    fn alloc_release_roundtrip() {
        let mut p = KvPool::new(3);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        let c = p.alloc().unwrap();
        assert_ne!(a, b);
        assert_ne!(b, c);
        assert!(p.alloc().is_none());
        p.release(b).unwrap();
        assert_eq!(p.available(), 1);
        assert_eq!(p.alloc().unwrap(), b);
    }

    #[test]
    fn double_free_rejected() {
        let mut p = KvPool::new(2);
        let a = p.alloc().unwrap();
        p.release(a).unwrap();
        assert!(p.release(a).is_err());
        assert!(p.release(99).is_err());
    }

    #[test]
    fn alloc_until_exhausted_then_none() {
        let mut p = KvPool::new(4);
        let mut got = Vec::new();
        while let Some(s) = p.alloc() {
            got.push(s);
        }
        assert_eq!(got.len(), 4);
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 2, 3]);
        assert_eq!(p.available(), 0);
        assert_eq!(p.in_use(), 4);
        assert!(p.alloc().is_none());
        assert!(p.alloc().is_none(), "None must be sticky, not panic");
    }

    #[test]
    fn failed_release_leaves_accounting_intact() {
        let mut p = KvPool::new(3);
        let a = p.alloc().unwrap();
        let _b = p.alloc().unwrap();
        // out-of-range release: rejected before any state mutation
        assert!(p.release(3).is_err());
        assert!(p.release(usize::MAX).is_err());
        assert_eq!(p.in_use(), 2);
        assert_eq!(p.available(), 1);
        // double free after a valid release: also state-preserving
        p.release(a).unwrap();
        assert!(p.release(a).is_err());
        assert_eq!(p.in_use(), 1);
        assert_eq!(p.available(), 2);
        assert_eq!(p.in_use() + p.available(), p.capacity());
    }

    #[test]
    fn release_of_never_allocated_slot_is_double_free() {
        // slot 2 exists but sits in the free list: releasing it again
        // must be rejected as a double free
        let mut p = KvPool::new(3);
        let _a = p.alloc().unwrap();
        assert!(p.release(2).is_err());
        assert_eq!(p.in_use(), 1);
    }

    /// Property-style test (hand-rolled; the image has no proptest):
    /// under a random alloc/release workload the pool never double
    /// allocates, never leaks, and in_use + available == capacity.
    #[test]
    fn random_workload_invariants() {
        let mut rng = SplitMix64::new(42);
        for trial in 0..50 {
            let cap = 1 + rng.below(16);
            let mut p = KvPool::new(cap);
            let mut held: Vec<usize> = Vec::new();
            for _ in 0..200 {
                if rng.below(2) == 0 {
                    if let Some(s) = p.alloc() {
                        assert!(!held.contains(&s),
                                "trial {trial}: double alloc of {s}");
                        held.push(s);
                    } else {
                        assert_eq!(held.len(), cap);
                    }
                } else if !held.is_empty() {
                    let i = rng.below(held.len());
                    let s = held.swap_remove(i);
                    p.release(s).unwrap();
                }
                assert_eq!(p.in_use(), held.len());
                assert_eq!(p.in_use() + p.available(), cap);
            }
        }
    }

    /// Property-style fabric-lifecycle test: a serving mix of admits
    /// (alloc + fill), releases (finish), preempt-and-resume pairs
    /// (release now, realloc later) and hostile probes (double free,
    /// out-of-range release, alloc-when-full) across 32 seeds. On
    /// failure the message carries `(seed, step)`: replay by pinning
    /// `seeds` to the failing seed and binary-searching `step` — the
    /// op stream is a pure function of the seed, so a failure
    /// shrinks by replay instead of by case minimization.
    #[test]
    fn admit_release_preempt_sequences_never_leak_or_double_free() {
        for seed in 0..32u64 {
            let mut rng = SplitMix64::new(seed);
            let cap = 1 + rng.below(12);
            let mut p = KvPool::new(cap);
            // slots held by "in-flight" work, and preempted work
            // waiting to be resumed (its slot already released)
            let mut held: Vec<usize> = Vec::new();
            let mut preempted = 0usize;
            for step in 0..400 {
                let ctx = format!("seed {seed} step {step}");
                match rng.below(6) {
                    // admit: fresh request or a preempted resume
                    0 | 1 => {
                        if let Some(s) = p.alloc() {
                            assert!(!held.contains(&s),
                                    "{ctx}: double alloc of {s}");
                            held.push(s);
                            if preempted > 0 && rng.below(2) == 0 {
                                preempted -= 1; // resumed
                            }
                        } else {
                            assert_eq!(held.len(), cap,
                                       "{ctx}: alloc failed with \
                                        free capacity");
                        }
                    }
                    // finish: release a held slot
                    2 | 3 => {
                        if !held.is_empty() {
                            let i = rng.below(held.len());
                            let s = held.swap_remove(i);
                            p.release(s).unwrap_or_else(|e| {
                                panic!("{ctx}: release({s}): {e}")
                            });
                        }
                    }
                    // preempt: victim's slot returns to the pool but
                    // the request stays logically alive
                    4 => {
                        if !held.is_empty() {
                            let i = rng.below(held.len());
                            let s = held.swap_remove(i);
                            p.release(s).unwrap_or_else(|e| {
                                panic!("{ctx}: preempt({s}): {e}")
                            });
                            preempted += 1;
                        }
                    }
                    // hostile probes: must error, must not corrupt
                    _ => {
                        let (iu, av) = (p.in_use(), p.available());
                        assert!(p.release(cap + rng.below(4)).is_err(),
                                "{ctx}: out-of-range release passed");
                        if let Some(&s) = held.first() {
                            // releasing then re-releasing = double
                            // free; probe on a fresh copy of the slot
                            p.release(s).unwrap_or_else(|e| {
                                panic!("{ctx}: release({s}): {e}")
                            });
                            assert!(p.release(s).is_err(),
                                    "{ctx}: double free passed");
                            let got = p.alloc().unwrap_or_else(|| {
                                panic!("{ctx}: realloc after probe")
                            });
                            assert_eq!(got, s,
                                       "{ctx}: LIFO realloc");
                        } else {
                            assert_eq!(
                                (p.in_use(), p.available()),
                                (iu, av),
                                "{ctx}: failed probe mutated state");
                        }
                    }
                }
                assert_eq!(p.in_use(), held.len(),
                           "{ctx}: in_use {} != held {}",
                           p.in_use(), held.len());
                assert_eq!(p.in_use() + p.available(), cap,
                           "{ctx}: leak — {} + {} != {cap}",
                           p.in_use(), p.available());
            }
            // drain: everything outstanding releases cleanly
            for s in held.drain(..) {
                p.release(s).unwrap_or_else(|e| {
                    panic!("seed {seed} drain release({s}): {e}")
                });
            }
            assert_eq!(p.in_use(), 0, "seed {seed}: drain leaked");
            assert_eq!(p.available(), cap);
        }
    }

    #[test]
    fn fill_slot_places_rows() {
        let (l, b, h, s, hd) = (2, 4, 2, 3, 2);
        let mut kv = BatchedKv::new(l, b, h, s, hd);
        let row = h * s * hd;
        let kc1 = HostTensor::f32((0..l * row).map(|x| x as f32).collect(),
                                  &[l, 1, h, s, hd]);
        let vc1 = HostTensor::f32(vec![7.0; l * row], &[l, 1, h, s, hd]);
        kv.fill_slot(2, &kc1, &vc1).unwrap();
        let kc = kv.kc.as_f32().unwrap();
        // layer 1, slot 2 row should contain the second layer of kc1
        let off = (b + 2) * row;
        assert_eq!(kc[off], row as f32);
        // untouched slot stays zero
        let off0 = (b + 1) * row;
        assert_eq!(kc[off0], 0.0);
        assert!(kv.fill_slot(9, &kc1, &vc1).is_err());
    }
}
