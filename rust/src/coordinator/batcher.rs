//! The continuous-batching scheduler: admission FIFO, slot claiming,
//! prefill-then-join, batched decode stepping.
//!
//! The scheduler is generic over [`InferenceBackend`] (PJRT engine or
//! the deterministic SimBackend) and reads time exclusively through a
//! shared [`Clock`], so the same code path serves production traffic
//! and the virtual-time stress harness.
//!
//! Sampling is batched the same way the backend step is: each decode
//! tick hands every active slot's logit row to ONE
//! [`BatchSampler::sample_rows`] call, which shapes all EXAQ rows
//! through a single bit-packed [`crate::exaq::BatchSoftmax`] plane
//! kernel instead of per-slot scalar softmaxes. Prefill admission
//! (batch-1 shaping of the freshly padded prompt plane) rides the same
//! sampler so the whole scheduler owns exactly one set of EXAQ tables.

use std::collections::VecDeque;
use std::rc::Rc;

use crate::model::sampling::{BatchSampler, SamplingParams};
use crate::runtime::backend::InferenceBackend;
use crate::runtime::{DecodeState, HostTensor, QuantMode};
use crate::util::clock::Clock;
use crate::util::error::{anyhow, Result};
use crate::util::rng::SplitMix64;

use super::kv::{BatchedKv, KvPool};
use super::metrics::Metrics;
use super::request::{InFlight, Request, Response};

/// Default seed of the sampling RNG (reproducible serving runs).
pub const DEFAULT_SAMPLER_SEED: u64 = 0xC0FFEE;

/// Scheduler over one model at one quantization setting.
pub struct Scheduler {
    model: String,
    quant: QuantMode,
    c_vec: Option<Vec<f32>>,
    pending: VecDeque<(Request, f64)>,
    active: Vec<Option<InFlight>>, // indexed by slot
    pool: KvPool,
    kv: BatchedKv,
    pub metrics: Metrics,
    rng: SplitMix64,
    sampler: BatchSampler,
    /// (plane row, params) pairs for the current sampling call.
    sample_rows: Vec<(usize, SamplingParams)>,
    /// Token output of the current sampling call.
    sample_out: Vec<i32>,
    seq: usize,
    eos: i32,
    decode_batch: usize,
    clock: Rc<dyn Clock>,
}

impl Scheduler {
    pub fn new<B: InferenceBackend + ?Sized>(
        backend: &B, model: &str, quant: QuantMode,
        c_vec: Option<Vec<f32>>, decode_batch: usize,
        clock: Rc<dyn Clock>,
    ) -> Result<Self> {
        let c = backend.model_config(model)?;
        Ok(Self {
            model: model.to_string(),
            quant,
            c_vec,
            pending: VecDeque::new(),
            active: (0..decode_batch).map(|_| None).collect(),
            pool: KvPool::new(decode_batch),
            kv: BatchedKv::new(c.n_layers, decode_batch, c.n_heads,
                               c.max_seq, c.head_dim),
            metrics: Metrics::default(),
            rng: SplitMix64::new(DEFAULT_SAMPLER_SEED),
            sampler: BatchSampler::default(),
            sample_rows: Vec::new(),
            sample_out: Vec::new(),
            seq: c.max_seq,
            eos: backend.eos_token(),
            decode_batch,
            clock,
        })
    }

    /// Reseed the sampling RNG (call before the first submit to get a
    /// different — still reproducible — stochastic-sampling stream).
    pub fn reseed_sampler(&mut self, seed: u64) {
        self.rng = SplitMix64::new(seed);
    }

    pub fn submit(&mut self, req: Request) {
        let now = self.clock.now();
        self.submit_at(req, now);
    }

    /// Submit with an explicit enqueue timestamp (clock seconds).
    /// Trace replay uses this: a request may only be *submitted* a tick
    /// after its simulated arrival, and the wait in between must count
    /// toward its TTFT/latency.
    pub fn submit_at(&mut self, req: Request, enqueued: f64) {
        self.metrics.requests_in += 1;
        self.pending.push_back((req, enqueued));
    }

    pub fn has_work(&self) -> bool {
        !self.pending.is_empty()
            || self.active.iter().any(Option::is_some)
    }

    pub fn active_count(&self) -> usize {
        self.active.iter().filter(|s| s.is_some()).count()
    }

    pub fn pending_count(&self) -> usize {
        self.pending.len()
    }

    /// Slot-pool view for accounting assertions.
    pub fn pool(&self) -> &KvPool {
        &self.pool
    }

    /// One scheduling tick: admit (prefill) while slots are free, then
    /// one batched decode step. Returns completed responses.
    pub fn tick<B: InferenceBackend + ?Sized>(
        &mut self, backend: &mut B,
    ) -> Result<Vec<Response>> {
        let mut done = Vec::new();

        // ---- admission: prefill pending requests into free slots (FIFO)
        while self.pool.available() > 0 && !self.pending.is_empty() {
            let Some((req, enqueued)) = self.pending.pop_front() else {
                break;
            };
            let slot = self.pool.alloc().ok_or_else(|| {
                anyhow!("slot pool reported a free slot but alloc \
                         failed")
            })?;
            let prompt_len = req.prompt.len().min(self.seq - 1);
            let mut padded = Vec::with_capacity(self.seq);
            padded.push(1); // <bos>
            padded.extend_from_slice(&req.prompt[..prompt_len]);
            padded.resize(self.seq, 0); // <pad>
            let tokens = HostTensor::i32(padded, &[1, self.seq]);
            let (logits, state) = backend.prefill(
                &self.model, self.quant, &tokens,
                self.c_vec.as_deref())?;
            self.metrics.prefills += 1;
            self.kv.fill_slot(slot, &state.kc, &state.vc)?;

            // sample the first generated token from the last prompt
            // logit (the prefill plane is [1, S, V]; row `pos` predicts
            // the next token) through the shared batched sampler
            let vocab = logits.shape[2];
            let pos = prompt_len; // logits index predicting next token
            self.sample_rows.clear();
            self.sample_rows.push((pos, req.params));
            self.sampler.sample_rows(logits.as_f32()?, vocab,
                                     &self.sample_rows, &mut self.rng,
                                     &mut self.sample_out);
            let tok = self.sample_out.first().copied().ok_or_else(
                || anyhow!("sampler returned no token for the \
                            prefill row"))?;
            let now = self.clock.now();
            let mut inf = InFlight {
                req,
                enqueued,
                first_token: Some(now),
                generated: vec![tok],
                slot,
                pos: prompt_len + 1, // next write position
            };
            if tok == self.eos || inf.req.max_new_tokens <= 1
                || inf.pos >= self.seq
            {
                done.push(self.finish(&mut inf)?);
                self.pool.release(slot)?;
            } else {
                self.active[slot] = Some(inf);
            }
        }

        // ---- decode: one batched step over all active slots
        let active_slots: Vec<usize> = self
            .active
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.as_ref().map(|_| i))
            .collect();
        if !active_slots.is_empty() {
            let mut token = vec![0i32; self.decode_batch];
            let mut pos = vec![0i32; self.decode_batch];
            for &s in &active_slots {
                let inf = self.active[s].as_ref().ok_or_else(
                    || anyhow!("active slot {s} emptied mid-tick"))?;
                token[s] = inf.generated.last().copied().ok_or_else(
                    || anyhow!("slot {s} active with no generated \
                                token"))?;
                pos[s] = inf.pos as i32;
            }
            // move (not clone) the batched KV through the backend call;
            // the buffers are unconditionally replaced by the returned
            // state below, so cloning would be pure memcpy overhead
            let placeholder = || HostTensor::f32(Vec::new(), &[0]);
            let mut state = DecodeState {
                kc: std::mem::replace(&mut self.kv.kc, placeholder()),
                vc: std::mem::replace(&mut self.kv.vc, placeholder()),
            };
            let logits = backend.decode(&self.model, self.quant, &token,
                                        &pos, &mut state,
                                        self.c_vec.as_deref())?;
            self.kv.kc = state.kc;
            self.kv.vc = state.vc;
            self.metrics.decode_steps += 1;
            self.metrics.decode_tokens += active_slots.len() as u64;
            self.metrics.batch_occupancy_sum += active_slots.len() as u64;

            let vocab = logits.shape[1];
            let lg = logits.as_f32()?;
            // one batched sampling call over every active slot's row:
            // all EXAQ rows go through a single bit-packed plane kernel
            self.sample_rows.clear();
            for &s in &active_slots {
                let inf = self.active[s].as_ref().ok_or_else(
                    || anyhow!("active slot {s} emptied mid-tick"))?;
                self.sample_rows.push((s, inf.req.params));
            }
            self.sampler.sample_rows(lg, vocab, &self.sample_rows,
                                     &mut self.rng,
                                     &mut self.sample_out);
            for (i, &s) in active_slots.iter().enumerate() {
                let tok = self.sample_out.get(i).copied().ok_or_else(
                    || anyhow!("sampler produced {} tokens for {} \
                                active rows", self.sample_out.len(),
                               active_slots.len()))?;
                let mut finished = false;
                {
                    let inf = self.active[s].as_mut().ok_or_else(
                        || anyhow!("active slot {s} emptied \
                                    mid-tick"))?;
                    inf.generated.push(tok);
                    inf.pos += 1;
                    if tok == self.eos
                        || inf.generated.len() >= inf.req.max_new_tokens
                        || inf.pos >= self.seq
                    {
                        finished = true;
                    }
                }
                if finished {
                    let mut inf = self.active[s].take().ok_or_else(
                        || anyhow!("finished slot {s} already \
                                    empty"))?;
                    done.push(self.finish(&mut inf)?);
                    self.pool.release(s)?;
                }
            }
        }

        self.metrics.requests_done += done.len() as u64;
        Ok(done)
    }

    fn finish(&mut self, inf: &mut InFlight) -> Result<Response> {
        let now = self.clock.now();
        let ttft = inf
            .first_token
            .map(|t| t - inf.enqueued)
            .unwrap_or(0.0);
        let total = now - inf.enqueued;
        self.metrics.ttft.record(ttft);
        self.metrics.total_latency.record(total);
        Ok(Response {
            id: inf.req.id,
            prompt_len: inf.req.prompt.len(),
            tokens: std::mem::take(&mut inf.generated),
            ttft,
            total_latency: total,
        })
    }
}

#[cfg(test)]
mod tests {
    // Scheduler logic that doesn't need a backend is covered through
    // KvPool/Metrics unit tests; end-to-end scheduling — admission
    // FIFO, occupancy, determinism, latency percentiles — is exercised
    // at scale by rust/tests/serving_integration.rs, which drives the
    // real Scheduler through the SimBackend on a VirtualClock (no
    // artifact bundle required).
    use std::rc::Rc;

    use super::*;
    use crate::model::SamplingParams;
    use crate::runtime::{SimBackend, SimConfig};
    use crate::util::clock::VirtualClock;

    #[test]
    fn admits_decodes_and_releases_slots() {
        let clock = Rc::new(VirtualClock::new());
        let mut sim =
            SimBackend::new(SimConfig::default(), clock.clone());
        let mut sched = Scheduler::new(&sim, "sim", QuantMode::None,
                                       None, 4, clock.clone())
            .unwrap();
        for id in 0..6u64 {
            sched.submit(Request {
                id,
                prompt: vec![5, 6, 7],
                max_new_tokens: 4,
                params: SamplingParams::greedy(),
            });
        }
        assert_eq!(sched.pending_count(), 6);
        let mut done = Vec::new();
        while sched.has_work() {
            assert_eq!(sched.pool().in_use(), sched.active_count());
            done.extend(sched.tick(&mut sim).unwrap());
        }
        assert_eq!(done.len(), 6);
        assert_eq!(sched.pool().in_use(), 0);
        assert_eq!(sched.pool().available(), 4);
        for r in &done {
            assert!(!r.tokens.is_empty());
            assert!(r.tokens.len() <= 4);
            assert!(r.total_latency >= r.ttft);
            assert!(r.ttft > 0.0, "virtual prefill must cost time");
        }
    }
}
