//! Single-replica scheduler facade over [`super::replica::Replica`].
//!
//! Historically `Scheduler` *was* the continuous-batching engine; the
//! multi-replica fabric moved the engine room into
//! `coordinator::replica` so a front-door router can drive N of them.
//! This facade keeps the original one-backend API (submit / tick /
//! drain) for the CLI, examples, and the single-replica serving path
//! — it is exactly a `Replica` with id 0 and no router in front.

use std::rc::Rc;

pub use super::replica::DEFAULT_SAMPLER_SEED;
use super::kv::KvPool;
use super::metrics::Metrics;
use super::replica::{Assignment, Replica};
use super::request::{Request, Response};
use crate::runtime::backend::InferenceBackend;
use crate::runtime::QuantMode;
use crate::util::clock::Clock;
use crate::util::error::Result;

/// Scheduler over one model at one quantization setting.
pub struct Scheduler {
    replica: Replica,
    clock: Rc<dyn Clock>,
}

impl Scheduler {
    pub fn new<B: InferenceBackend + ?Sized>(
        backend: &B, model: &str, quant: QuantMode,
        c_vec: Option<Vec<f32>>, decode_batch: usize,
        clock: Rc<dyn Clock>,
    ) -> Result<Self> {
        let replica = Replica::new(0, backend, model, quant, c_vec,
                                   decode_batch, clock.clone())?;
        Ok(Self { replica, clock })
    }

    /// Reseed the sampling RNG (call before the first submit to get a
    /// different — still reproducible — stochastic-sampling stream).
    pub fn reseed_sampler(&mut self, seed: u64) {
        self.replica.reseed_sampler(seed);
    }

    pub fn submit(&mut self, req: Request) {
        let now = self.clock.now();
        self.submit_at(req, now);
    }

    /// Submit with an explicit enqueue timestamp (clock seconds).
    /// Trace replay uses this: a request may only be *submitted* a tick
    /// after its simulated arrival, and the wait in between must count
    /// toward its TTFT/latency.
    pub fn submit_at(&mut self, req: Request, enqueued: f64) {
        self.replica.assign(Assignment::fresh(req, enqueued));
    }

    pub fn has_work(&self) -> bool {
        self.replica.has_work()
    }

    pub fn active_count(&self) -> usize {
        self.replica.active_count()
    }

    pub fn pending_count(&self) -> usize {
        self.replica.queue_len()
    }

    /// Slot-pool view for accounting assertions.
    pub fn pool(&self) -> &KvPool {
        self.replica.pool()
    }

    /// Serving counters and latency histograms.
    pub fn metrics(&self) -> &Metrics {
        self.replica.metrics()
    }

    /// One scheduling tick: admit (prefill) while slots are free, then
    /// one batched decode step. Returns completed responses.
    pub fn tick<B: InferenceBackend + ?Sized>(
        &mut self, backend: &mut B,
    ) -> Result<Vec<Response>> {
        let mut done = Vec::new();
        self.replica.tick(backend, &mut done)?;
        Ok(done)
    }
}

#[cfg(test)]
mod tests {
    // Scheduler logic that doesn't need a backend is covered through
    // KvPool/Metrics unit tests; end-to-end scheduling — admission
    // FIFO, occupancy, determinism, latency percentiles — is exercised
    // at scale by rust/tests/serving_integration.rs (single replica)
    // and rust/tests/fabric_integration.rs (router + N replicas),
    // which drive the real engine through the SimBackend on a
    // VirtualClock (no artifact bundle required).
    use std::rc::Rc;

    use super::*;
    use crate::model::SamplingParams;
    use crate::runtime::{SimBackend, SimConfig};
    use crate::util::clock::VirtualClock;

    #[test]
    fn admits_decodes_and_releases_slots() {
        let clock = Rc::new(VirtualClock::new());
        let mut sim =
            SimBackend::new(SimConfig::default(), clock.clone());
        let mut sched = Scheduler::new(&sim, "sim", QuantMode::None,
                                       None, 4, clock.clone())
            .unwrap();
        for id in 0..6u64 {
            sched.submit(Request::new(id, vec![5, 6, 7], 4,
                                      SamplingParams::greedy()));
        }
        assert_eq!(sched.pending_count(), 6);
        let mut done = Vec::new();
        while sched.has_work() {
            assert_eq!(sched.pool().in_use(), sched.active_count());
            done.extend(sched.tick(&mut sim).unwrap());
        }
        assert_eq!(done.len(), 6);
        assert_eq!(sched.pool().in_use(), 0);
        assert_eq!(sched.pool().available(), 4);
        assert_eq!(sched.metrics().requests_done, 6);
        for r in &done {
            assert!(!r.tokens.is_empty());
            assert!(r.tokens.len() <= 4);
            assert!(r.total_latency >= r.ttft);
            assert!(r.ttft > 0.0, "virtual prefill must cost time");
        }
    }
}
