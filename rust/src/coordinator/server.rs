//! Serve loops: drain a workload through the scheduler and collect
//! responses + throughput (the serving examples, benches and the
//! stress harness drive these).
//!
//! Both loops are generic over [`InferenceBackend`] and measure time
//! on the shared [`Clock`], so the same code serves a PJRT engine on
//! wall time and the SimBackend on virtual time.

use std::rc::Rc;

use crate::runtime::backend::InferenceBackend;
use crate::runtime::QuantMode;
use crate::util::clock::Clock;
use crate::util::error::Result;

use super::batcher::Scheduler;
use super::request::{Request, Response, TimedRequest};

/// Configuration of a serve run.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub model: String,
    pub quant: QuantMode,
    pub c_vec: Option<Vec<f32>>,
    pub decode_batch: usize,
}

/// Run all `requests` (already arrived) to completion; returns
/// (responses, elapsed clock seconds, scheduler with final metrics).
pub fn serve_until_drained<B: InferenceBackend + ?Sized>(
    backend: &mut B, cfg: &ServeConfig, requests: Vec<Request>,
    clock: Rc<dyn Clock>,
) -> Result<(Vec<Response>, f64, Scheduler)> {
    let mut sched = Scheduler::new(backend, &cfg.model, cfg.quant,
                                   cfg.c_vec.clone(), cfg.decode_batch,
                                   clock.clone())?;
    for r in requests {
        sched.submit(r);
    }
    let t0 = clock.now();
    let mut out = Vec::new();
    while sched.has_work() {
        out.extend(sched.tick(backend)?);
    }
    Ok((out, clock.now() - t0, sched))
}

/// Replay a timed arrival trace: requests are submitted when the clock
/// passes their arrival offset; when the scheduler is idle the clock
/// skips ahead to the next arrival (virtual clocks jump, wall clocks
/// sleep). Returns (responses, elapsed clock seconds, scheduler).
pub fn serve_trace<B: InferenceBackend + ?Sized>(
    backend: &mut B, cfg: &ServeConfig, mut trace: Vec<TimedRequest>,
    clock: Rc<dyn Clock>,
) -> Result<(Vec<Response>, f64, Scheduler)> {
    trace.sort_by(|a, b| {
        a.at.total_cmp(&b.at).then(a.req.id.cmp(&b.req.id))
    });
    let mut sched = Scheduler::new(backend, &cfg.model, cfg.quant,
                                   cfg.c_vec.clone(), cfg.decode_batch,
                                   clock.clone())?;
    let t0 = clock.now();
    let mut out = Vec::new();
    let mut next = 0usize;
    while next < trace.len() || sched.has_work() {
        while next < trace.len()
            && trace[next].at <= clock.now() - t0
        {
            // enqueue at the *arrival* time: a tick may have advanced
            // the clock past several arrivals, and that queue wait is
            // part of the latency being measured
            sched.submit_at(trace[next].req.clone(),
                            t0 + trace[next].at);
            next += 1;
        }
        if !sched.has_work() {
            // idle: jump to the next arrival (next < len is implied by
            // the loop condition when nothing is in flight)
            let gap = trace[next].at - (clock.now() - t0);
            clock.advance(gap.max(1e-9));
            continue;
        }
        out.extend(sched.tick(backend)?);
    }
    Ok((out, clock.now() - t0, sched))
}
