//! Serve loops: drain a workload through the scheduler and collect
//! responses + throughput (the serving examples, benches and the
//! stress harness drive these).
//!
//! Two layers live here:
//!
//! * the single-replica loops ([`serve_until_drained`],
//!   [`serve_trace`]) — generic over [`InferenceBackend`], measuring
//!   time on one shared [`Clock`];
//! * the multi-replica [`Fabric`]: a [`Router`] front door over N
//!   [`Replica`] workers, each with its own backend and its own
//!   [`VirtualClock`]. The fabric advances a global virtual `now` to
//!   the earliest replica completion or the next trace arrival, so a
//!   fleet of independently-clocked workers serves one coherent
//!   timeline — deterministically, because every scheduling decision
//!   is a pure function of (arrival order, request fields, seed).

use std::rc::Rc;

use crate::runtime::backend::InferenceBackend;
use crate::runtime::QuantMode;
use crate::util::clock::{Clock, VirtualClock};
use crate::util::error::{bail, Result};

use super::batcher::Scheduler;
use super::metrics::Metrics;
use super::replica::Replica;
use super::request::{
    Priority, Request, Response, TimedRequest, TokenEvent,
};
use super::router::{Router, RouterConfig};

/// Configuration of a serve run.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub model: String,
    pub quant: QuantMode,
    pub c_vec: Option<Vec<f32>>,
    pub decode_batch: usize,
}

/// Run all `requests` (already arrived) to completion; returns
/// (responses, elapsed clock seconds, scheduler with final metrics).
pub fn serve_until_drained<B: InferenceBackend + ?Sized>(
    backend: &mut B, cfg: &ServeConfig, requests: Vec<Request>,
    clock: Rc<dyn Clock>,
) -> Result<(Vec<Response>, f64, Scheduler)> {
    let mut sched = Scheduler::new(backend, &cfg.model, cfg.quant,
                                   cfg.c_vec.clone(), cfg.decode_batch,
                                   clock.clone())?;
    for r in requests {
        sched.submit(r);
    }
    let t0 = clock.now();
    let mut out = Vec::new();
    while sched.has_work() {
        out.extend(sched.tick(backend)?);
    }
    Ok((out, clock.now() - t0, sched))
}

/// Replay a timed arrival trace: requests are submitted when the clock
/// passes their arrival offset; when the scheduler is idle the clock
/// skips ahead to the next arrival (virtual clocks jump, wall clocks
/// sleep). Returns (responses, elapsed clock seconds, scheduler).
pub fn serve_trace<B: InferenceBackend + ?Sized>(
    backend: &mut B, cfg: &ServeConfig, mut trace: Vec<TimedRequest>,
    clock: Rc<dyn Clock>,
) -> Result<(Vec<Response>, f64, Scheduler)> {
    trace.sort_by(|a, b| {
        a.at.total_cmp(&b.at).then(a.req.id.cmp(&b.req.id))
    });
    let mut sched = Scheduler::new(backend, &cfg.model, cfg.quant,
                                   cfg.c_vec.clone(), cfg.decode_batch,
                                   clock.clone())?;
    let t0 = clock.now();
    let mut out = Vec::new();
    let mut next = 0usize;
    while next < trace.len() || sched.has_work() {
        while next < trace.len()
            && trace[next].at <= clock.now() - t0
        {
            // enqueue at the *arrival* time: a tick may have advanced
            // the clock past several arrivals, and that queue wait is
            // part of the latency being measured
            sched.submit_at(trace[next].req.clone(),
                            t0 + trace[next].at);
            next += 1;
        }
        if !sched.has_work() {
            // idle: jump to the next arrival (next < len is implied by
            // the loop condition when nothing is in flight)
            let gap = trace[next].at - (clock.now() - t0);
            clock.advance(gap.max(1e-9));
            continue;
        }
        out.extend(sched.tick(backend)?);
    }
    Ok((out, clock.now() - t0, sched))
}

/// Configuration of a multi-replica fabric.
#[derive(Clone, Debug)]
pub struct FabricConfig {
    pub serve: ServeConfig,
    pub router: RouterConfig,
    /// Collect per-token [`TokenEvent`]s (off by default: one Vec
    /// push per token).
    pub collect_stream: bool,
}

/// Router + N worker replicas on one simulated timeline.
pub struct Fabric<B: InferenceBackend> {
    cfg: FabricConfig,
    router: Router,
    replicas: Vec<Replica>,
    backends: Vec<B>,
    clocks: Vec<Rc<VirtualClock>>,
    now: f64,
    stream: Vec<TokenEvent>,
}

impl<B: InferenceBackend> Fabric<B> {
    /// Build `n_replicas` workers; `mk(i, clock)` constructs replica
    /// `i`'s backend on its private virtual clock.
    pub fn new<F>(
        n_replicas: usize, cfg: FabricConfig, mut mk: F,
    ) -> Result<Self>
    where
        F: FnMut(usize, Rc<dyn Clock>) -> Result<B>,
    {
        if n_replicas == 0 {
            bail!("fabric needs at least one replica");
        }
        let router = Router::new(cfg.router);
        let mut replicas = Vec::with_capacity(n_replicas);
        let mut backends = Vec::with_capacity(n_replicas);
        let mut clocks = Vec::with_capacity(n_replicas);
        for i in 0..n_replicas {
            let clock = Rc::new(VirtualClock::new());
            let backend =
                mk(i, clock.clone() as Rc<dyn Clock>)?;
            let mut replica = Replica::new(
                i, &backend, &cfg.serve.model, cfg.serve.quant,
                cfg.serve.c_vec.clone(), cfg.serve.decode_batch,
                clock.clone() as Rc<dyn Clock>,
            )?;
            replica.set_collect_stream(cfg.collect_stream);
            replicas.push(replica);
            backends.push(backend);
            clocks.push(clock);
        }
        Ok(Self {
            cfg,
            router,
            replicas,
            backends,
            clocks,
            now: 0.0,
            stream: Vec::new(),
        })
    }

    /// Current fabric-wide virtual second.
    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    pub fn replica(&self, i: usize) -> &Replica {
        &self.replicas[i]
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Per-replica sampler reseed (distinct streams per worker so
    /// stochastic sampling doesn't correlate across the fleet).
    pub fn reseed_samplers(&mut self, seed: u64) {
        for (i, rep) in self.replicas.iter_mut().enumerate() {
            rep.reseed_sampler(seed.wrapping_add(
                (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
            ));
        }
    }

    /// Queued + in-flight work anywhere in the fabric.
    pub fn has_work(&self) -> bool {
        self.router.queued_len() > 0
            || self.replicas.iter().any(Replica::has_work)
    }

    /// Submit at the current fabric time. Returns `false` when the
    /// router's admission control rejected the request.
    pub fn submit(&mut self, req: Request) -> bool {
        let now = self.now;
        self.router.submit(req, now)
    }

    /// Cancel a request wherever it currently lives (router queue, or
    /// queued/in-flight on a replica). The terminal `Cancelled`
    /// response is pushed to `out`; returns whether it was found.
    pub fn cancel(
        &mut self, id: u64, out: &mut Vec<Response>,
    ) -> Result<bool> {
        if let Some(r) = self.router.cancel(id, self.now) {
            out.push(r);
            return Ok(true);
        }
        for rep in self.replicas.iter_mut() {
            if rep.cancel(id, out)? {
                return Ok(true);
            }
        }
        Ok(false)
    }

    /// Drain collected token events (empty unless
    /// `cfg.collect_stream`).
    pub fn take_stream(&mut self) -> Vec<TokenEvent> {
        std::mem::take(&mut self.stream)
    }

    /// Sum of free-slot capacity the router could still dispatch
    /// into, across the whole fleet.
    fn total_capacity(&self) -> usize {
        self.replicas.iter().map(Replica::capacity_left).sum()
    }

    /// Preemption pass: when interactive work is starved of capacity,
    /// evict just enough less-urgent in-flight requests (least urgent
    /// tier first, then longest decode, then lowest replica/slot) and
    /// hand their resumable state back to the router.
    fn preempt_for_interactive(&mut self) -> Result<()> {
        let starved = self.router.queued_at(Priority::Interactive);
        let mut need =
            starved.saturating_sub(self.total_capacity());
        while need > 0 {
            let mut best: Option<(usize, usize, usize)> = None;
            let mut best_key = (0usize, 0usize);
            for (r, rep) in self.replicas.iter().enumerate() {
                let Some((p, total, slot)) =
                    rep.preempt_candidate(Priority::Interactive)
                else {
                    continue;
                };
                let key = (p.index(), total);
                if best.is_none() || key > best_key {
                    best = Some((r, slot, total));
                    best_key = key;
                }
            }
            let Some((r, slot, _)) = best else { break };
            let asg = self.replicas[r].preempt_slot(slot)?;
            self.router.requeue(asg);
            need -= 1;
        }
        Ok(())
    }

    /// One fabric step at virtual second `now`: expire router-stage
    /// deadlines, preempt if interactive work is starved, dispatch
    /// queued work to ready replicas (most free capacity first), tick
    /// every ready replica, then advance `now` to the earliest busy
    /// replica's clock or `horizon`, whichever is sooner. Returns
    /// whether the step made progress (work or time).
    pub fn step(
        &mut self, horizon: Option<f64>, out: &mut Vec<Response>,
    ) -> Result<bool> {
        let now = self.now;
        self.router.sweep_timeouts(now, out);
        if self.cfg.router.preemption {
            self.preempt_for_interactive()?;
        }

        // dispatch: fill the emptiest ready replica first (greedy
        // least-loaded; ties broken by replica index, so placement is
        // a pure function of queue state)
        let mut dispatched = 0usize;
        loop {
            let mut best: Option<(usize, usize)> = None; // (cap, r)
            for (r, rep) in self.replicas.iter().enumerate() {
                if self.clocks[r].now() > now {
                    continue; // still busy until its clock is reached
                }
                let cap = rep.capacity_left();
                if cap == 0 {
                    continue;
                }
                let better = match best {
                    None => true,
                    Some((bcap, _)) => cap > bcap,
                };
                if better {
                    best = Some((cap, r));
                }
            }
            let Some((_, r)) = best else { break };
            let Some(asg) = self.router.next() else { break };
            self.replicas[r].assign(asg);
            dispatched += 1;
        }

        // tick every ready replica that has work, on its own clock
        // synced up to the fabric's now
        let mut ticked = false;
        for r in 0..self.replicas.len() {
            if self.clocks[r].now() > now
                || !self.replicas[r].has_work()
            {
                continue;
            }
            let behind = now - self.clocks[r].now();
            self.clocks[r].advance(behind); // no-op when behind <= 0
            self.replicas[r].tick(&mut self.backends[r], out)?;
            if self.cfg.collect_stream {
                self.stream
                    .extend(self.replicas[r].take_stream());
            }
            ticked = true;
        }

        // advance the fabric timeline to the next event: the
        // earliest busy replica's completion — or, when work is still
        // queued at the router, the earliest moment an idle replica
        // with free capacity becomes ready (its clock may have run
        // ahead of `now` while finishing its previous batch)
        let mut next_t = f64::INFINITY;
        for (r, rep) in self.replicas.iter().enumerate() {
            let relevant = rep.has_work()
                || (self.router.queued_len() > 0
                    && rep.capacity_left() > 0);
            if relevant {
                let t = self.clocks[r].now();
                if t > now && t < next_t {
                    next_t = t;
                }
            }
        }
        if let Some(h) = horizon {
            if h > now && h < next_t {
                next_t = h;
            }
        }
        if next_t.is_finite() {
            self.now = next_t;
        }
        Ok(dispatched > 0 || ticked || next_t.is_finite())
    }

    /// Replay a timed arrival trace to completion, streaming each
    /// terminal [`Response`] into `on_done`. Returns elapsed virtual
    /// seconds.
    pub fn run_trace_with<F: FnMut(Response)>(
        &mut self, mut trace: Vec<TimedRequest>, mut on_done: F,
    ) -> Result<f64> {
        trace.sort_by(|a, b| {
            a.at.total_cmp(&b.at).then(a.req.id.cmp(&b.req.id))
        });
        let t0 = self.now;
        let mut next = 0usize;
        let mut out = Vec::new();
        loop {
            while next < trace.len()
                && t0 + trace[next].at <= self.now
            {
                // enqueue at the *arrival* time: the fabric may have
                // jumped past several arrivals and the queue wait is
                // part of the measured latency
                self.router.submit(trace[next].req.clone(),
                                   t0 + trace[next].at);
                next += 1;
            }
            if next >= trace.len() && !self.has_work() {
                break;
            }
            let horizon = if next < trace.len() {
                Some(t0 + trace[next].at)
            } else {
                None
            };
            out.clear();
            let progressed = self.step(horizon, &mut out)?;
            for r in out.drain(..) {
                on_done(r);
            }
            if !progressed {
                match horizon {
                    Some(h) if h > self.now => self.now = h,
                    // nothing can progress and nothing will arrive:
                    // bail instead of spinning forever
                    _ => bail!("fabric stalled with work pending"),
                }
            }
        }
        Ok(self.now - t0)
    }

    /// Replay a timed arrival trace to completion; returns
    /// (responses in completion order, elapsed virtual seconds).
    pub fn run_trace(
        &mut self, trace: Vec<TimedRequest>,
    ) -> Result<(Vec<Response>, f64)> {
        let mut out = Vec::new();
        let elapsed =
            self.run_trace_with(trace, |r| out.push(r))?;
        Ok((out, elapsed))
    }

    /// Router-stage counters (rejected / cancelled / timed out while
    /// queued at the front door).
    pub fn router_metrics(&self) -> &Metrics {
        &self.router.metrics
    }

    /// Fleet-wide merged metrics: router-stage counters plus every
    /// replica's counters and latency histograms. The counter sets
    /// are disjoint (replicas own `requests_in`/histograms, the
    /// router owns `rejected`), so the merge never double-counts.
    pub fn fleet_metrics(&self) -> Metrics {
        let mut m = self.router.metrics.clone();
        for rep in &self.replicas {
            m.merge(rep.metrics());
        }
        m
    }
}
