//! Serve loop: drain a workload through the scheduler and collect
//! responses + throughput (the serving examples and benches drive this).

use std::time::Instant;

use anyhow::Result;

use crate::runtime::{Engine, QuantMode};

use super::batcher::Scheduler;
use super::request::{Request, Response};

/// Configuration of a serve run.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub model: String,
    pub quant: QuantMode,
    pub c_vec: Option<Vec<f32>>,
    pub decode_batch: usize,
}

/// Run all `requests` to completion; returns (responses, wall seconds,
/// scheduler with final metrics).
pub fn serve_until_drained(engine: &mut Engine, cfg: &ServeConfig,
                           requests: Vec<Request>)
                           -> Result<(Vec<Response>, f64, Scheduler)> {
    let mut sched = Scheduler::new(engine, &cfg.model, cfg.quant,
                                   cfg.c_vec.clone(), cfg.decode_batch)?;
    for r in requests {
        sched.submit(r);
    }
    let t0 = Instant::now();
    let mut out = Vec::new();
    while sched.has_work() {
        out.extend(sched.tick(engine)?);
    }
    let wall = t0.elapsed().as_secs_f64();
    Ok((out, wall, sched))
}
