//! Serving metrics: counters + a fixed-bucket latency histogram.
//!
//! Counters saturate instead of wrapping: a million-request stress
//! run merged across a fleet must never panic in release or wrap in
//! debug, and a pinned `u64::MAX` is a visible, testable ceiling.

/// Simple log-scale latency histogram (seconds).
#[derive(Clone, Debug, Default)]
pub struct Histogram {
    /// bucket i counts samples < 1e-4 * 2^i seconds.
    counts: [u64; 24],
    sum: f64,
    n: u64,
    max: f64,
}

impl Histogram {
    pub fn record(&mut self, secs: f64) {
        self.record_n(secs, 1);
    }

    /// Record `n` identical samples at once (bulk path for merges and
    /// the hostile-input tests). Saturates instead of overflowing.
    pub fn record_n(&mut self, secs: f64, n: u64) {
        if n == 0 {
            return;
        }
        let mut b = 0usize;
        let mut edge = 1e-4;
        while secs >= edge && b + 1 < self.counts.len() {
            edge *= 2.0;
            b += 1;
        }
        self.counts[b] = self.counts[b].saturating_add(n);
        self.sum += secs * n as f64;
        self.n = self.n.saturating_add(n);
        self.max = self.max.max(secs);
    }

    /// Fold another histogram into this one (fleet-wide metrics
    /// merge). Bucket-exact: merging then reading a quantile equals
    /// recording every underlying sample into one histogram.
    pub fn merge(&mut self, other: &Histogram) {
        for (c, &o) in self.counts.iter_mut().zip(&other.counts) {
            *c = c.saturating_add(o);
        }
        self.sum += other.sum;
        self.n = self.n.saturating_add(other.n);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.sum / self.n as f64 }
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Approximate quantile from bucket upper edges.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let target = (q * self.n as f64).ceil() as u64;
        let mut acc = 0u64;
        let mut edge = 1e-4;
        for &c in &self.counts {
            acc = acc.saturating_add(c);
            if acc >= target {
                return edge;
            }
            edge *= 2.0;
        }
        self.max
    }
}

/// Aggregate serving metrics.
///
/// Ownership in the fabric is partitioned so a fleet-wide
/// [`Metrics::merge`] never double-counts: replicas own
/// `requests_in` / `requests_done` / the engine counters / the
/// latency histograms (plus `cancelled` / `timed_out` /
/// `preemptions` / `resumes` for work that reached them), while the
/// router owns `rejected` and the `cancelled` / `timed_out` of
/// requests that never left its queue.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub requests_in: u64,
    pub requests_done: u64,
    pub prefills: u64,
    pub decode_steps: u64,
    pub decode_tokens: u64,
    pub batch_occupancy_sum: u64,
    /// Refused by router admission control (queue full).
    pub rejected: u64,
    /// Cancelled by the client (queued or in flight).
    pub cancelled: u64,
    /// Expired past their deadline (queued or in flight).
    pub timed_out: u64,
    /// In-flight evictions to make room for interactive work.
    pub preemptions: u64,
    /// Preempted requests re-admitted for another episode.
    pub resumes: u64,
    pub ttft: Histogram,
    pub total_latency: Histogram,
}

impl Metrics {
    /// Mean decode-batch occupancy (tokens per decode step).
    pub fn mean_occupancy(&self) -> f64 {
        if self.decode_steps == 0 {
            0.0
        } else {
            self.batch_occupancy_sum as f64 / self.decode_steps as f64
        }
    }

    /// Fold another metrics block into this one (saturating).
    pub fn merge(&mut self, other: &Metrics) {
        self.requests_in =
            self.requests_in.saturating_add(other.requests_in);
        self.requests_done =
            self.requests_done.saturating_add(other.requests_done);
        self.prefills = self.prefills.saturating_add(other.prefills);
        self.decode_steps =
            self.decode_steps.saturating_add(other.decode_steps);
        self.decode_tokens =
            self.decode_tokens.saturating_add(other.decode_tokens);
        self.batch_occupancy_sum = self
            .batch_occupancy_sum
            .saturating_add(other.batch_occupancy_sum);
        self.rejected = self.rejected.saturating_add(other.rejected);
        self.cancelled =
            self.cancelled.saturating_add(other.cancelled);
        self.timed_out =
            self.timed_out.saturating_add(other.timed_out);
        self.preemptions =
            self.preemptions.saturating_add(other.preemptions);
        self.resumes = self.resumes.saturating_add(other.resumes);
        self.ttft.merge(&other.ttft);
        self.total_latency.merge(&other.total_latency);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_statistics() {
        let mut h = Histogram::default();
        for i in 1..=100 {
            h.record(i as f64 * 1e-3);
        }
        assert_eq!(h.count(), 100);
        assert!((h.mean() - 0.0505).abs() < 1e-9);
        assert!(h.quantile(0.5) >= 0.04 && h.quantile(0.5) <= 0.13,
                "p50 {}", h.quantile(0.5));
        assert!(h.quantile(1.0) >= 0.1);
        assert_eq!(h.max(), 0.1);
    }

    #[test]
    fn occupancy() {
        let mut m = Metrics::default();
        m.decode_steps = 4;
        m.batch_occupancy_sum = 10;
        assert!((m.mean_occupancy() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn quantiles_are_monotonic_and_bounded() {
        let mut h = Histogram::default();
        // bimodal: a fast mode near 1 ms and a slow tail near 0.5 s
        for _ in 0..90 {
            h.record(1.1e-3);
        }
        for _ in 0..10 {
            h.record(0.5);
        }
        let qs = [0.1, 0.5, 0.9, 0.95, 0.99, 1.0];
        let mut prev = 0.0;
        for q in qs {
            let v = h.quantile(q);
            assert!(v >= prev, "quantile({q}) = {v} < {prev}");
            assert!(v > 0.0);
            prev = v;
        }
        // p50 sits in the fast mode, p99 in the slow tail
        assert!(h.quantile(0.5) < 0.01, "{}", h.quantile(0.5));
        assert!(h.quantile(0.99) >= 0.25, "{}", h.quantile(0.99));
        assert_eq!(h.max(), 0.5);
        assert_eq!(h.count(), 100);
        let want_mean = (90.0 * 1.1e-3 + 10.0 * 0.5) / 100.0;
        assert!((h.mean() - want_mean).abs() < 1e-9);
    }

    #[test]
    fn empty_histogram_is_all_zeros() {
        let h = Histogram::default();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.quantile(0.99), 0.0);
        assert_eq!(h.max(), 0.0);
    }

    #[test]
    fn single_sample_pins_every_quantile_to_its_bucket_edge() {
        let mut h = Histogram::default();
        h.record(5e-3);
        // 5 ms lands in bucket 6 (first edge with 5e-3 < 1e-4 * 2^b);
        // with n = 1 every quantile must return exactly that edge,
        // computed by the same repeated doubling the bucket walk uses
        let mut edge = 1e-4;
        for _ in 0..6 {
            edge *= 2.0;
        }
        for q in [0.01, 0.5, 0.99, 1.0] {
            assert_eq!(h.quantile(q), edge, "q = {q}");
        }
        assert_eq!(h.count(), 1);
        assert_eq!(h.mean(), 5e-3);
        assert_eq!(h.max(), 5e-3);
    }

    #[test]
    fn all_equal_samples_pin_p50_equal_to_p99() {
        let mut h = Histogram::default();
        for _ in 0..100 {
            h.record(1e-3);
        }
        // 1 ms lands in bucket 4: 1e-4 * 2^4 = 1.6 ms upper edge
        let mut edge = 1e-4;
        for _ in 0..4 {
            edge *= 2.0;
        }
        assert_eq!(h.quantile(0.5), edge);
        assert_eq!(h.quantile(0.99), edge);
        assert_eq!(h.quantile(0.5), h.quantile(0.99));
        assert_eq!(h.max(), 1e-3);
    }

    #[test]
    fn u64_saturation_never_panics_or_wraps() {
        let mut h = Histogram::default();
        h.record_n(1e-3, u64::MAX);
        h.record_n(1e-3, u64::MAX); // would wrap without saturation
        h.record_n(0.5, u64::MAX); // second bucket saturates too
        assert_eq!(h.count(), u64::MAX);
        // quantile accumulation must also saturate, not wrap: p99 of
        // "MAX fast samples + MAX slow samples" stays in range and
        // the walk terminates at a real bucket edge
        let p99 = h.quantile(0.99);
        assert!(p99.is_finite() && p99 > 0.0, "{p99}");
        let p50 = h.quantile(0.5);
        assert!(p50 <= p99, "{p50} vs {p99}");
        assert!(h.mean().is_finite());
        assert_eq!(h.max(), 0.5);

        let mut a = Histogram::default();
        a.record_n(1e-3, u64::MAX);
        let mut b = Histogram::default();
        b.record_n(2e-3, 7);
        a.merge(&b); // saturating merge
        assert_eq!(a.count(), u64::MAX);
        assert!(a.quantile(0.5).is_finite());
    }

    #[test]
    fn record_n_zero_is_a_no_op() {
        let mut h = Histogram::default();
        h.record_n(1.0, 0);
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.max(), 0.0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn merge_equals_recording_into_one_histogram() {
        let mut one = Histogram::default();
        let mut left = Histogram::default();
        let mut right = Histogram::default();
        for i in 1..=50 {
            one.record(i as f64 * 1e-3);
            left.record(i as f64 * 1e-3);
        }
        for i in 51..=100 {
            one.record(i as f64 * 1e-3);
            right.record(i as f64 * 1e-3);
        }
        left.merge(&right);
        assert_eq!(left.count(), one.count());
        assert_eq!(left.max(), one.max());
        assert!((left.mean() - one.mean()).abs() < 1e-12);
        for q in [0.1, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(left.quantile(q), one.quantile(q), "q = {q}");
        }
    }

    #[test]
    fn metrics_merge_sums_counters_and_histograms() {
        let mut a = Metrics::default();
        a.requests_in = 3;
        a.requests_done = 2;
        a.rejected = 1;
        a.preemptions = 4;
        a.ttft.record(1e-3);
        let mut b = Metrics::default();
        b.requests_in = 5;
        b.requests_done = 5;
        b.cancelled = 2;
        b.timed_out = 1;
        b.resumes = 4;
        b.decode_steps = 10;
        b.batch_occupancy_sum = 30;
        b.ttft.record(2e-3);
        a.merge(&b);
        assert_eq!(a.requests_in, 8);
        assert_eq!(a.requests_done, 7);
        assert_eq!(a.rejected, 1);
        assert_eq!(a.cancelled, 2);
        assert_eq!(a.timed_out, 1);
        assert_eq!(a.preemptions, 4);
        assert_eq!(a.resumes, 4);
        assert_eq!(a.ttft.count(), 2);
        assert!((a.mean_occupancy() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn out_of_range_samples_clamp_to_edge_buckets() {
        let mut h = Histogram::default();
        h.record(0.0); // below the first bucket edge
        h.record(1e9); // far beyond the last bucket edge
        assert_eq!(h.count(), 2);
        // the huge sample clamps into the last bucket (~839 s edge);
        // the true maximum is still tracked exactly
        assert!(h.quantile(1.0) >= 800.0, "{}", h.quantile(1.0));
        assert_eq!(h.max(), 1e9);
        assert!(h.quantile(0.5) <= 1e-4 * 2.0);
    }
}
