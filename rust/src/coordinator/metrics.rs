//! Serving metrics: counters + a fixed-bucket latency histogram.

/// Simple log-scale latency histogram (seconds).
#[derive(Clone, Debug, Default)]
pub struct Histogram {
    /// bucket i counts samples < 1e-4 * 2^i seconds.
    counts: [u64; 24],
    sum: f64,
    n: u64,
    max: f64,
}

impl Histogram {
    pub fn record(&mut self, secs: f64) {
        let mut b = 0usize;
        let mut edge = 1e-4;
        while secs >= edge && b + 1 < self.counts.len() {
            edge *= 2.0;
            b += 1;
        }
        self.counts[b] += 1;
        self.sum += secs;
        self.n += 1;
        self.max = self.max.max(secs);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.sum / self.n as f64 }
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Approximate quantile from bucket upper edges.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let target = (q * self.n as f64).ceil() as u64;
        let mut acc = 0u64;
        let mut edge = 1e-4;
        for &c in &self.counts {
            acc += c;
            if acc >= target {
                return edge;
            }
            edge *= 2.0;
        }
        self.max
    }
}

/// Aggregate serving metrics.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub requests_in: u64,
    pub requests_done: u64,
    pub prefills: u64,
    pub decode_steps: u64,
    pub decode_tokens: u64,
    pub batch_occupancy_sum: u64,
    pub ttft: Histogram,
    pub total_latency: Histogram,
}

impl Metrics {
    /// Mean decode-batch occupancy (tokens per decode step).
    pub fn mean_occupancy(&self) -> f64 {
        if self.decode_steps == 0 {
            0.0
        } else {
            self.batch_occupancy_sum as f64 / self.decode_steps as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_statistics() {
        let mut h = Histogram::default();
        for i in 1..=100 {
            h.record(i as f64 * 1e-3);
        }
        assert_eq!(h.count(), 100);
        assert!((h.mean() - 0.0505).abs() < 1e-9);
        assert!(h.quantile(0.5) >= 0.04 && h.quantile(0.5) <= 0.13,
                "p50 {}", h.quantile(0.5));
        assert!(h.quantile(1.0) >= 0.1);
        assert_eq!(h.max(), 0.1);
    }

    #[test]
    fn occupancy() {
        let mut m = Metrics::default();
        m.decode_steps = 4;
        m.batch_occupancy_sum = 10;
        assert!((m.mean_occupancy() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn quantiles_are_monotonic_and_bounded() {
        let mut h = Histogram::default();
        // bimodal: a fast mode near 1 ms and a slow tail near 0.5 s
        for _ in 0..90 {
            h.record(1.1e-3);
        }
        for _ in 0..10 {
            h.record(0.5);
        }
        let qs = [0.1, 0.5, 0.9, 0.95, 0.99, 1.0];
        let mut prev = 0.0;
        for q in qs {
            let v = h.quantile(q);
            assert!(v >= prev, "quantile({q}) = {v} < {prev}");
            assert!(v > 0.0);
            prev = v;
        }
        // p50 sits in the fast mode, p99 in the slow tail
        assert!(h.quantile(0.5) < 0.01, "{}", h.quantile(0.5));
        assert!(h.quantile(0.99) >= 0.25, "{}", h.quantile(0.99));
        assert_eq!(h.max(), 0.5);
        assert_eq!(h.count(), 100);
        let want_mean = (90.0 * 1.1e-3 + 10.0 * 0.5) / 100.0;
        assert!((h.mean() - want_mean).abs() < 1e-9);
    }

    #[test]
    fn empty_histogram_is_all_zeros() {
        let h = Histogram::default();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.max(), 0.0);
    }

    #[test]
    fn out_of_range_samples_clamp_to_edge_buckets() {
        let mut h = Histogram::default();
        h.record(0.0); // below the first bucket edge
        h.record(1e9); // far beyond the last bucket edge
        assert_eq!(h.count(), 2);
        // the huge sample clamps into the last bucket (~839 s edge);
        // the true maximum is still tracked exactly
        assert!(h.quantile(1.0) >= 800.0, "{}", h.quantile(1.0));
        assert_eq!(h.max(), 1e9);
        assert!(h.quantile(0.5) <= 1e-4 * 2.0);
    }
}
