//! Scenario-mix workload generator for the serving stress harness.
//!
//! Produces deterministic timed request traces ([`TimedRequest`]) from
//! a seeded [`SplitMix64`]: steady streams, instantaneous bursts,
//! long-prompt heavy tails, mixed generation lengths (sampled through
//! the EXAQ Algo-2 sampling softmax), and chat-style early-EOS turns.
//! The same spec + seed always yields the byte-identical trace, which
//! is the foundation of the determinism assertions in
//! `rust/tests/serving_integration.rs`.

use crate::model::SamplingParams;
use crate::util::rng::SplitMix64;

use super::request::{Priority, Request, TimedRequest};

/// Arrival + size pattern of a synthetic workload.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Scenario {
    /// Uniform arrivals at `rate` requests/second, mid-size prompts,
    /// greedy decoding.
    Steady { rate: f64 },
    /// All requests arrive in `n_bursts` instantaneous spikes spaced
    /// `gap` seconds apart.
    Burst { n_bursts: usize, gap: f64 },
    /// Mostly short prompts with a heavy tail of near-`max_seq`
    /// prompts (truncation-path stress).
    LongPromptTail { rate: f64 },
    /// `max_new_tokens` spread over [1, 24] and stochastic sampling
    /// through the EXAQ Algorithm-2 softmax (`params.exaq`).
    MixedLengths { rate: f64 },
    /// Chat-style turns with a generous token budget that rely on the
    /// backend emitting EOS early (pair with `SimConfig::eos_bias`).
    ChatEarlyEos { rate: f64 },
}

/// Full description of a generated workload.
#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    pub scenario: Scenario,
    pub n_requests: usize,
    pub seed: u64,
    /// Vocabulary size of the serving model; prompt tokens are drawn
    /// from `[4, vocab)` to stay clear of the special ids.
    pub vocab: usize,
    /// Model context length (bounds prompt lengths).
    pub max_seq: usize,
    /// Fairness buckets: each request draws its tenant uniformly from
    /// `[0, tenants)`. 1 = the pre-fabric single-tenant behaviour.
    pub tenants: u32,
}

impl WorkloadSpec {
    pub fn new(scenario: Scenario, n_requests: usize, seed: u64,
               vocab: usize, max_seq: usize) -> Self {
        assert!(vocab > 8, "vocabulary too small for prompt sampling");
        assert!(max_seq >= 8, "context too short for prompt sampling");
        Self { scenario, n_requests, seed, vocab, max_seq, tenants: 1 }
    }

    /// Spread requests across `tenants` fairness buckets.
    pub fn with_tenants(mut self, tenants: u32) -> Self {
        assert!(tenants >= 1, "need at least one tenant");
        self.tenants = tenants;
        self
    }
}

fn prompt(rng: &mut SplitMix64, len: usize, vocab: usize) -> Vec<i32> {
    (0..len).map(|_| (4 + rng.below(vocab - 4)) as i32).collect()
}

/// Generate the deterministic timed trace for `spec`.
pub fn generate(spec: &WorkloadSpec) -> Vec<TimedRequest> {
    let mut rng = SplitMix64::new(spec.seed);
    // fabric annotations (tenant, mixed-tier priority) come from a
    // derived stream so the size/arrival stream above is byte-stable
    // against pre-fabric traces of the same seed
    let mut frng = SplitMix64::new(spec.seed ^ 0x7E77_A117);
    let mid = (spec.max_seq / 4).max(2);
    let mut out = Vec::with_capacity(spec.n_requests);
    for id in 0..spec.n_requests as u64 {
        let i = id as usize;
        let (at, plen, max_new, params) = match spec.scenario {
            Scenario::Steady { rate } => (
                i as f64 / rate.max(1e-9),
                2 + rng.below(mid),
                4 + rng.below(13),
                SamplingParams::greedy(),
            ),
            Scenario::Burst { n_bursts, gap } => (
                (i % n_bursts.max(1)) as f64 * gap,
                2 + rng.below(mid),
                8,
                SamplingParams::greedy(),
            ),
            Scenario::LongPromptTail { rate } => {
                // 1 in 8 requests (and always the first, so every
                // trace exercises truncation) carries a prompt at or
                // beyond the context length
                let plen = if i == 0 || rng.below(8) == 0 {
                    spec.max_seq - 2 + rng.below(spec.max_seq)
                } else {
                    2 + rng.below(mid)
                };
                (i as f64 / rate.max(1e-9), plen, 6,
                 SamplingParams::greedy())
            }
            Scenario::MixedLengths { rate } => (
                i as f64 / rate.max(1e-9),
                2 + rng.below(mid),
                1 + rng.below(24),
                SamplingParams::exaq(0.8, 2, -4.0),
            ),
            Scenario::ChatEarlyEos { rate } => (
                i as f64 / rate.max(1e-9),
                2 + rng.below(mid),
                spec.max_seq / 2,
                SamplingParams::greedy(),
            ),
        };
        let tenant = frng.below(spec.tenants.max(1) as usize) as u32;
        // scheduling tier per scenario: chat turns are interactive,
        // long-prompt tails are offline batch work, the mixed
        // scenario spreads across all three tiers
        let priority = match spec.scenario {
            Scenario::ChatEarlyEos { .. } => Priority::Interactive,
            Scenario::LongPromptTail { .. } => Priority::Batch,
            Scenario::MixedLengths { .. } => {
                match frng.below(3) {
                    0 => Priority::Interactive,
                    1 => Priority::Standard,
                    _ => Priority::Batch,
                }
            }
            _ => Priority::Standard,
        };
        out.push(TimedRequest {
            at,
            req: Request::new(id, prompt(&mut rng, plen, spec.vocab),
                              max_new.max(1), params)
                .with_tenant(tenant)
                .with_priority(priority),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(scenario: Scenario) -> WorkloadSpec {
        WorkloadSpec::new(scenario, 64, 42, 64, 64)
    }

    #[test]
    fn generation_is_deterministic() {
        let a = generate(&spec(Scenario::MixedLengths { rate: 100.0 }));
        let b = generate(&spec(Scenario::MixedLengths { rate: 100.0 }));
        assert_eq!(a.len(), 64);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.req.id, y.req.id);
            assert_eq!(x.req.prompt, y.req.prompt);
            assert_eq!(x.req.max_new_tokens, y.req.max_new_tokens);
        }
        let c = generate(&WorkloadSpec::new(
            Scenario::MixedLengths { rate: 100.0 }, 64, 43, 64, 64));
        assert!(a.iter().zip(&c).any(|(x, y)|
            x.req.prompt != y.req.prompt));
    }

    #[test]
    fn steady_arrivals_are_monotonic_and_tokens_in_vocab() {
        let t = generate(&spec(Scenario::Steady { rate: 50.0 }));
        for w in t.windows(2) {
            assert!(w[1].at >= w[0].at);
        }
        for r in &t {
            assert!(!r.req.prompt.is_empty());
            assert!(r.req.prompt.iter().all(|&x| (4..64).contains(&x)));
            assert!(r.req.max_new_tokens >= 1);
        }
    }

    #[test]
    fn burst_collapses_arrival_times() {
        let t = generate(&spec(Scenario::Burst { n_bursts: 4,
                                                 gap: 0.5 }));
        let mut times: Vec<f64> =
            t.iter().map(|r| r.at).collect();
        times.sort_by(f64::total_cmp);
        times.dedup();
        assert_eq!(times, vec![0.0, 0.5, 1.0, 1.5]);
    }

    #[test]
    fn long_tail_exceeds_context_sometimes() {
        let t = generate(&spec(Scenario::LongPromptTail { rate: 10.0 }));
        assert!(t.iter().any(|r| r.req.prompt.len() >= 62),
                "expected at least one near/over-context prompt");
        assert!(t.iter().any(|r| r.req.prompt.len() < 20));
    }

    #[test]
    fn tenants_and_priorities_annotate_deterministically() {
        // default: single tenant, scenario-typed priorities
        let t = generate(&spec(Scenario::ChatEarlyEos { rate: 10.0 }));
        assert!(t.iter().all(|r| r.req.tenant == 0));
        assert!(t.iter().all(|r| {
            r.req.priority == Priority::Interactive
        }));
        let t = generate(&spec(Scenario::LongPromptTail {
            rate: 10.0,
        }));
        assert!(t.iter().all(|r| r.req.priority == Priority::Batch));

        // multi-tenant: every bucket shows up, assignment is stable
        let s = spec(Scenario::MixedLengths { rate: 10.0 })
            .with_tenants(4);
        let a = generate(&s);
        let b = generate(&s);
        for t in 0..4u32 {
            assert!(a.iter().any(|r| r.req.tenant == t),
                    "tenant {t} never drawn");
        }
        for p in Priority::ALL {
            assert!(a.iter().any(|r| r.req.priority == p),
                    "{} never drawn", p.name());
        }
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.req.tenant, y.req.tenant);
            assert_eq!(x.req.priority, y.req.priority);
        }

        // the size/arrival stream is byte-stable against the
        // single-tenant trace of the same seed (annotations draw
        // from a derived stream)
        let single = generate(&spec(Scenario::MixedLengths {
            rate: 10.0,
        }));
        for (x, y) in a.iter().zip(&single) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.req.prompt, y.req.prompt);
            assert_eq!(x.req.max_new_tokens, y.req.max_new_tokens);
        }
    }

    #[test]
    fn mixed_lengths_uses_exaq_sampling() {
        let t = generate(&spec(Scenario::MixedLengths { rate: 10.0 }));
        assert!(t.iter().all(|r| r.req.params.exaq == Some((2, -4.0))));
        let lens: Vec<usize> =
            t.iter().map(|r| r.req.max_new_tokens).collect();
        assert!(lens.iter().any(|&l| l <= 4));
        assert!(lens.iter().any(|&l| l >= 16));
    }
}
