//! The front door of the serving fabric: admission control, priority
//! tiers, and per-tenant fairness over a fleet of replicas.
//!
//! The router holds all not-yet-dispatched work in three priority
//! tiers ([`Priority::ALL`]), each a set of per-tenant FIFO queues
//! drained round-robin. Dispatch order is therefore a pure function
//! of (arrival order, request fields) — no wall-clock, no randomness,
//! no map-iteration nondeterminism (`BTreeMap` only) — which is what
//! lets the million-request stress suite assert bit-identical reruns.
//!
//! The router never talks to a backend: the fabric driver
//! (`super::server::Fabric`) pulls [`Assignment`]s out of
//! [`Router::next`] and pushes them into replicas, and hands
//! preempted work back through [`Router::requeue`].

use std::collections::{BTreeMap, VecDeque};

use super::metrics::Metrics;
use super::replica::Assignment;
use super::request::{
    FinishReason, Priority, Request, Response, NO_REPLICA,
};

/// Router policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct RouterConfig {
    /// Reject new submits once this many requests are queued at the
    /// router (0 = unbounded).
    pub max_queue: usize,
    /// Allow evicting less-urgent in-flight work when interactive
    /// requests are starved of capacity.
    pub preemption: bool,
    /// Reserved for stochastic policies; current policies are all
    /// deterministic and ignore it.
    pub seed: u64,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self { max_queue: 0, preemption: true, seed: 0 }
    }
}

/// Queue entry: the assignment plus its global arrival number, which
/// makes FIFO-within-tenant explicit and cheap to assert in tests.
#[derive(Debug)]
struct Queued {
    asg: Assignment,
    arrival: u64,
}

/// One priority tier: per-tenant FIFO queues drained round-robin.
/// The cursor remembers the last-served tenant; the next dispatch
/// starts strictly after it in sorted-tenant order (wrapping), so no
/// tenant can starve another inside its tier.
#[derive(Debug, Default)]
struct TierQueue {
    queues: BTreeMap<u32, VecDeque<Queued>>,
    last: Option<u32>,
    len: usize,
}

impl TierQueue {
    fn push_back(&mut self, q: Queued) {
        self.queues.entry(q.asg.req.tenant).or_default().push_back(q);
        self.len += 1;
    }

    fn push_front(&mut self, q: Queued) {
        self.queues.entry(q.asg.req.tenant).or_default().push_front(q);
        self.len += 1;
    }

    /// Pop from the tenant strictly after the fairness cursor
    /// (wrapping round the sorted tenant set).
    fn pop_round_robin(&mut self) -> Option<Queued> {
        let tenant = {
            let after = self.last.map(|t| {
                self.queues
                    .range((
                        std::ops::Bound::Excluded(t),
                        std::ops::Bound::Unbounded,
                    ))
                    .next()
                    .map(|(k, _)| *k)
            });
            match after {
                Some(Some(t)) => t,
                // cursor past the end (or unset): wrap to the first
                _ => *self.queues.keys().next()?,
            }
        };
        let q = self.queues.get_mut(&tenant)?.pop_front()?;
        if self.queues.get(&tenant).is_some_and(VecDeque::is_empty) {
            self.queues.remove(&tenant);
        }
        self.last = Some(tenant);
        self.len -= 1;
        Some(q)
    }

    fn remove_id(&mut self, id: u64) -> Option<Queued> {
        let mut hit: Option<(u32, usize)> = None;
        for (t, dq) in self.queues.iter() {
            if let Some(i) =
                dq.iter().position(|q| q.asg.req.id == id)
            {
                hit = Some((*t, i));
                break;
            }
        }
        let (t, i) = hit?;
        let q = self.queues.get_mut(&t)?.remove(i)?;
        if self.queues.get(&t).is_some_and(VecDeque::is_empty) {
            self.queues.remove(&t);
        }
        self.len -= 1;
        Some(q)
    }
}

/// The front-door router.
pub struct Router {
    cfg: RouterConfig,
    tiers: Vec<TierQueue>,
    arrivals: u64,
    /// Queued entries carrying a deadline. Keeps
    /// [`Router::sweep_timeouts`] O(1) per step when no queued work
    /// has one — the common case in the million-request storms, where
    /// a full-queue walk per step would go quadratic in the backlog.
    timed: usize,
    /// Queued-stage counters only (`rejected`/`timed_out`/
    /// `cancelled`); replicas own `requests_in` and the latency
    /// histograms, so a fleet-wide merge never double-counts.
    pub metrics: Metrics,
}

impl Router {
    pub fn new(cfg: RouterConfig) -> Self {
        Self {
            cfg,
            tiers: (0..Priority::ALL.len())
                .map(|_| TierQueue::default())
                .collect(),
            arrivals: 0,
            timed: 0,
            metrics: Metrics::default(),
        }
    }

    /// Admission control: accept the request into its tier (true) or
    /// reject it because the router queue is full (false).
    pub fn submit(&mut self, req: Request, now: f64) -> bool {
        if self.cfg.max_queue > 0
            && self.queued_len() >= self.cfg.max_queue
        {
            self.metrics.rejected += 1;
            return false;
        }
        let tier = req.priority.index();
        if req.timeout.is_some() {
            self.timed += 1;
        }
        let asg = Assignment::fresh(req, now);
        let arrival = self.arrivals;
        self.arrivals += 1;
        self.tiers[tier].push_back(Queued { asg, arrival });
        true
    }

    /// Requeue preempted work at the head of its tenant's queue (it
    /// already waited once; no admission control on the way back in).
    pub fn requeue(&mut self, asg: Assignment) {
        let tier = asg.req.priority.index();
        if asg.req.timeout.is_some() {
            self.timed += 1;
        }
        let arrival = self.arrivals;
        self.arrivals += 1;
        self.tiers[tier].push_front(Queued { asg, arrival });
    }

    /// Next assignment to dispatch: strictest tier first, round-robin
    /// across tenants inside the tier.
    pub fn next(&mut self) -> Option<Assignment> {
        for tier in self.tiers.iter_mut() {
            if let Some(q) = tier.pop_round_robin() {
                if q.asg.req.timeout.is_some() {
                    self.timed = self.timed.saturating_sub(1);
                }
                return Some(q.asg);
            }
        }
        None
    }

    /// Cancel a queued request. In-flight work is the replicas'
    /// business; the fabric tries the router first, then each
    /// replica.
    pub fn cancel(&mut self, id: u64, now: f64) -> Option<Response> {
        for tier in self.tiers.iter_mut() {
            if let Some(q) = tier.remove_id(id) {
                if q.asg.req.timeout.is_some() {
                    self.timed = self.timed.saturating_sub(1);
                }
                self.metrics.cancelled += 1;
                return Some(exit_response(
                    q.asg,
                    FinishReason::Cancelled,
                    now,
                ));
            }
        }
        None
    }

    /// Expire queued requests whose deadline passed while waiting at
    /// the front door.
    pub fn sweep_timeouts(
        &mut self, now: f64, out: &mut Vec<Response>,
    ) {
        if self.timed == 0 {
            return;
        }
        for tier in self.tiers.iter_mut() {
            let tenants: Vec<u32> =
                tier.queues.keys().copied().collect();
            for t in tenants {
                let Some(dq) = tier.queues.get_mut(&t) else {
                    continue;
                };
                let mut i = 0;
                while i < dq.len() {
                    let expired = dq[i]
                        .asg
                        .req
                        .timeout
                        .map(|dt| now >= dq[i].asg.enqueued + dt)
                        .unwrap_or(false);
                    if expired {
                        if let Some(q) = dq.remove(i) {
                            tier.len -= 1;
                            self.timed =
                                self.timed.saturating_sub(1);
                            out.push(exit_response(
                                q.asg,
                                FinishReason::TimedOut,
                                now,
                            ));
                            self.metrics.timed_out += 1;
                        } else {
                            i += 1;
                        }
                    } else {
                        i += 1;
                    }
                }
                if tier.queues.get(&t).is_some_and(VecDeque::is_empty)
                {
                    tier.queues.remove(&t);
                }
            }
        }
    }

    /// Requests queued at the given priority.
    pub fn queued_at(&self, p: Priority) -> usize {
        self.tiers[p.index()].len
    }

    /// Total requests queued at the router.
    pub fn queued_len(&self) -> usize {
        self.tiers.iter().map(|t| t.len).sum()
    }

    /// Global arrival counter (monotone over submits + requeues).
    pub fn arrivals(&self) -> u64 {
        self.arrivals
    }
}

/// Response for work that never reached (or never resumed on) a
/// replica: tokens are whatever earlier episodes produced.
fn exit_response(
    asg: Assignment, finish: FinishReason, now: f64,
) -> Response {
    Response {
        id: asg.req.id,
        prompt_len: asg.req.prompt.len(),
        tokens: asg.prior,
        ttft: asg
            .first_token
            .map(|t| t - asg.enqueued)
            .unwrap_or(0.0),
        total_latency: now - asg.enqueued,
        tenant: asg.req.tenant,
        priority: asg.req.priority,
        replica: NO_REPLICA,
        finish,
        preemptions: asg.preemptions,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::SamplingParams;

    fn req(id: u64, tenant: u32, p: Priority) -> Request {
        Request::new(id, vec![4, 5], 4, SamplingParams::greedy())
            .with_tenant(tenant)
            .with_priority(p)
    }

    #[test]
    fn tiers_drain_strictest_first() {
        let mut r = Router::new(RouterConfig::default());
        assert!(r.submit(req(0, 0, Priority::Batch), 0.0));
        assert!(r.submit(req(1, 0, Priority::Standard), 0.0));
        assert!(r.submit(req(2, 0, Priority::Interactive), 0.0));
        let order: Vec<u64> = std::iter::from_fn(|| r.next())
            .map(|a| a.req.id)
            .collect();
        assert_eq!(order, vec![2, 1, 0]);
    }

    #[test]
    fn round_robin_across_tenants_within_tier() {
        let mut r = Router::new(RouterConfig::default());
        // tenant 0 floods first, tenant 1 and 2 arrive after
        for id in 0..4 {
            assert!(r.submit(req(id, 0, Priority::Standard), 0.0));
        }
        assert!(r.submit(req(10, 1, Priority::Standard), 0.0));
        assert!(r.submit(req(20, 2, Priority::Standard), 0.0));
        let order: Vec<u64> = std::iter::from_fn(|| r.next())
            .map(|a| a.req.id)
            .collect();
        // fair interleave, not 0,1,2,3,10,20
        assert_eq!(order, vec![0, 10, 20, 1, 2, 3]);
    }

    #[test]
    fn admission_control_rejects_past_max_queue() {
        let mut r = Router::new(RouterConfig {
            max_queue: 2,
            ..RouterConfig::default()
        });
        assert!(r.submit(req(0, 0, Priority::Standard), 0.0));
        assert!(r.submit(req(1, 0, Priority::Standard), 0.0));
        assert!(!r.submit(req(2, 0, Priority::Standard), 0.0));
        assert_eq!(r.metrics.rejected, 1);
        assert_eq!(r.queued_len(), 2);
    }

    #[test]
    fn cancel_and_timeout_leave_queue_consistent() {
        let mut r = Router::new(RouterConfig::default());
        assert!(r.submit(req(0, 0, Priority::Standard), 0.0));
        assert!(r.submit(
            req(1, 1, Priority::Standard).with_timeout(0.5),
            0.0,
        ));
        let c = r.cancel(0, 0.1).expect("queued cancel hits");
        assert_eq!(c.finish, FinishReason::Cancelled);
        assert_eq!(c.replica, NO_REPLICA);
        assert!(r.cancel(99, 0.1).is_none());
        let mut out = Vec::new();
        r.sweep_timeouts(1.0, &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].id, 1);
        assert_eq!(out[0].finish, FinishReason::TimedOut);
        assert_eq!(r.queued_len(), 0);
        assert_eq!(r.metrics.cancelled, 1);
        assert_eq!(r.metrics.timed_out, 1);
        assert!(r.next().is_none());
    }

    #[test]
    fn timeout_bookkeeping_survives_pop_and_requeue() {
        // the sweep fast-path is gated on a counter of queued
        // deadline-carrying entries; popping must decrement it and
        // requeueing preempted work must restore it, or deadlines
        // silently stop firing
        let mut r = Router::new(RouterConfig::default());
        assert!(r.submit(
            req(0, 0, Priority::Standard).with_timeout(0.1),
            0.0,
        ));
        let a = r.next().expect("queued assignment pops");
        r.requeue(a); // still carries its deadline
        let mut out = Vec::new();
        r.sweep_timeouts(1.0, &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].finish, FinishReason::TimedOut);
        assert_eq!(r.queued_len(), 0);
        assert_eq!(r.metrics.timed_out, 1);
    }

    #[test]
    fn requeue_goes_to_the_front_of_its_tenant() {
        let mut r = Router::new(RouterConfig::default());
        assert!(r.submit(req(0, 0, Priority::Standard), 0.0));
        assert!(r.submit(req(1, 0, Priority::Standard), 0.0));
        let a = r.next().expect("one queued");
        assert_eq!(a.req.id, 0);
        let mut back = a;
        back.preemptions = 1;
        r.requeue(back);
        let order: Vec<u64> = std::iter::from_fn(|| r.next())
            .map(|x| x.req.id)
            .collect();
        assert_eq!(order, vec![0, 1]);
    }
}
