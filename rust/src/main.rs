//! `repro` — CLI for the EXAQ reproduction.
//!
//! Subcommands map one-to-one onto the experiment index in DESIGN.md:
//!
//!   solve-clip    C*(sigma, M) from the analytic model        (Fig. 3)
//!   fit-table1    regenerate the linear approximation         (Table 1)
//!   mse-curve     MSE_clip/MSE_quant/total vs C               (Fig. 2)
//!   breakdown     op-level runtime shares                     (Fig. 1)
//!   calibrate     runtime calibration + Fig. 6 series         (Fig. 6)
//!   eval          accuracy tables                             (Tab. 2/4/5/6)
//!   generate      greedy/temperature generation (quickstart)
//!   serve-demo    batched serving demo over the coordinator
//!   stress        deterministic serving stress run on the SimBackend
//!                 (no artifacts needed; virtual-clock latency report)
//!   lint          determinism lint over the repo tree
//!                 (exit 0 clean / 1 violations / 2 internal error)
//!   compare       bench regression gate over two BENCH_*.json files
//!                 (exit 0 pass / 1 regression / 2 bad input; a
//!                 missing *baseline* file passes with a note so the
//!                 gate can ride before baselines are committed)
//!   selftest      engine smoke: load bundle, run one prefill

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::rc::Rc;

use exaq_repro::util::clock::{Stopwatch, VirtualClock, WallClock};
use exaq_repro::util::error::{anyhow, bail, Result};

use exaq_repro::calib;
use exaq_repro::coordinator::{serve_trace, serve_until_drained,
                              workload, Fabric, FabricConfig, Request,
                              RouterConfig, Scenario, ServeConfig,
                              TimedRequest, WorkloadSpec};
use exaq_repro::cost::{GemmPrecision, MachineModel, TransformerShape};
use exaq_repro::eval::{eval_task, family_world_seed, mean_std, World,
                       ALL_TASKS};
use exaq_repro::exaq::fit::fit_table1;
use exaq_repro::exaq::mc::simulated_optimal_clip;
use exaq_repro::exaq::mse::MseModel;
use exaq_repro::exaq::solver::{optimal_clip, optimal_clip_mean_zero};
use exaq_repro::exaq::{clip_exaq, clip_naive};
use exaq_repro::model::{SamplingParams, Tokenizer};
use exaq_repro::report::{f as fnum, pct, Table};
use exaq_repro::runtime::{Engine, QuantMode, SimBackend, SimConfig};

/// Tiny flag parser: `--key value` pairs + positional subcommand,
/// with the remaining positionals kept in order (`compare` takes two
/// file paths).
struct Args {
    flags: BTreeMap<String, String>,
    positionals: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> (Option<String>, Args) {
        let mut flags = BTreeMap::new();
        let mut positionals = Vec::new();
        let mut cmd = None;
        let mut i = 0;
        while i < argv.len() {
            if let Some(k) = argv[i].strip_prefix("--") {
                let v = argv.get(i + 1).cloned().unwrap_or_default();
                flags.insert(k.to_string(), v);
                i += 2;
            } else {
                if cmd.is_none() {
                    cmd = Some(argv[i].clone());
                } else {
                    positionals.push(argv[i].clone());
                }
                i += 1;
            }
        }
        (cmd, Args { flags, positionals })
    }

    fn get(&self, k: &str, default: &str) -> String {
        self.flags.get(k).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_f64(&self, k: &str, default: f64) -> f64 {
        self.flags.get(k).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn get_usize(&self, k: &str, default: usize) -> usize {
        self.flags.get(k).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get("artifacts", "artifacts"))
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, args) = Args::parse(&argv);
    match cmd.as_deref() {
        Some("solve-clip") => cmd_solve_clip(&args),
        Some("fit-table1") => cmd_fit_table1(&args),
        Some("mse-curve") => cmd_mse_curve(&args),
        Some("breakdown") => cmd_breakdown(&args),
        Some("calibrate") => cmd_calibrate(&args),
        Some("eval") => cmd_eval(&args),
        Some("damage") => cmd_damage(&args),
        Some("generate") => cmd_generate(&args),
        Some("serve-demo") => cmd_serve_demo(&args),
        Some("stress") => cmd_stress(&args),
        Some("lint") => std::process::exit(cmd_lint(&args)),
        Some("compare") => std::process::exit(cmd_compare(&args)),
        Some("selftest") => cmd_selftest(&args),
        other => {
            eprintln!("usage: repro <solve-clip|fit-table1|mse-curve|\
                       breakdown|calibrate|eval|generate|serve-demo|\
                       stress|lint|compare|selftest> [--flags]");
            if let Some(o) = other {
                bail!("unknown command {o}");
            }
            Ok(())
        }
    }
}

fn cmd_solve_clip(args: &Args) -> Result<()> {
    let sigma = args.get_f64("sigma", 1.0);
    let bits = args.get_usize("bits", 2) as u32;
    let c = optimal_clip(sigma, bits);
    let c0 = optimal_clip_mean_zero(sigma, bits);
    let sim = simulated_optimal_clip(sigma, bits, 20, 1234);
    println!("sigma={sigma} M={bits}");
    println!("  C* (max-shifted protocol)  = {c:.4}");
    println!("  C* (literal mean-0 model)  = {c0:.4}");
    println!("  C* (monte-carlo simulation)= {sim:.4}");
    Ok(())
}

fn cmd_fit_table1(args: &Args) -> Result<()> {
    let mut t = Table::new(
        "Table 1 — linear approximation of C*(sigma)",
        &["M", "ours slope", "ours intercept", "paper slope",
          "paper intercept", "max residual"]);
    let paper = [(2u32, -1.66, -1.85), (3, -1.75, -2.06)];
    for bits in [2u32, 3, 4] {
        let fit = fit_table1(bits);
        let (ps, pi) = paper
            .iter()
            .find(|(b, _, _)| *b == bits)
            .map(|&(_, s, i)| (fnum(s, 2), fnum(i, 2)))
            .unwrap_or(("-".into(), "-".into()));
        t.row(&[bits.to_string(), fnum(fit.slope, 3),
                fnum(fit.intercept, 3), ps, pi,
                fnum(fit.max_residual, 3)]);
    }
    println!("{}", t.to_markdown());
    if !args.get("csv", "").is_empty() {
        exaq_repro::report::write_csv(&args.get("csv", ""), &t)?;
    }
    Ok(())
}

fn cmd_mse_curve(args: &Args) -> Result<()> {
    let sigma = args.get_f64("sigma", 1.0);
    let bits = args.get_usize("bits", 2) as u32;
    let model = MseModel::max_shifted(sigma, bits);
    let mut t = Table::new(
        "Fig. 2 — distortion vs clip threshold",
        &["C", "MSE_quant", "MSE_clip", "MSE_total"]);
    for p in model.curve(-6.0 * sigma - 4.0, -0.2, 60) {
        t.row(&[fnum(p.c, 3), format!("{:.3e}", p.quant),
                format!("{:.3e}", p.clip), format!("{:.3e}", p.total)]);
    }
    println!("{}", t.to_markdown());
    Ok(())
}

fn cmd_breakdown(_args: &Args) -> Result<()> {
    let m = MachineModel::default();
    let llama7b = TransformerShape {
        layers: 32, d_model: 4096, n_heads: 32, d_ff: 11008, seq: 2048,
        batch: 1, vocab: 32000,
    };
    let mut t = Table::new(
        "Fig. 1 — runtime share by op type (LLaMA-2-7B shape)",
        &["scenario", "gemm", "softmax", "elementwise"]);
    for (name, prec, bits) in [
        ("BF16 + original softmax", GemmPrecision::Bf16, None),
        ("FP8  + original softmax", GemmPrecision::Fp8, None),
        ("BF16 + EXAQ 2-bit", GemmPrecision::Bf16, Some(2)),
        ("FP8  + EXAQ 2-bit", GemmPrecision::Fp8, Some(2)),
    ] {
        let shares = m.breakdown(llama7b, prec, bits);
        t.row(&[name.to_string(), pct(shares[0].share),
                pct(shares[1].share), pct(shares[2].share)]);
    }
    println!("{}", t.to_markdown());
    Ok(())
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let model = args.get("model", "s");
    let mut engine = Engine::load(&dir)?;
    let cal = calib::calibrate(&mut engine, &model)?;
    let mut t = Table::new(
        &format!("Calibration — model {model} (Fig. 6 aggregate)"),
        &["layer", "sigma", "min", "mean", "C_exaq2", "C_naive"]);
    let e2 = clip_exaq(&cal.layers, 2);
    let nv = clip_naive(&cal.layers);
    for (i, l) in cal.layers.iter().enumerate() {
        t.row(&[i.to_string(), fnum(l.sigma, 3), fnum(l.min, 2),
                fnum(l.mean, 3), fnum(e2[i] as f64, 3),
                fnum(nv[i] as f64, 3)]);
    }
    println!("{}", t.to_markdown());
    if !args.get("fig6-csv", "").is_empty() {
        let mut c = Table::new("", &["iteration", "layer", "sigma"]);
        for (it, row) in cal.fig6_sigma.iter().enumerate() {
            for (l, s) in row.iter().enumerate() {
                c.row(&[it.to_string(), l.to_string(), fnum(*s, 4)]);
            }
        }
        exaq_repro::report::write_csv(&args.get("fig6-csv", ""), &c)?;
        println!("wrote {}", args.get("fig6-csv", ""));
    }
    if let Ok(py) = calib::load_calibration(&dir, &model) {
        let drift = cal
            .layers
            .iter()
            .zip(&py.layers)
            .map(|(a, b)| (a.sigma - b.sigma).abs())
            .fold(0.0, f64::max);
        println!("max sigma drift vs build-time calibration.json: \
                  {drift:.4}");
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let models: Vec<String> = args.get("models", "s,m")
        .split(',').map(str::to_string).collect();
    let n = args.get_usize("n", 30);
    let seeds = args.get_usize("seeds", 1);
    let mut engine = Engine::load(&dir)?;

    for model in &models {
        let entry = engine.manifest.model(model)?.clone();
        let world = World::build(family_world_seed(entry.family));
        let cal = calib::load_calibration(&dir, model)
            .or_else(|_| calib::calibrate(&mut engine, model))?;
        let configs: Vec<(String, QuantMode, Option<Vec<f32>>)> = vec![
            ("NONE".into(), QuantMode::None, None),
            ("NAIVE-INT2".into(), QuantMode::Static { bits: 2 },
             Some(clip_naive(&cal.layers))),
            ("EXAQ-INT2".into(), QuantMode::Static { bits: 2 },
             Some(clip_exaq(&cal.layers, 2))),
            ("NAIVE-INT3".into(), QuantMode::Static { bits: 3 },
             Some(clip_naive(&cal.layers))),
            ("EXAQ-INT3".into(), QuantMode::Static { bits: 3 },
             Some(clip_exaq(&cal.layers, 3))),
        ];
        let mut headers = vec!["config".to_string()];
        headers.extend(ALL_TASKS.iter().map(|t| t.name().to_string()));
        headers.push("avg".into());
        let hdr_refs: Vec<&str> =
            headers.iter().map(String::as_str).collect();
        let mut t = Table::new(
            &format!("Table 2 analogue — model {model} \
                      ({} params, n={n}, seeds={seeds})",
                     entry.config.n_params),
            &hdr_refs);
        let mut sig_t = Table::new(
            &format!("Table 4 analogue — per-task std over {seeds} \
                      seeds, model {model}"),
            &hdr_refs);
        for (name, quant, c_vec) in &configs {
            let mut cells = vec![name.clone()];
            let mut sig_cells = vec![name.clone()];
            let mut accs_avg = Vec::new();
            for task in ALL_TASKS {
                let mut per_seed = Vec::new();
                for s in 0..seeds {
                    let r = eval_task(&mut engine, model, *quant,
                                      c_vec.as_deref(), task, &world, n,
                                      1000 + s as u64 * 7919)?;
                    per_seed.push(r.accuracy * 100.0);
                }
                let (m, sd) = mean_std(&per_seed);
                cells.push(fnum(m, 1));
                sig_cells.push(fnum(sd, 2));
                accs_avg.push(m);
            }
            let avg: f64 =
                accs_avg.iter().sum::<f64>() / accs_avg.len() as f64;
            cells.push(fnum(avg, 1));
            sig_cells.push("-".into());
            t.row(&cells);
            sig_t.row(&sig_cells);
            eprintln!("[eval] {model} {name} done");
        }
        println!("{}", t.to_markdown());
        if seeds > 1 {
            println!("{}", sig_t.to_markdown());
        }
        if !args.get("csv", "").is_empty() {
            exaq_repro::report::write_csv(
                &format!("{}_{}.csv", args.get("csv", ""), model), &t)?;
        }
    }
    Ok(())
}

/// Distribution-level quantization damage: mean KL(NONE || config) of the
/// next-token distributions over held-out corpus text. Accuracy on the
/// synthetic tasks saturates (they are easier than real NLP suites), so
/// this is the sensitive analogue of Table 2's degradation axis — the
/// EXAQ < NAIVE ordering at INT2 shows here (EXPERIMENTS.md §Table 2).
fn cmd_damage(args: &Args) -> Result<()> {
    use exaq_repro::eval::corpus::generate_tokens;
    let dir = artifacts_dir(args);
    let models: Vec<String> = args.get("models", "s,m,l")
        .split(',').map(str::to_string).collect();
    let n_batches = args.get_usize("batches", 4);
    let mut engine = Engine::load(&dir)?;
    let seq = engine.manifest.seq;
    let tok = Tokenizer::from_manifest(&engine.manifest);

    let mut t = Table::new(
        "Quantization damage — mean KL(NONE || config), nats/token",
        &["model", "NAIVE-INT2", "EXAQ-INT2", "NAIVE-INT3",
          "EXAQ-INT3", "EXAQ/NAIVE @INT2"]);
    for model in &models {
        let entry = engine.manifest.model(model)?.clone();
        let world = World::build(family_world_seed(entry.family));
        let cal = calib::load_calibration(&dir, model)
            .or_else(|_| calib::calibrate(&mut engine, model))?;
        let stream = generate_tokens(&world, &tok, 987654,
                                     n_batches * 8 * seq + 1);
        let mut base = Vec::new();
        let mut kls = BTreeMap::new();
        let configs: Vec<(String, QuantMode, Option<Vec<f32>>)> = vec![
            ("NAIVE-INT2".into(), QuantMode::Static { bits: 2 },
             Some(clip_naive(&cal.layers))),
            ("EXAQ-INT2".into(), QuantMode::Static { bits: 2 },
             Some(clip_exaq(&cal.layers, 2))),
            ("NAIVE-INT3".into(), QuantMode::Static { bits: 3 },
             Some(clip_naive(&cal.layers))),
            ("EXAQ-INT3".into(), QuantMode::Static { bits: 3 },
             Some(clip_exaq(&cal.layers, 3))),
        ];
        for b in 0..n_batches {
            let lo = b * 8 * seq;
            let tokens = exaq_repro::runtime::HostTensor::i32(
                stream[lo..lo + 8 * seq].to_vec(), &[8, seq]);
            let (lg0, _) =
                engine.prefill(model, QuantMode::None, &tokens, None)?;
            base.clear();
            base.extend_from_slice(lg0.as_f32()?);
            let vocab = lg0.shape[2];
            for (name, quant, c_vec) in &configs {
                let (lg, _) = engine.prefill(model, *quant, &tokens,
                                             c_vec.as_deref())?;
                let q = lg.as_f32()?;
                let mut kl_sum = 0.0f64;
                let rows = base.len() / vocab;
                for r in 0..rows {
                    kl_sum += kl_rows(&base[r * vocab..(r + 1) * vocab],
                                      &q[r * vocab..(r + 1) * vocab]);
                }
                *kls.entry(name.clone()).or_insert(0.0) +=
                    kl_sum / rows as f64 / n_batches as f64;
            }
        }
        let n2 = kls["NAIVE-INT2"];
        let e2 = kls["EXAQ-INT2"];
        t.row(&[model.clone(), format!("{n2:.4}"), format!("{e2:.4}"),
                format!("{:.4}", kls["NAIVE-INT3"]),
                format!("{:.4}", kls["EXAQ-INT3"]),
                fnum(e2 / n2, 3)]);
        eprintln!("[damage] {model} done");
    }
    println!("{}", t.to_markdown());
    if !args.get("csv", "").is_empty() {
        exaq_repro::report::write_csv(&args.get("csv", ""), &t)?;
    }
    Ok(())
}

fn kl_rows(p_logits: &[f32], q_logits: &[f32]) -> f64 {
    // KL(softmax(p) || softmax(q))
    let lse = |xs: &[f32]| {
        let m = xs.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
        m + xs.iter().map(|&x| ((x as f64) - m).exp()).sum::<f64>().ln()
    };
    let zp = lse(p_logits);
    let zq = lse(q_logits);
    let mut kl = 0.0;
    for (&lp, &lq) in p_logits.iter().zip(q_logits) {
        let logp = lp as f64 - zp;
        let p = logp.exp();
        if p > 1e-12 {
            kl += p * (logp - (lq as f64 - zq));
        }
    }
    kl.max(0.0)
}

fn cmd_generate(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let model = args.get("model", "s");
    let prompt = args.get("prompt", "alice is in the");
    let max_new = args.get_usize("max-new", 12);
    let quant = parse_quant(&args.get("quant", "exaq2"))?;
    let mut engine = Engine::load(&dir)?;
    let tok = Tokenizer::from_manifest(&engine.manifest);
    let c_vec = c_vec_for(&dir, &mut engine, &model, quant)?;

    let cfg = ServeConfig {
        model: model.clone(),
        quant,
        c_vec,
        decode_batch: 8,
    };
    let req = Request::new(0, tok.encode(&prompt)?, max_new,
                           SamplingParams::greedy());
    let (mut resp, wall, _) =
        serve_until_drained(&mut engine, &cfg, vec![req],
                            Rc::new(WallClock::new()))?;
    let r = resp.pop().ok_or_else(|| anyhow!("no response"))?;
    println!("prompt : {prompt}");
    println!("output : {}", tok.decode(&r.tokens));
    println!("({} tokens in {:.2}s, ttft {:.3}s)",
             r.tokens.len(), wall, r.ttft);
    Ok(())
}

fn cmd_serve_demo(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let model = args.get("model", "s");
    let n_req = args.get_usize("requests", 16);
    let quant = parse_quant(&args.get("quant", "exaq2"))?;
    let mut engine = Engine::load(&dir)?;
    let tok = Tokenizer::from_manifest(&engine.manifest);
    let c_vec = c_vec_for(&dir, &mut engine, &model, quant)?;
    let entry = engine.manifest.model(&model)?.clone();
    let world = World::build(family_world_seed(entry.family));
    let mut rng = exaq_repro::util::rng::SplitMix64::new(7);

    let reqs: Vec<Request> = (0..n_req as u64)
        .map(|id| {
            let inst = exaq_repro::eval::Task::Completion
                .generate(&world, &mut rng);
            Request::new(
                id,
                inst.prompt.iter()
                    .map(|w| tok.id(w).unwrap()).collect(),
                16,
                SamplingParams::greedy(),
            )
        })
        .collect();
    let cfg = ServeConfig { model, quant, c_vec, decode_batch: 8 };
    let (resps, wall, sched) =
        serve_until_drained(&mut engine, &cfg, reqs,
                            Rc::new(WallClock::new()))?;
    let toks: usize = resps.iter().map(|r| r.tokens.len()).sum();
    println!("served {} requests, {toks} tokens in {wall:.2}s \
              ({:.1} tok/s)", resps.len(), toks as f64 / wall);
    println!("p50 ttft {:.3}s  p50 latency {:.3}s  mean occupancy {:.2}",
             sched.metrics().ttft.quantile(0.5),
             sched.metrics().total_latency.quantile(0.5),
             sched.metrics().mean_occupancy());
    Ok(())
}

/// Deterministic serving stress run: scenario workload -> SimBackend
/// -> real Scheduler on a virtual clock. Needs no artifacts; the same
/// seed always prints the same numbers. With `--replicas N` (N > 1)
/// the trace runs through the router + N-replica fabric instead of
/// the single scheduler, printing per-replica occupancy/TTFT columns.
fn cmd_stress(args: &Args) -> Result<()> {
    let n = args.get_usize("requests", 1000);
    let seed = args.get_usize("seed", 7) as u64;
    let decode_batch = args.get_usize("decode-batch", 8);
    let replicas = args.get_usize("replicas", 1);
    let tenants = args.get_usize("tenants", 1).max(1) as u32;
    let rate = args.get_f64("rate", 200.0);
    let scenario = match args.get("scenario", "steady").as_str() {
        "steady" => Scenario::Steady { rate },
        "burst" => Scenario::Burst {
            n_bursts: args.get_usize("bursts", 4),
            gap: args.get_f64("gap", 0.25),
        },
        "long-tail" => Scenario::LongPromptTail { rate },
        "mixed" => Scenario::MixedLengths { rate },
        "chat" => Scenario::ChatEarlyEos { rate },
        other => bail!("unknown scenario {other} \
                        (steady|burst|long-tail|mixed|chat)"),
    };

    let clock = Rc::new(VirtualClock::new());
    let sim_cfg = SimConfig {
        seed: seed ^ 0x51B0,
        eos_bias: if matches!(scenario, Scenario::ChatEarlyEos { .. }) {
            0.15
        } else {
            0.0
        },
        ..SimConfig::default()
    };
    let spec = WorkloadSpec::new(scenario, n, seed, sim_cfg.vocab,
                                 sim_cfg.max_seq)
        .with_tenants(tenants);
    let cfg = ServeConfig {
        model: "sim".into(),
        quant: QuantMode::None,
        c_vec: None,
        decode_batch,
    };
    let trace = workload::generate(&spec);
    if replicas > 1 {
        return stress_fabric(args, n, seed, decode_batch, replicas,
                             &sim_cfg, &cfg, trace);
    }
    let mut sim = SimBackend::new(sim_cfg, clock.clone());
    let host0 = Stopwatch::start();
    let (resps, sim_secs, sched) =
        serve_trace(&mut sim, &cfg, trace, clock)?;
    let host_secs = host0.seconds();

    if resps.len() != n {
        bail!("stress run lost requests: {} of {n} completed",
              resps.len());
    }
    let toks: usize = resps.iter().map(|r| r.tokens.len()).sum();
    let m = sched.metrics();
    let mut t = Table::new(
        &format!("Serving stress — scenario {}, {n} requests, \
                  decode batch {decode_batch}, seed {seed}",
                 args.get("scenario", "steady")),
        &["metric", "value"]);
    t.row(&["simulated seconds".into(), fnum(sim_secs, 4)]);
    t.row(&["simulated tok/s".into(),
            fnum(toks as f64 / sim_secs.max(1e-12), 1)]);
    t.row(&["host seconds".into(), fnum(host_secs, 3)]);
    t.row(&["prefills".into(), m.prefills.to_string()]);
    t.row(&["decode steps".into(), m.decode_steps.to_string()]);
    t.row(&["mean batch occupancy".into(),
            fnum(m.mean_occupancy(), 2)]);
    t.row(&["p50 ttft (s)".into(), fnum(m.ttft.quantile(0.5), 5)]);
    t.row(&["p99 ttft (s)".into(), fnum(m.ttft.quantile(0.99), 5)]);
    t.row(&["p50 latency (s)".into(),
            fnum(m.total_latency.quantile(0.5), 5)]);
    t.row(&["p99 latency (s)".into(),
            fnum(m.total_latency.quantile(0.99), 5)]);
    t.row(&["max latency (s)".into(),
            fnum(m.total_latency.max(), 5)]);
    println!("{}", t.to_markdown());
    Ok(())
}

/// Multi-replica leg of `repro stress`: the same trace through the
/// router + N-replica fabric, with an aggregate table plus
/// per-replica occupancy/TTFT columns.
#[allow(clippy::too_many_arguments)]
fn stress_fabric(
    args: &Args, n: usize, seed: u64, decode_batch: usize,
    replicas: usize, sim_cfg: &SimConfig, serve: &ServeConfig,
    trace: Vec<TimedRequest>,
) -> Result<()> {
    let fab_cfg = FabricConfig {
        serve: serve.clone(),
        router: RouterConfig {
            max_queue: args.get_usize("max-queue", 0),
            preemption: args.get("preemption", "on") != "off",
            seed,
        },
        collect_stream: false,
    };
    let mk_cfg = sim_cfg.clone();
    let mut fab = Fabric::new(replicas, fab_cfg, |_, clock| {
        Ok(SimBackend::new(mk_cfg.clone(), clock))
    })?;
    let host0 = Stopwatch::start();
    let (resps, sim_secs) = fab.run_trace(trace)?;
    let host_secs = host0.seconds();
    let fleet = fab.fleet_metrics();
    if resps.len() + fleet.rejected as usize != n {
        bail!("fabric run lost requests: {} responses + {} rejected \
               of {n}", resps.len(), fleet.rejected);
    }
    let toks: usize = resps.iter().map(|r| r.tokens.len()).sum();
    let mut t = Table::new(
        &format!("Serving fabric — scenario {}, {n} requests, \
                  {replicas} replicas, decode batch {decode_batch}, \
                  seed {seed}",
                 args.get("scenario", "steady")),
        &["metric", "value"]);
    t.row(&["simulated seconds".into(), fnum(sim_secs, 4)]);
    t.row(&["simulated tok/s".into(),
            fnum(toks as f64 / sim_secs.max(1e-12), 1)]);
    t.row(&["host seconds".into(), fnum(host_secs, 3)]);
    t.row(&["prefills".into(), fleet.prefills.to_string()]);
    t.row(&["decode steps".into(), fleet.decode_steps.to_string()]);
    t.row(&["mean batch occupancy".into(),
            fnum(fleet.mean_occupancy(), 2)]);
    t.row(&["preemptions".into(), fleet.preemptions.to_string()]);
    t.row(&["resumes".into(), fleet.resumes.to_string()]);
    t.row(&["rejected".into(), fleet.rejected.to_string()]);
    t.row(&["timed out".into(), fleet.timed_out.to_string()]);
    t.row(&["p50 ttft (s)".into(),
            fnum(fleet.ttft.quantile(0.5), 5)]);
    t.row(&["p99 ttft (s)".into(),
            fnum(fleet.ttft.quantile(0.99), 5)]);
    t.row(&["p50 latency (s)".into(),
            fnum(fleet.total_latency.quantile(0.5), 5)]);
    t.row(&["p99 latency (s)".into(),
            fnum(fleet.total_latency.quantile(0.99), 5)]);
    t.row(&["max latency (s)".into(),
            fnum(fleet.total_latency.max(), 5)]);
    println!("{}", t.to_markdown());

    let mut pr = Table::new(
        "Per-replica",
        &["replica", "requests done", "prefills", "decode steps",
          "occupancy", "p50 ttft (s)", "p99 ttft (s)"]);
    for i in 0..fab.n_replicas() {
        let m = fab.replica(i).metrics();
        pr.row(&[i.to_string(),
                 m.requests_done.to_string(),
                 m.prefills.to_string(),
                 m.decode_steps.to_string(),
                 fnum(m.mean_occupancy(), 2),
                 fnum(m.ttft.quantile(0.5), 5),
                 fnum(m.ttft.quantile(0.99), 5)]);
    }
    println!("{}", pr.to_markdown());
    Ok(())
}

/// `repro lint [--root DIR] [--json FILE] [--list]` — run the
/// determinism lint pass over the repo tree. Returns the process exit
/// code per the contract: 0 clean, 1 violations, 2 internal error.
fn cmd_lint(args: &Args) -> i32 {
    if args.flags.contains_key("list") {
        for r in exaq_repro::lint::RULES {
            println!("{:<28} {}", r.name, r.summary);
        }
        return 0;
    }
    let root = PathBuf::from(args.get("root", "."));
    let report = match exaq_repro::lint::run_tree(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("repro lint: internal error: {e}");
            return 2;
        }
    };
    for v in &report.violations {
        println!("{v}");
    }
    let json_path = args.get("json", "");
    if !json_path.is_empty() {
        let j = report.to_json(&root.to_string_lossy());
        let body = j.to_string_pretty() + "\n";
        if let Err(e) = std::fs::write(&json_path, body) {
            eprintln!("repro lint: writing {json_path}: {e}");
            return 2;
        }
    }
    eprintln!("repro lint: {} files, {} violation(s), {} suppressed",
              report.files, report.violations.len(),
              report.suppressed);
    if report.is_clean() { 0 } else { 1 }
}

/// `repro compare <baseline.json> <current.json> [--threshold 0.10]
/// [--gate hard|soft] [--markdown]` — the bench regression gate.
/// Exit codes: 0 pass, 1 regression (hard gate only), 2
/// unreadable/invalid input. A missing *baseline* file passes with a
/// note (repos grow the baseline snapshot later); a missing
/// *current* file is an error. `EXAQ_BENCH_GATE=soft` downgrades
/// failures to warnings, same as `--gate soft` — for riding the gate
/// non-blocking in CI first. `--markdown` swaps the plain-text
/// report for a per-cell markdown table (deltas per metric); the
/// exit-code contract is identical in both modes. Because the flag
/// parser pairs `--key value`, put `--markdown` after the two file
/// paths.
fn cmd_compare(args: &Args) -> i32 {
    use exaq_repro::report::compare;
    use exaq_repro::util::json::Json;
    let [base_path, cur_path] = args.positionals.as_slice() else {
        eprintln!("usage: repro compare <baseline.json> \
                   <current.json> [--threshold 0.10] \
                   [--gate hard|soft] [--markdown]");
        return 2;
    };
    let base_body = match std::fs::read_to_string(base_path) {
        Ok(s) => s,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            println!("repro compare: baseline {base_path} not found \
                      — nothing to gate against (pass)");
            return 0;
        }
        Err(e) => {
            eprintln!("repro compare: reading {base_path}: {e}");
            return 2;
        }
    };
    let cur_body = match std::fs::read_to_string(cur_path) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("repro compare: reading {cur_path}: {e}");
            return 2;
        }
    };
    let parse = |path: &str, body: &str| match Json::parse(body) {
        Ok(j) => Some(j),
        Err(e) => {
            eprintln!("repro compare: parsing {path}: {e}");
            None
        }
    };
    let (Some(base), Some(cur)) = (parse(base_path, &base_body),
                                   parse(cur_path, &cur_body))
    else {
        return 2;
    };
    let threshold =
        args.get_f64("threshold", compare::DEFAULT_THRESHOLD);
    let soft = args.get("gate", "hard") == "soft"
        || std::env::var("EXAQ_BENCH_GATE").as_deref() == Ok("soft");
    let report = match compare::compare(&base, &cur, threshold) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("repro compare: {e}");
            return 2;
        }
    };
    if args.flags.contains_key("markdown") {
        print!("{}", report.render_markdown());
    } else {
        print!("{}", report.render());
    }
    if report.failed() {
        if soft {
            println!("repro compare: FAILED, but gate is soft \
                      (EXAQ_BENCH_GATE=soft) — not blocking");
            return 0;
        }
        return 1;
    }
    0
}

fn cmd_selftest(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let mut engine = Engine::load(&dir)?;
    let names: Vec<String> =
        engine.manifest.models.keys().cloned().collect();
    println!("bundle: {} models, vocab {}", names.len(),
             engine.manifest.vocab.len());
    let model = names.first().ok_or_else(|| anyhow!("empty bundle"))?
        .clone();
    let seq = engine.manifest.seq;
    let tokens = exaq_repro::runtime::HostTensor::i32(
        vec![1; seq], &[1, seq]);
    let (logits, _) =
        engine.prefill(&model, QuantMode::None, &tokens, None)?;
    println!("selftest OK: prefill {model} -> logits {:?}",
             logits.shape);
    Ok(())
}

fn parse_quant(s: &str) -> Result<QuantMode> {
    Ok(match s {
        "none" => QuantMode::None,
        "exaq2" | "naive2" | "q2" => QuantMode::Static { bits: 2 },
        "exaq3" | "naive3" | "q3" => QuantMode::Static { bits: 3 },
        other => bail!("unknown quant mode {other} \
                        (none|exaq2|exaq3|naive2|naive3)"),
    })
}

/// Derive the clip vector for a CLI quant selection (EXAQ coefficients).
fn c_vec_for(dir: &std::path::Path, engine: &mut Engine, model: &str,
             quant: QuantMode) -> Result<Option<Vec<f32>>> {
    let QuantMode::Static { bits } = quant else { return Ok(None) };
    let cal = calib::load_calibration(dir, model)
        .or_else(|_| calib::calibrate(engine, model))?;
    Ok(Some(clip_exaq(&cal.layers, bits)))
}
