//! PJRT runtime: loads the AOT bundle (`artifacts/`) and executes the
//! lowered HLO entry points. Python is never on this path — the bundle is
//! self-contained (HLO text + weights + manifest + calibration).
//!
//! * [`manifest`] — parses `manifest.json` (models, configs, artifact
//!   signatures).
//! * [`weights`]  — the TLW1 flat weight format (mirror of
//!   `python/compile/weights_io.py`).
//! * [`tensor`]   — host-side tensors crossing the PJRT boundary.
//! * [`engine`]   — PJRT client wrapper: compile cache, resident weight
//!   buffers, typed prefill/decode/stats calls.

pub mod engine;
pub mod manifest;
pub mod tensor;
pub mod weights;

pub use engine::{DecodeState, Engine, QuantMode};
pub use manifest::{ArtifactSpec, Manifest, ModelConfig, ModelEntry};
pub use tensor::HostTensor;
