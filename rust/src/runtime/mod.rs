//! L2 runtime: execution backends behind the [`InferenceBackend`]
//! trait.
//!
//! * [`backend`]  — the `InferenceBackend` contract the coordinator
//!   schedules against (prefill / decode / optional calibration stats).
//! * [`engine`]   — PJRT engine: loads the AOT bundle (`artifacts/`)
//!   and executes the lowered HLO entry points (Python is never on the
//!   request path). Real execution needs the `pjrt` feature; the
//!   default build ships the same-signature stub in [`pjrt`].
//! * [`sim`]      — deterministic in-process simulation backend:
//!   seeded logits through the real EXAQ Algo-2 pipeline, cost-model
//!   latency on a virtual clock. No artifacts required.
//! * [`manifest`] — parses `manifest.json` (models, configs, artifact
//!   signatures).
//! * [`weights`]  — the TLW1 flat weight format (mirror of
//!   `python/compile/weights_io.py`).
//! * [`tensor`]   — host-side tensors crossing the backend boundary.
//! * [`pjrt`]     — the PJRT FFI surface (re-export or stub).

pub mod backend;
pub mod engine;
pub mod manifest;
pub mod pjrt;
pub mod sim;
pub mod tensor;
pub mod weights;

pub use backend::InferenceBackend;
pub use engine::{DecodeState, Engine, QuantMode};
pub use manifest::{ArtifactSpec, Manifest, ModelConfig, ModelEntry};
pub use sim::{SimBackend, SimConfig};
pub use tensor::HostTensor;
