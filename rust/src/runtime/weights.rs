//! TLW1 flat weight format loader — byte-level mirror of
//! `python/compile/weights_io.py` (little-endian, f32 tensors).

use std::io::Read;
use std::path::Path;

use crate::util::error::{bail, Context, Result};

const MAGIC: &[u8; 4] = b"TLW1";

/// One named tensor from a weight file.
#[derive(Clone, Debug)]
pub struct WeightTensor {
    pub name: String,
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

/// Load every tensor from a TLW1 file, preserving file order (which is
/// the executable input order per the manifest).
pub fn load_weights(path: &Path) -> Result<Vec<WeightTensor>> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading weights {path:?}"))?;
    parse_weights(&bytes)
}

pub fn parse_weights(bytes: &[u8]) -> Result<Vec<WeightTensor>> {
    let mut cur = std::io::Cursor::new(bytes);
    let mut magic = [0u8; 4];
    cur.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("bad weight file magic {magic:?}");
    }
    let n = read_u32(&mut cur)? as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let name_len = read_u32(&mut cur)? as usize;
        let mut name = vec![0u8; name_len];
        cur.read_exact(&mut name)?;
        let name = String::from_utf8(name).context("weight name utf8")?;
        let ndim = read_u32(&mut cur)? as usize;
        if ndim > 8 {
            bail!("implausible ndim {ndim} for tensor {name}");
        }
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(read_u32(&mut cur)? as usize);
        }
        let count: usize = dims.iter().product();
        let mut raw = vec![0u8; count * 4];
        cur.read_exact(&mut raw)
            .with_context(|| format!("data of tensor {name}"))?;
        let data = raw
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        out.push(WeightTensor { name, dims, data });
    }
    Ok(out)
}

fn read_u32(cur: &mut std::io::Cursor<&[u8]>) -> Result<u32> {
    let mut b = [0u8; 4];
    cur.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn encode(tensors: &[(&str, &[usize], &[f32])]) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
        for (name, dims, data) in tensors {
            out.extend_from_slice(&(name.len() as u32).to_le_bytes());
            out.extend_from_slice(name.as_bytes());
            out.extend_from_slice(&(dims.len() as u32).to_le_bytes());
            for d in *dims {
                out.extend_from_slice(&(*d as u32).to_le_bytes());
            }
            for v in *data {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        out
    }

    #[test]
    fn roundtrip() {
        let bytes = encode(&[
            ("tok_emb", &[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]),
            ("norm_f", &[3], &[1.0, 1.0, 1.0]),
        ]);
        let ws = parse_weights(&bytes).unwrap();
        assert_eq!(ws.len(), 2);
        assert_eq!(ws[0].name, "tok_emb");
        assert_eq!(ws[0].dims, vec![2, 3]);
        assert_eq!(ws[0].data[4], 5.0);
        assert_eq!(ws[1].dims, vec![3]);
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(parse_weights(b"XXXX\x00\x00\x00\x00").is_err());
    }

    #[test]
    fn rejects_truncated() {
        let mut bytes = encode(&[("w", &[4], &[1.0, 2.0, 3.0, 4.0])]);
        bytes.truncate(bytes.len() - 3);
        assert!(parse_weights(&bytes).is_err());
    }

    #[test]
    fn loads_real_artifact_if_present() {
        // Cross-language check against the Python writer.
        let p = std::path::Path::new(
            concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/weights_s.bin"));
        if !p.exists() {
            return; // artifacts not built in this checkout
        }
        let ws = load_weights(p).unwrap();
        assert_eq!(ws[0].name, "tok_emb");
        assert!(ws.len() > 10);
        assert!(ws.iter().all(|w| !w.data.is_empty()));
        assert!(ws.iter().all(|w| w.data.iter().all(|v| v.is_finite())));
    }
}
