//! [`InferenceBackend`] — the execution contract the serving coordinator
//! schedules against.
//!
//! Two implementations ship in-tree:
//!
//! * [`crate::runtime::Engine`] — the PJRT engine running the AOT
//!   bundle (requires the `pjrt` feature + built artifacts).
//! * [`crate::runtime::SimBackend`] — a deterministic in-process fake
//!   transformer (seeded logits, EXAQ Algo-2 output path, cost-model
//!   latency on a virtual clock) so scheduling, batching and latency
//!   accounting are testable at scale with no artifacts at all.
//!
//! The trait deliberately mirrors the engine's typed entry points:
//! batch-1 prefill filling a KV slot, then batched decode steps over
//! host-resident [`DecodeState`].

use crate::util::error::{anyhow, Result};

use super::engine::{DecodeState, QuantMode};
use super::manifest::ModelConfig;
use super::tensor::HostTensor;

/// Everything the coordinator needs from an execution backend.
pub trait InferenceBackend {
    /// Architecture of `model` (shapes the scheduler's KV pool).
    fn model_config(&self, model: &str) -> Result<ModelConfig>;

    /// Token id that terminates generation.
    fn eos_token(&self) -> i32;

    /// Prefill: tokens `[B, S]` (+ clip vector for quantized modes) ->
    /// (logits `[B, S, V]`, per-sequence KV state `[L, B, H, S, hd]`).
    fn prefill(&mut self, model: &str, quant: QuantMode,
               tokens: &HostTensor, c_vec: Option<&[f32]>)
               -> Result<(HostTensor, DecodeState)>;

    /// One decode step: token `[B]`, pos `[B]` -> logits `[B, V]`;
    /// `state` is updated in place.
    fn decode(&mut self, model: &str, quant: QuantMode, token: &[i32],
              pos: &[i32], state: &mut DecodeState,
              c_vec: Option<&[f32]>) -> Result<HostTensor>;

    /// Calibration prefill: tokens `[B, S]`, lengths `[B]` ->
    /// (logits, per-layer stats `[L, 4]`). Optional — backends without
    /// a calibration path keep the default error.
    fn prefill_stats(&mut self, _model: &str, _tokens: &HostTensor,
                     _lengths: &[i32])
                     -> Result<(HostTensor, HostTensor)> {
        Err(anyhow!("this backend does not support calibration \
                     statistics"))
    }
}
