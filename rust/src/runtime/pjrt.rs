//! The PJRT FFI surface the engine compiles against.
//!
//! With the `pjrt` cargo feature the real `xla` bindings are re-exported
//! verbatim (vendoring them and adding the dependency to Cargo.toml is
//! on the integrator). Without it — the default, since the build image
//! vendors no crates — this module provides signature-compatible stubs
//! whose entry point, [`PjRtClient::cpu`], fails with a clear message.
//! Everything downstream still type-checks, `Engine::load` surfaces the
//! error at runtime, and serving falls back to [`crate::runtime::sim`].

#[cfg(feature = "pjrt")]
pub use xla::*;

#[cfg(not(feature = "pjrt"))]
mod stub {
    use std::fmt;

    /// Error produced by every stub entry point.
    #[derive(Debug)]
    pub struct XlaError(pub String);

    impl fmt::Display for XlaError {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            f.write_str(&self.0)
        }
    }

    impl std::error::Error for XlaError {}

    fn unavailable() -> XlaError {
        XlaError(
            "built without the `pjrt` feature: PJRT execution is \
             unavailable (serve through the SimBackend, or vendor the \
             xla bindings and rebuild with --features pjrt)"
                .to_string(),
        )
    }

    /// Stub device buffer.
    #[derive(Debug)]
    pub struct PjRtBuffer;

    /// Stub compiled executable.
    #[derive(Debug)]
    pub struct PjRtLoadedExecutable;

    /// Stub host literal.
    #[derive(Debug)]
    pub struct Literal;

    /// Stub HLO module proto.
    #[derive(Debug)]
    pub struct HloModuleProto;

    /// Stub XLA computation.
    #[derive(Debug)]
    pub struct XlaComputation;

    /// Element dtypes the runtime understands.
    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    pub enum ElementType {
        F32,
        S32,
        Pred,
    }

    /// Stub array shape (dims + dtype).
    #[derive(Debug)]
    pub struct ArrayShape {
        dims: Vec<i64>,
        ty: ElementType,
    }

    impl ArrayShape {
        pub fn dims(&self) -> &[i64] {
            &self.dims
        }

        pub fn ty(&self) -> ElementType {
            self.ty
        }
    }

    /// Stub PJRT client: construction always fails, so no other stub
    /// method is reachable at runtime.
    #[derive(Debug)]
    pub struct PjRtClient;

    impl PjRtClient {
        pub fn cpu() -> Result<PjRtClient, XlaError> {
            Err(unavailable())
        }

        pub fn buffer_from_host_buffer<T>(
            &self,
            _data: &[T],
            _dims: &[usize],
            _device: Option<usize>,
        ) -> Result<PjRtBuffer, XlaError> {
            Err(unavailable())
        }

        pub fn compile(&self, _c: &XlaComputation)
                       -> Result<PjRtLoadedExecutable, XlaError> {
            Err(unavailable())
        }
    }

    impl HloModuleProto {
        pub fn from_text_file(_path: &str)
                              -> Result<HloModuleProto, XlaError> {
            Err(unavailable())
        }
    }

    impl XlaComputation {
        pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
            XlaComputation
        }
    }

    impl PjRtLoadedExecutable {
        pub fn execute_b(&self, _args: &[&PjRtBuffer])
                         -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
            Err(unavailable())
        }
    }

    impl PjRtBuffer {
        pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
            Err(unavailable())
        }
    }

    impl Literal {
        pub fn array_shape(&self) -> Result<ArrayShape, XlaError> {
            Err(unavailable())
        }

        pub fn to_tuple(&self) -> Result<Vec<Literal>, XlaError> {
            Err(unavailable())
        }

        pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
            Err(unavailable())
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn stub_client_fails_loudly() {
            let e = PjRtClient::cpu().unwrap_err();
            assert!(e.to_string().contains("pjrt"));
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::*;
