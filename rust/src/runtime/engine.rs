//! The PJRT execution engine.
//!
//! Owns the CPU PJRT client, lazily compiles HLO-text artifacts (cached
//! per key), keeps each model's weights resident as device buffers, and
//! exposes typed `prefill` / `decode` / `prefill_stats` calls.
//!
//! Outputs cross back to the host as a decomposed tuple literal (the xla
//! crate cannot split a tuple buffer on-device, see DESIGN.md §Perf);
//! weights never re-cross after load thanks to `execute_b`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::clock::Stopwatch;
use crate::util::error::{anyhow, bail, Result};

use super::backend::InferenceBackend;
use super::manifest::{ArtifactSpec, Manifest, ModelConfig, ModelEntry};
use super::pjrt as xla;
use super::tensor::{HostTensor, TensorData};
use super::weights::load_weights;

/// Which softmax variant an inference call should run.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum QuantMode {
    /// Exact softmax (Table 2 "NONE").
    None,
    /// Calibrated clip thresholds, `bits`-bit LUT softmax. The clip
    /// vector decides EXAQ vs NAIVE (computed by `exaq::clip`).
    Static { bits: u32 },
    /// Per-row dynamic statistics (ablation artifacts).
    DynamicExaq { bits: u32 },
    /// Per-row NAIVE min/2 (ablation artifacts).
    DynamicNaive { bits: u32 },
}

impl QuantMode {
    /// The artifact-key fragment this mode selects (matches aot.py tags).
    pub fn tag(&self) -> String {
        match self {
            QuantMode::None => "none".into(),
            QuantMode::Static { bits } => format!("q{bits}"),
            QuantMode::DynamicExaq { bits } => format!("dynexaq{bits}"),
            QuantMode::DynamicNaive { bits } => format!("dynnaive{bits}"),
        }
    }

    /// Does this mode take a `c_vec` runtime input?
    pub fn needs_cvec(&self) -> bool {
        matches!(self, QuantMode::Static { .. })
    }
}

/// Host-resident decode state (KV caches round-trip per step).
#[derive(Clone, Debug)]
pub struct DecodeState {
    pub kc: HostTensor,
    pub vc: HostTensor,
}

/// Aggregate execution metrics (inspected by the coordinator / benches).
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    pub compiles: u64,
    pub executions: u64,
    pub exec_micros: u64,
    pub upload_bytes: u64,
    pub download_bytes: u64,
}

struct LoadedModel {
    entry: ModelEntry,
    weight_bufs: Vec<xla::PjRtBuffer>,
}

/// The engine. Single-owner (the worker thread); not Sync by design.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    // BTreeMaps, not HashMaps: model/executable walk order is
    // deterministic, per the deterministic-iteration lint rule
    models: BTreeMap<String, LoadedModel>,
    executables: BTreeMap<String, xla::PjRtLoadedExecutable>,
    pub stats: EngineStats,
}

impl Engine {
    /// Open an artifact bundle directory.
    pub fn load(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Engine {
            client,
            dir: dir.to_path_buf(),
            manifest,
            models: BTreeMap::new(),
            executables: BTreeMap::new(),
            stats: EngineStats::default(),
        })
    }

    /// Ensure a model's weights are resident; idempotent.
    pub fn load_model(&mut self, name: &str) -> Result<()> {
        if self.models.contains_key(name) {
            return Ok(());
        }
        let entry = self.manifest.model(name)?.clone();
        let tensors = load_weights(&self.dir.join(&entry.weights_file))?;
        if tensors.len() != entry.param_names.len() {
            bail!("weight count {} != manifest {}", tensors.len(),
                  entry.param_names.len());
        }
        let mut weight_bufs = Vec::with_capacity(tensors.len());
        for (t, want) in tensors.iter().zip(&entry.param_names) {
            if &t.name != want {
                bail!("weight order mismatch: file has {}, manifest {}",
                      t.name, want);
            }
            self.stats.upload_bytes += (t.data.len() * 4) as u64;
            let buf = self
                .client
                .buffer_from_host_buffer::<f32>(&t.data, &t.dims, None)
                .map_err(|e| anyhow!("uploading {}: {e}", t.name))?;
            weight_bufs.push(buf);
        }
        self.models.insert(name.to_string(),
                           LoadedModel { entry, weight_bufs });
        Ok(())
    }

    pub fn model_entry(&self, name: &str) -> Result<&ModelEntry> {
        self.manifest.model(name)
    }

    /// Find the artifact for (model, entry, quant, batch).
    pub fn select_artifact(&self, model: &str, entry: &str,
                           quant: QuantMode, batch: usize)
                           -> Result<&ArtifactSpec> {
        let m = self.manifest.model(model)?;
        let tag = quant.tag();
        let key = format!("{entry}_{model}_{tag}_b{batch}");
        m.artifacts
            .iter()
            .find(|a| a.key == key)
            .ok_or_else(|| anyhow!("no artifact '{key}' for model {model}"))
    }

    fn executable(&mut self, file: &str, key: &str)
                  -> Result<&xla::PjRtLoadedExecutable> {
        if !self.executables.contains_key(key) {
            let t0 = Stopwatch::start();
            let path = self.dir.join(file);
            let path_str = path.to_str().ok_or_else(
                || anyhow!("non-UTF8 artifact path {path:?}"))?;
            let proto = xla::HloModuleProto::from_text_file(path_str)
                .map_err(|e| anyhow!("parsing HLO {path:?}: {e}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {key}: {e}"))?;
            self.stats.compiles += 1;
            eprintln!("[engine] compiled {key} in {:.2}s",
                      t0.seconds());
            self.executables.insert(key.to_string(), exe);
        }
        Ok(&self.executables[key])
    }

    /// Run one artifact: weights (resident) ++ `extra` (uploaded) -> host
    /// tensors of the output tuple.
    pub fn run(&mut self, model: &str, artifact_key: &str,
               extra: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.load_model(model)?;
        let (file, n_inputs) = {
            let m = self.manifest.model(model)?;
            let a = m
                .artifacts
                .iter()
                .find(|a| a.key == artifact_key)
                .ok_or_else(|| anyhow!("unknown artifact {artifact_key}"))?;
            (a.file.clone(), a.inputs.len())
        };
        let n_weights = self.models[model].weight_bufs.len();
        if n_weights + extra.len() != n_inputs {
            bail!("{artifact_key}: {} weights + {} extras != {} inputs",
                  n_weights, extra.len(), n_inputs);
        }

        // upload the per-call inputs
        let mut uploaded = Vec::with_capacity(extra.len());
        for t in extra {
            self.stats.upload_bytes += (t.len() * 4) as u64;
            let buf = match &t.data {
                TensorData::F32(v) => self
                    .client
                    .buffer_from_host_buffer::<f32>(v, &t.shape, None),
                TensorData::I32(v) => self
                    .client
                    .buffer_from_host_buffer::<i32>(v, &t.shape, None),
            }
            .map_err(|e| anyhow!("uploading arg: {e}"))?;
            uploaded.push(buf);
        }

        self.executable(&file, artifact_key)?;
        let model_bufs = &self.models[model].weight_bufs;
        let exe = &self.executables[artifact_key];
        let mut args: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(n_inputs);
        args.extend(model_bufs.iter());
        args.extend(uploaded.iter());

        let t0 = Stopwatch::start();
        let outs = exe
            .execute_b(&args)
            .map_err(|e| anyhow!("executing {artifact_key}: {e}"))?;
        self.stats.executions += 1;
        self.stats.exec_micros += t0.micros();

        let lit = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching output: {e}"))?;
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow!("decomposing output tuple: {e}"))?;
        let mut tensors = Vec::with_capacity(parts.len());
        for p in &parts {
            let t = HostTensor::from_literal(p)?;
            self.stats.download_bytes += (t.len() * 4) as u64;
            tensors.push(t);
        }
        Ok(tensors)
    }

    // ---- typed entry points ---------------------------------------------

    /// Prefill: tokens [B,S] (+ c_vec for quantized modes) ->
    /// (logits [B,S,V], DecodeState).
    pub fn prefill(&mut self, model: &str, quant: QuantMode,
                   tokens: &HostTensor, c_vec: Option<&[f32]>)
                   -> Result<(HostTensor, DecodeState)> {
        let batch = tokens.shape[0];
        let key = self
            .select_artifact(model, "prefill", quant, batch)?
            .key
            .clone();
        let mut extra = vec![tokens.clone()];
        if quant.needs_cvec() {
            let c = c_vec.ok_or_else(|| anyhow!("quant mode needs c_vec"))?;
            extra.push(HostTensor::f32(c.to_vec(), &[c.len()]));
        }
        let mut outs = self.run(model, &key, &extra)?;
        let (Some(vc), Some(kc), Some(logits)) =
            (outs.pop(), outs.pop(), outs.pop())
        else {
            bail!("prefill returned too few outputs, expected 3");
        };
        if !outs.is_empty() {
            bail!("prefill returned {} outputs, expected 3",
                  outs.len() + 3);
        }
        Ok((logits, DecodeState { kc, vc }))
    }

    /// One decode step: token [B], pos [B] -> logits [B,V]; state updated.
    pub fn decode(&mut self, model: &str, quant: QuantMode,
                  token: &[i32], pos: &[i32], state: &mut DecodeState,
                  c_vec: Option<&[f32]>) -> Result<HostTensor> {
        let batch = token.len();
        let key = self
            .select_artifact(model, "decode", quant, batch)?
            .key
            .clone();
        let mut extra = vec![
            HostTensor::i32(token.to_vec(), &[batch]),
            HostTensor::i32(pos.to_vec(), &[batch]),
            state.kc.clone(),
            state.vc.clone(),
        ];
        if quant.needs_cvec() {
            let c = c_vec.ok_or_else(|| anyhow!("quant mode needs c_vec"))?;
            extra.push(HostTensor::f32(c.to_vec(), &[c.len()]));
        }
        let mut outs = self.run(model, &key, &extra)?;
        let (Some(vc), Some(kc), Some(logits)) =
            (outs.pop(), outs.pop(), outs.pop())
        else {
            bail!("decode returned too few outputs, expected 3");
        };
        if !outs.is_empty() {
            bail!("decode returned {} outputs, expected 3",
                  outs.len() + 3);
        }
        state.vc = vc;
        state.kc = kc;
        Ok(logits)
    }

    /// Calibration prefill: tokens [B,S], lengths [B] ->
    /// (logits, stats [L,4] = (count, mean, M2, min) per layer).
    pub fn prefill_stats(&mut self, model: &str, tokens: &HostTensor,
                         lengths: &[i32])
                         -> Result<(HostTensor, HostTensor)> {
        let batch = tokens.shape[0];
        let key = self
            .select_artifact(model, "prefill_stats", QuantMode::None,
                             batch)?
            .key
            .clone();
        let extra = vec![
            tokens.clone(),
            HostTensor::i32(lengths.to_vec(), &[lengths.len()]),
        ];
        let mut outs = self.run(model, &key, &extra)?;
        let (Some(stats), Some(logits)) = (outs.pop(), outs.pop())
        else {
            bail!("prefill_stats returned too few outputs, \
                   expected 2");
        };
        if !outs.is_empty() {
            bail!("prefill_stats returned {} outputs, expected 2",
                  outs.len() + 2);
        }
        Ok((logits, stats))
    }

    /// Fresh all-zero decode state sized for `model` at `batch`.
    pub fn empty_state(&self, model: &str, batch: usize)
                       -> Result<DecodeState> {
        let c = &self.manifest.model(model)?.config;
        let shape = [c.n_layers, batch, c.n_heads, c.max_seq, c.head_dim];
        Ok(DecodeState {
            kc: HostTensor::zeros_f32(&shape),
            vc: HostTensor::zeros_f32(&shape),
        })
    }
}

/// The PJRT engine is one of the two serving backends (the other is
/// [`super::sim::SimBackend`]); the trait methods delegate to the typed
/// inherent entry points above.
impl InferenceBackend for Engine {
    fn model_config(&self, model: &str) -> Result<ModelConfig> {
        Ok(self.manifest.model(model)?.config.clone())
    }

    fn eos_token(&self) -> i32 {
        self.manifest.eos as i32
    }

    fn prefill(&mut self, model: &str, quant: QuantMode,
               tokens: &HostTensor, c_vec: Option<&[f32]>)
               -> Result<(HostTensor, DecodeState)> {
        Engine::prefill(self, model, quant, tokens, c_vec)
    }

    fn decode(&mut self, model: &str, quant: QuantMode, token: &[i32],
              pos: &[i32], state: &mut DecodeState,
              c_vec: Option<&[f32]>) -> Result<HostTensor> {
        Engine::decode(self, model, quant, token, pos, state, c_vec)
    }

    fn prefill_stats(&mut self, model: &str, tokens: &HostTensor,
                     lengths: &[i32])
                     -> Result<(HostTensor, HostTensor)> {
        Engine::prefill_stats(self, model, tokens, lengths)
    }
}
