//! `artifacts/manifest.json` parser — the contract between the AOT
//! pipeline (`python/compile/aot.py`) and the Rust runtime.

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::error::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Model architecture parameters (mirrors python ModelConfig).
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: String,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub vocab_size: usize,
    pub max_seq: usize,
    pub head_dim: usize,
    pub n_params: usize,
}

/// One tensor of an executable signature.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One lowered executable.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub key: String,
    pub file: String,
    /// prefill | decode | prefill_stats
    pub entry: String,
    /// none | static | dynamic_exaq | dynamic_naive
    pub quant: String,
    pub bits: u32,
    pub batch: usize,
    pub seq: usize,
    pub inputs: Vec<TensorSpec>,
}

/// One model of the bundle.
#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub family: u32,
    pub config: ModelConfig,
    pub weights_file: String,
    pub param_names: Vec<String>,
    pub artifacts: Vec<ArtifactSpec>,
}

/// The whole bundle.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub seq: usize,
    pub vocab: Vec<String>,
    pub pad: usize,
    pub bos: usize,
    pub eos: usize,
    pub sep: usize,
    /// bits -> (slope, intercept) of Table 1.
    pub table1: BTreeMap<u32, (f64, f64)>,
    pub models: BTreeMap<String, ModelEntry>,
}

fn req<'a>(j: &'a Json, key: &str) -> Result<&'a Json> {
    j.get(key).ok_or_else(|| anyhow!("manifest: missing key '{key}'"))
}

fn req_usize(j: &Json, key: &str) -> Result<usize> {
    req(j, key)?.as_usize()
        .ok_or_else(|| anyhow!("manifest: '{key}' not a number"))
}

fn req_str(j: &Json, key: &str) -> Result<String> {
    Ok(req(j, key)?
        .as_str()
        .ok_or_else(|| anyhow!("manifest: '{key}' not a string"))?
        .to_string())
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).context("parsing manifest.json")?;
        let specials = req(&j, "specials")?;
        let mut table1 = BTreeMap::new();
        if let Some(t) = j.get("table1").and_then(Json::as_obj) {
            for (k, v) in t {
                let bits: u32 = k.parse().context("table1 bits key")?;
                let arr = v.as_f64_vec()
                    .ok_or_else(|| anyhow!("table1 row not numeric"))?;
                if arr.len() != 2 {
                    bail!("table1 row wrong arity");
                }
                table1.insert(bits, (arr[0], arr[1]));
            }
        }
        let mut models = BTreeMap::new();
        for (name, m) in req(&j, "models")?
            .as_obj()
            .ok_or_else(|| anyhow!("models not an object"))?
        {
            models.insert(name.clone(), parse_model(m)
                .with_context(|| format!("model {name}"))?);
        }
        Ok(Manifest {
            seq: req_usize(&j, "seq")?,
            vocab: req(&j, "vocab")?
                .as_str_vec()
                .ok_or_else(|| anyhow!("vocab not a string array"))?,
            pad: req_usize(specials, "pad")?,
            bos: req_usize(specials, "bos")?,
            eos: req_usize(specials, "eos")?,
            sep: req_usize(specials, "sep")?,
            table1,
            models,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!(
                "model '{name}' not in bundle (have: {:?})",
                self.models.keys().collect::<Vec<_>>()))
    }
}

fn parse_model(m: &Json) -> Result<ModelEntry> {
    let c = req(m, "config")?;
    let config = ModelConfig {
        name: req_str(c, "name")?,
        n_layers: req_usize(c, "n_layers")?,
        d_model: req_usize(c, "d_model")?,
        n_heads: req_usize(c, "n_heads")?,
        d_ff: req_usize(c, "d_ff")?,
        vocab_size: req_usize(c, "vocab_size")?,
        max_seq: req_usize(c, "max_seq")?,
        head_dim: req_usize(c, "head_dim")?,
        n_params: req_usize(c, "n_params")?,
    };
    let mut artifacts = Vec::new();
    for a in req(m, "artifacts")?
        .as_arr()
        .ok_or_else(|| anyhow!("artifacts not an array"))?
    {
        let mut inputs = Vec::new();
        for t in req(a, "inputs")?
            .as_arr()
            .ok_or_else(|| anyhow!("inputs not an array"))?
        {
            inputs.push(TensorSpec {
                name: req_str(t, "name")?,
                shape: req(t, "shape")?
                    .as_f64_vec()
                    .ok_or_else(|| anyhow!("shape not numeric"))?
                    .into_iter()
                    .map(|d| d as usize)
                    .collect(),
                dtype: req_str(t, "dtype")?,
            });
        }
        artifacts.push(ArtifactSpec {
            key: req_str(a, "key")?,
            file: req_str(a, "file")?,
            entry: req_str(a, "entry")?,
            quant: req_str(a, "quant")?,
            bits: req_usize(a, "bits")? as u32,
            batch: req_usize(a, "batch")?,
            seq: req_usize(a, "seq")?,
            inputs,
        });
    }
    Ok(ModelEntry {
        family: req_usize(m, "family")? as u32,
        config,
        weights_file: req_str(m, "weights")?,
        param_names: req(m, "param_names")?
            .as_str_vec()
            .ok_or_else(|| anyhow!("param_names not strings"))?,
        artifacts,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": 1, "seq": 64,
      "vocab": ["<pad>", "<bos>", "<eos>", "<sep>", "the"],
      "specials": {"pad": 0, "bos": 1, "eos": 2, "sep": 3},
      "table1": {"2": [-1.66, -1.85], "3": [-1.75, -2.06]},
      "models": {
        "s": {
          "family": 1,
          "config": {"name": "s", "n_layers": 2, "d_model": 96,
                     "n_heads": 4, "d_ff": 256, "vocab_size": 104,
                     "max_seq": 64, "head_dim": 24, "n_params": 231648},
          "weights": "weights_s.bin",
          "param_names": ["tok_emb", "norm_f"],
          "artifacts": [
            {"key": "prefill_s_none_b1", "file": "prefill_s_none_b1.hlo.txt",
             "entry": "prefill", "quant": "none", "bits": 0,
             "batch": 1, "seq": 64,
             "inputs": [{"name": "tok_emb", "shape": [104, 96],
                         "dtype": "float32"}]}
          ]
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.seq, 64);
        assert_eq!(m.vocab.len(), 5);
        assert_eq!(m.table1[&2], (-1.66, -1.85));
        let s = m.model("s").unwrap();
        assert_eq!(s.config.n_layers, 2);
        assert_eq!(s.artifacts[0].inputs[0].shape, vec![104, 96]);
        assert!(m.model("zz").is_err());
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let p = std::path::Path::new(
            concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
        if !p.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(p).unwrap();
        assert!(m.models.len() >= 4, "expected full family bundle");
        for (name, entry) in &m.models {
            assert!(!entry.artifacts.is_empty(), "{name} has no artifacts");
            // every artifact's weight inputs match param_names order
            for a in &entry.artifacts {
                for (i, pn) in entry.param_names.iter().enumerate() {
                    assert_eq!(&a.inputs[i].name, pn,
                               "{}: weight order mismatch", a.key);
                }
            }
        }
    }
}
