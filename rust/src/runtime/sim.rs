//! Deterministic in-process simulation backend.
//!
//! A fake transformer for driving the continuous-batching coordinator
//! at scale with no PJRT artifacts: logits are seeded per (token,
//! position) from [`SplitMix64`] and shaped through the *real* EXAQ
//! Algorithm-2 pipeline — by default the batched bit-packed plane
//! kernel ([`BatchSoftmax::softmax_rows`]), which shapes ALL rows of a
//! prefill/decode step in one call (set
//! [`SimConfig::batched_softmax`] = false for the per-row scalar
//! baseline; the two are bit-identical, only the host time differs).
//! Per-step latency is charged to the shared [`Clock`] from the
//! [`crate::cost`] cycle model, so TTFT / latency / occupancy metrics
//! are exact and reproducible under a
//! [`crate::util::clock::VirtualClock`].
//!
//! Attention is simulated too: each step builds a seeded
//! `[rows × seq]` score plane (one row per (slot, head) — and per
//! query position during prefill) and shapes it through the fused
//! packed pipeline ([`AttentionPlane::attend`]); the attended vectors
//! become the layer-0 value-cache payload. Set
//! [`SimConfig::fused_attention`] = false for the two-step
//! quantize -> softmax -> dense-PV reference, or
//! [`SimConfig::streaming_attention`] = true for the one-pass
//! streaming kernel that never holds a dense score plane — the
//! vectors are bit-identical in every mode, only peak score memory
//! and host time differ.

use std::rc::Rc;

use crate::cost::{GemmPrecision, MachineModel, TransformerShape};
use crate::exaq::batched::BatchSoftmax;
use crate::exaq::plane::AttentionPlane;
use crate::exaq::stream::StreamingAttention;
use crate::util::clock::Clock;
use crate::util::error::{bail, Result};
use crate::util::rng::SplitMix64;

use super::backend::InferenceBackend;
use super::engine::{DecodeState, QuantMode};
use super::manifest::ModelConfig;
use super::tensor::HostTensor;

/// Architecture + behaviour of the simulated model.
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Model name the scheduler addresses (anything else errors).
    pub name: String,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub vocab: usize,
    /// Token id treated as end-of-sequence.
    pub eos: i32,
    /// Master seed for the per-position logit streams.
    pub seed: u64,
    /// Probability that a position's logits strongly prefer EOS —
    /// drives the early-stopping chat scenarios (0.0 = organic only).
    pub eos_bias: f64,
    /// Bit-width of the Algo-2 pipeline shaping the logits (also the
    /// softmax variant the latency model charges for when quantized).
    pub shape_bits: u32,
    /// Clip threshold of the shaping quantizer.
    pub shape_clip: f32,
    /// Shape logits through the batched bit-packed plane kernel
    /// (default) or the per-row scalar path. Bit-identical results;
    /// the flag exists so benches can report the host-time delta.
    pub batched_softmax: bool,
    /// Shape attention scores through the fused packed pipeline
    /// ([`AttentionPlane::attend`], default) or the two-step
    /// quantize -> softmax -> dense-PV reference. Bit-identical
    /// vectors; the flag exists so benches can report the host-time
    /// delta of keeping the plane packed.
    pub fused_attention: bool,
    /// Route attention through the streaming one-pass kernel
    /// ([`crate::exaq::StreamingAttention`]) instead: scores are
    /// consumed tile by tile and the kernel never holds a dense f32
    /// score plane. Bit-identical vectors again; takes precedence
    /// over [`SimConfig::fused_attention`] when set.
    pub streaming_attention: bool,
    /// Worker count for the batched plane kernel (0 = auto: the row
    /// pool's own heuristic). Logits are bit-identical for any value —
    /// the pool is deterministic — so this only moves host time.
    pub threads: usize,
    /// Simulated accelerator clock in cycles/second (converts the cost
    /// model's cycles into seconds on the shared clock).
    pub clock_hz: f64,
    pub gemm_precision: GemmPrecision,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self {
            name: "sim".to_string(),
            n_layers: 2,
            n_heads: 2,
            head_dim: 4,
            d_ff: 16,
            max_seq: 64,
            vocab: 64,
            eos: 2,
            seed: 0x5EED_CAFE,
            eos_bias: 0.0,
            shape_bits: 2,
            shape_clip: -4.0,
            batched_softmax: true,
            fused_attention: true,
            streaming_attention: false,
            threads: 0,
            clock_hz: 1.0e6,
            gemm_precision: GemmPrecision::Bf16,
        }
    }
}

impl SimConfig {
    /// Smallest model that still exercises every serving path
    /// (truncation, multi-token decode, attention planes): the
    /// million-request fabric suite uses this so a full storm fits in
    /// seconds of host time.
    pub fn tiny() -> Self {
        Self {
            n_layers: 1,
            n_heads: 1,
            head_dim: 4,
            d_ff: 8,
            max_seq: 16,
            vocab: 16,
            ..Self::default()
        }
    }

    fn d_model(&self) -> usize {
        self.n_heads * self.head_dim
    }

    fn model_config(&self) -> ModelConfig {
        let d = self.d_model();
        ModelConfig {
            name: self.name.clone(),
            n_layers: self.n_layers,
            d_model: d,
            n_heads: self.n_heads,
            d_ff: self.d_ff,
            vocab_size: self.vocab,
            max_seq: self.max_seq,
            head_dim: self.head_dim,
            n_params: self.n_layers
                * (4 * d * d + 3 * d * self.d_ff)
                + 2 * self.vocab * d,
        }
    }

    fn shape(&self, batch: usize) -> TransformerShape {
        TransformerShape {
            layers: self.n_layers,
            d_model: self.d_model(),
            n_heads: self.n_heads,
            d_ff: self.d_ff,
            seq: self.max_seq,
            batch,
            vocab: self.vocab,
        }
    }
}

/// The simulation backend. See the module docs.
pub struct SimBackend {
    cfg: SimConfig,
    machine: MachineModel,
    clock: Rc<dyn Clock>,
    /// The batched Algorithm-2 engine shaping every logit plane
    /// (tables + bit-packed code plane, reused across steps).
    engine: BatchSoftmax,
    /// The fused packed attention plane shaping every step's score
    /// plane at the same (bits, clip) as the logit engine.
    plane: AttentionPlane,
    /// The streaming one-pass kernel at the same (bits, clip); used
    /// when [`SimConfig::streaming_attention`] is set (bit-identical
    /// to the plane — only peak score memory and host time differ).
    stream: StreamingAttention,
    /// Seeded `[max_seq × head_dim]` value plane shared by every head
    /// (built once, never mutated — the PV pass only reads it).
    values: Vec<f32>,
    /// Per-row EOS-bias rolls of the step being generated.
    rolls: Vec<f64>,
    // attention scratch, reused so steady-state steps allocate
    // nothing once the high-water shapes are reached
    att_scores: Vec<f32>,
    att_vlens: Vec<usize>,
    att_out: Vec<f32>,
    /// Executed-step counters (inspected by benches/tests).
    pub prefills: u64,
    pub decode_steps: u64,
}

impl SimBackend {
    pub fn new(cfg: SimConfig, clock: Rc<dyn Clock>) -> Self {
        assert!((cfg.eos as usize) < cfg.vocab,
                "eos id outside the simulated vocabulary");
        assert!(cfg.vocab >= 8, "vocabulary too small to be interesting");
        let mut engine =
            BatchSoftmax::new(cfg.shape_bits, cfg.shape_clip);
        engine.set_threads(cfg.threads);
        let mut plane =
            AttentionPlane::new(cfg.shape_bits, cfg.shape_clip);
        plane.set_threads(cfg.threads);
        let mut stream =
            StreamingAttention::new(cfg.shape_bits, cfg.shape_clip);
        stream.set_threads(cfg.threads);
        let mut vrng = SplitMix64::new(cfg.seed ^ 0xA77E);
        let values: Vec<f32> = (0..cfg.max_seq * cfg.head_dim)
            .map(|_| vrng.normal() as f32)
            .collect();
        Self {
            cfg,
            machine: MachineModel::default(),
            clock,
            engine,
            plane,
            stream,
            values,
            rolls: Vec::new(),
            att_scores: Vec::new(),
            att_vlens: Vec::new(),
            att_out: Vec::new(),
            prefills: 0,
            decode_steps: 0,
        }
    }

    pub fn config(&self) -> &SimConfig {
        &self.cfg
    }

    /// Seconds one batch-`b` prefill occupies the simulated device.
    pub fn prefill_seconds(&self, batch: usize) -> f64 {
        self.machine.prefill_cycles(self.cfg.shape(batch),
                                    self.cfg.gemm_precision,
                                    Some(self.cfg.shape_bits))
            / self.cfg.clock_hz
    }

    /// Seconds one batched decode step occupies the simulated device.
    pub fn decode_seconds(&self, batch: usize) -> f64 {
        self.machine
            .decode_step_cycles(self.cfg.shape(batch),
                                self.cfg.gemm_precision,
                                Some(self.cfg.shape_bits), batch,
                                self.cfg.max_seq)
            / self.cfg.clock_hz
    }

    fn seed_for(&self, token: i32, pos: usize) -> u64 {
        self.cfg
            .seed
            .wrapping_add((token as u64)
                .wrapping_mul(0x9E37_79B9_7F4A_7C15))
            .wrapping_add((pos as u64)
                .wrapping_mul(0xBF58_476D_1CE4_E5B9))
    }

    /// Shape a `[rows × vocab]` noise plane into log-probabilities:
    /// one batched Algorithm-2 kernel call (or the per-row scalar
    /// baseline when `batched_softmax` is off), then log.
    fn shape_plane(&mut self, plane: &mut [f32], rows: usize) {
        let v = self.cfg.vocab;
        if self.cfg.batched_softmax {
            self.engine.softmax_rows(plane, rows, v, &[]);
        } else {
            for row in plane.chunks_exact_mut(v) {
                self.engine.softmax_row(row, v);
            }
        }
        for x in plane.iter_mut() {
            *x = (*x).max(1e-30).ln();
        }
    }

    /// Seed of one (token, position, head) attention-score row —
    /// decorrelated from the logit stream by the head mix.
    fn att_seed(&self, token: i32, pos: usize, head: usize) -> u64 {
        self.seed_for(token, pos)
            ^ (head as u64 + 1).wrapping_mul(0xA24B_AED4_963E_E407)
    }

    /// Run the prepared `[rows × max_seq]` score plane
    /// (`self.att_scores` / `self.att_vlens`) through the packed
    /// attention pipeline into `self.att_out` (`[rows × head_dim]`).
    /// Streaming, fused, and two-step are bit-identical by the
    /// plane/stream contracts.
    fn run_attention(&mut self, rows: usize) {
        let (seq, hd) = (self.cfg.max_seq, self.cfg.head_dim);
        self.att_out.resize(rows * hd, 0.0);
        if self.cfg.streaming_attention {
            self.stream.attend_scores(&self.att_scores, rows, seq,
                                      &self.att_vlens, &self.values,
                                      hd, &mut self.att_out);
        } else if self.cfg.fused_attention {
            self.plane.attend(&self.att_scores, rows, seq,
                              &self.att_vlens, &self.values, hd,
                              &mut self.att_out);
        } else {
            self.plane.attend_two_step(&self.att_scores, rows, seq,
                                       &self.att_vlens, &self.values,
                                       hd, &mut self.att_out);
        }
    }

    /// Deterministic EOS boost, decided by the row's noise-stream roll.
    fn apply_eos_bias(&self, row: &mut [f32], roll: f64) {
        if self.cfg.eos_bias > 0.0 && roll < self.cfg.eos_bias {
            row[self.cfg.eos as usize] += 16.0;
        }
    }

    /// Fill one vocab-sized logit row for (last token, position):
    /// seeded noise -> EXAQ Algo-2 softmax -> log-probabilities, with
    /// an optional deterministic EOS boost. Batched steps produce
    /// bit-identical rows via [`Self::shape_plane`] over many rows.
    fn logits_row(&mut self, token: i32, pos: usize, out: &mut [f32]) {
        let roll = fill_noise(self.seed_for(token, pos), out);
        self.shape_plane(out, 1);
        self.apply_eos_bias(out, roll);
    }

    fn kv_shape(&self, batch: usize) -> [usize; 5] {
        [self.cfg.n_layers, batch, self.cfg.n_heads, self.cfg.max_seq,
         self.cfg.head_dim]
    }

    fn check_model(&self, model: &str) -> Result<()> {
        if model != self.cfg.name {
            bail!("SimBackend serves model '{}', not '{model}'",
                  self.cfg.name);
        }
        Ok(())
    }
}

/// Seeded noise for one logit row; returns the row's EOS-bias roll
/// (drawn right after the noise so the stream layout is stable).
fn fill_noise(seed: u64, out: &mut [f32]) -> f64 {
    let mut rng = SplitMix64::new(seed);
    for x in out.iter_mut() {
        *x = (rng.normal() as f32) * 2.0;
    }
    rng.uniform()
}

impl InferenceBackend for SimBackend {
    fn model_config(&self, model: &str) -> Result<ModelConfig> {
        self.check_model(model)?;
        Ok(self.cfg.model_config())
    }

    fn eos_token(&self) -> i32 {
        self.cfg.eos
    }

    fn prefill(&mut self, model: &str, quant: QuantMode,
               tokens: &HostTensor, c_vec: Option<&[f32]>)
               -> Result<(HostTensor, DecodeState)> {
        self.check_model(model)?;
        if quant.needs_cvec() && c_vec.is_none() {
            bail!("quant mode {quant:?} needs a clip vector");
        }
        if tokens.shape.len() != 2 {
            bail!("prefill tokens must be [B, S], got {:?}",
                  tokens.shape);
        }
        let (b, s) = (tokens.shape[0], tokens.shape[1]);
        if b == 0 {
            bail!("prefill needs at least one sequence");
        }
        if s != self.cfg.max_seq {
            bail!("prefill seq {s} != simulated artifact seq {}",
                  self.cfg.max_seq);
        }
        let toks = tokens.as_i32()?;
        let v = self.cfg.vocab;

        // the whole [B*S, V] prefill plane is shaped in ONE batched
        // Algorithm-2 kernel call
        let mut logits = vec![0.0f32; b * s * v];
        self.rolls.clear();
        for bi in 0..b {
            for p in 0..s {
                let tok = toks[bi * s + p];
                let row = &mut logits[(bi * s + p) * v
                    ..(bi * s + p + 1) * v];
                let seed = self.seed_for(tok, p);
                self.rolls.push(fill_noise(seed, row));
            }
        }
        self.shape_plane(&mut logits, b * s);
        for (row, &roll) in logits.chunks_exact_mut(v).zip(&self.rolls)
        {
            self.apply_eos_bias(row, roll);
        }

        // deterministic KV payload: a cheap per-sequence signature (the
        // coordinator only routes these bytes, it never reads them);
        // fold the whole prompt so distinct requests get distinct bytes
        let shape = self.kv_shape(b);
        let kv_len: usize = shape.iter().product();
        let mut sig = self.cfg.seed ^ 0xD1CE;
        for &t in toks {
            sig = sig
                .wrapping_mul(0x0100_0000_01B3)
                .wrapping_add(t as u64);
        }
        let mut kv_rng = SplitMix64::new(sig);
        let kc: Vec<f32> =
            (0..kv_len).map(|_| kv_rng.uniform() as f32).collect();
        let mut vc: Vec<f32> =
            (0..kv_len).map(|_| kv_rng.uniform() as f32).collect();

        // attention: one causal score row per (sequence, head, query
        // position), shaped through the packed plane in one call; the
        // attended vectors become the layer-0 value-cache payload
        // (row order matches the [b, heads, seq, hd] cache layout, so
        // the copy below is a straight prefix write)
        let heads = self.cfg.n_heads;
        let hd = self.cfg.head_dim;
        let rows = b * heads * s;
        self.att_scores.resize(rows * s, 0.0);
        self.att_vlens.clear();
        for bi in 0..b {
            for h in 0..heads {
                for q in 0..s {
                    let seed =
                        self.att_seed(toks[bi * s + q], q, h);
                    let r = (bi * heads + h) * s + q;
                    let row =
                        &mut self.att_scores[r * s..(r + 1) * s];
                    fill_noise(seed, row);
                    self.att_vlens.push(q + 1);
                }
            }
        }
        self.run_attention(rows);
        vc[..rows * hd].copy_from_slice(&self.att_out[..rows * hd]);

        self.prefills += 1;
        self.clock.advance(self.prefill_seconds(b));
        Ok((
            HostTensor::f32(logits, &[b, s, v]),
            DecodeState {
                kc: HostTensor::f32(kc, &shape),
                vc: HostTensor::f32(vc, &shape),
            },
        ))
    }

    fn decode(&mut self, model: &str, quant: QuantMode, token: &[i32],
              pos: &[i32], state: &mut DecodeState,
              c_vec: Option<&[f32]>) -> Result<HostTensor> {
        self.check_model(model)?;
        if quant.needs_cvec() && c_vec.is_none() {
            bail!("quant mode {quant:?} needs a clip vector");
        }
        let b = token.len();
        if pos.len() != b {
            bail!("decode token/pos arity mismatch: {b} vs {}",
                  pos.len());
        }
        let expect = self.kv_shape(b);
        if state.kc.shape != expect {
            bail!("decode state shape {:?} != expected {:?}",
                  state.kc.shape, expect);
        }
        // batch every active slot's logit row into one plane kernel
        // call (the serving hot path this crate exists to accelerate)
        let v = self.cfg.vocab;
        let mut logits = vec![0.0f32; b * v];
        self.rolls.clear();
        for (i, (&tok, &p)) in token.iter().zip(pos).enumerate() {
            let row = &mut logits[i * v..(i + 1) * v];
            let seed = self.seed_for(tok, p as usize);
            self.rolls.push(fill_noise(seed, row));
        }
        self.shape_plane(&mut logits, b);
        for (row, &roll) in logits.chunks_exact_mut(v).zip(&self.rolls)
        {
            self.apply_eos_bias(row, roll);
        }

        // simulate the cache write: stamp the token at its position in
        // layer 0 / head 0 so tests can observe slot plumbing
        let (heads, seq, hd) =
            (self.cfg.n_heads, self.cfg.max_seq, self.cfg.head_dim);
        if let Ok(kc) = state.kc.as_f32_mut() {
            for (i, &p) in pos.iter().enumerate() {
                let p = (p as usize).min(seq - 1);
                kc[(i * heads * seq + p) * hd] = token[i] as f32;
            }
        }

        // attention: one score row per (slot, head) over the keys
        // seen so far, shaped through the packed plane; the attended
        // vector lands at the slot's position in the layer-0 value
        // cache (mirroring the kc stamp above)
        let rows = b * heads;
        self.att_scores.resize(rows * seq, 0.0);
        self.att_vlens.clear();
        for (i, (&tok, &p)) in token.iter().zip(pos).enumerate() {
            let p = (p as usize).min(seq - 1);
            for h in 0..heads {
                let seed = self.att_seed(tok, p, h);
                let r = i * heads + h;
                let row =
                    &mut self.att_scores[r * seq..(r + 1) * seq];
                fill_noise(seed, row);
                self.att_vlens.push(p + 1);
            }
        }
        self.run_attention(rows);
        if let Ok(vc) = state.vc.as_f32_mut() {
            for (i, &p) in pos.iter().enumerate() {
                let p = (p as usize).min(seq - 1);
                for h in 0..heads {
                    let r = i * heads + h;
                    let dst = (r * seq + p) * hd;
                    vc[dst..dst + hd].copy_from_slice(
                        &self.att_out[r * hd..(r + 1) * hd]);
                }
            }
        }

        self.decode_steps += 1;
        self.clock.advance(self.decode_seconds(b));
        Ok(HostTensor::f32(logits, &[b, v]))
    }

    fn prefill_stats(&mut self, model: &str, tokens: &HostTensor,
                     lengths: &[i32])
                     -> Result<(HostTensor, HostTensor)> {
        self.check_model(model)?;
        let (logits, _) =
            self.prefill(model, QuantMode::None, tokens, None)?;
        let count: f64 = lengths.iter().map(|&l| l as f64).sum();
        let mut stats = Vec::with_capacity(self.cfg.n_layers * 4);
        for l in 0..self.cfg.n_layers {
            let sigma = 0.8 + 0.05 * l as f64;
            let mean = -1.5 - 0.1 * l as f64;
            stats.push(count as f32);
            stats.push(mean as f32);
            stats.push((count * sigma * sigma) as f32);
            stats.push((mean - 4.0 * sigma) as f32);
        }
        let stats =
            HostTensor::f32(stats, &[self.cfg.n_layers, 4]);
        Ok((logits, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::VirtualClock;

    fn backend() -> (SimBackend, Rc<VirtualClock>) {
        let clock = Rc::new(VirtualClock::new());
        let b = SimBackend::new(SimConfig::default(), clock.clone());
        (b, clock)
    }

    fn prompt_tensor(cfg: &SimConfig) -> HostTensor {
        let mut toks = vec![1i32; cfg.max_seq];
        for (i, t) in toks.iter_mut().enumerate() {
            *t = 4 + (i as i32 % 7);
        }
        HostTensor::i32(toks, &[1, cfg.max_seq])
    }

    #[test]
    fn prefill_shapes_and_advances_clock() {
        let (mut b, clock) = backend();
        let tokens = prompt_tensor(&b.cfg.clone());
        let (logits, state) =
            b.prefill("sim", QuantMode::None, &tokens, None).unwrap();
        assert_eq!(logits.shape, vec![1, 64, 64]);
        assert_eq!(state.kc.shape, vec![2, 1, 2, 64, 4]);
        assert!(clock.now() > 0.0, "prefill must cost simulated time");
        assert_eq!(b.prefills, 1);
    }

    #[test]
    fn logit_rows_are_log_probabilities() {
        let (mut b, _clock) = backend();
        let mut row = vec![0.0f32; 64];
        b.logits_row(7, 3, &mut row);
        let total: f32 = row.iter().map(|&x| x.exp()).sum();
        assert!((total - 1.0).abs() < 1e-3, "sum exp(logit) = {total}");
    }

    #[test]
    fn same_inputs_same_logits() {
        let (mut a, _) = backend();
        let (mut b, _) = backend();
        let mut ra = vec![0.0f32; 64];
        let mut rb = vec![0.0f32; 64];
        a.logits_row(11, 5, &mut ra);
        b.logits_row(11, 5, &mut rb);
        assert_eq!(ra, rb);
        // distinct positions decorrelate
        b.logits_row(11, 6, &mut ra);
        assert_ne!(ra, rb);
    }

    #[test]
    fn batched_and_scalar_shaping_are_bit_identical() {
        let clock = Rc::new(VirtualClock::new());
        let mut a =
            SimBackend::new(SimConfig::default(), clock.clone());
        let scalar_cfg = SimConfig { batched_softmax: false,
                                     ..SimConfig::default() };
        let mut b = SimBackend::new(scalar_cfg, clock);
        let mut ra = vec![0.0f32; 64];
        let mut rb = vec![0.0f32; 64];
        a.logits_row(7, 3, &mut ra);
        b.logits_row(7, 3, &mut rb);
        assert_eq!(ra, rb, "kernel modes diverged on a single row");
        // whole decode steps agree too (same tokens downstream)
        let mut state_a = DecodeState {
            kc: HostTensor::zeros_f32(&a.kv_shape(4)),
            vc: HostTensor::zeros_f32(&a.kv_shape(4)),
        };
        let mut state_b = DecodeState {
            kc: HostTensor::zeros_f32(&b.kv_shape(4)),
            vc: HostTensor::zeros_f32(&b.kv_shape(4)),
        };
        let la = a
            .decode("sim", QuantMode::None, &[5, 9, 11, 2],
                    &[1, 2, 3, 4], &mut state_a, None)
            .unwrap();
        let lb = b
            .decode("sim", QuantMode::None, &[5, 9, 11, 2],
                    &[1, 2, 3, 4], &mut state_b, None)
            .unwrap();
        assert_eq!(la.as_f32().unwrap(), lb.as_f32().unwrap());
    }

    #[test]
    fn pooled_prefill_is_bit_identical_to_single_thread() {
        let clock = Rc::new(VirtualClock::new());
        let one = SimConfig { threads: 1, ..SimConfig::default() };
        let many = SimConfig { threads: 7, ..SimConfig::default() };
        let mut a = SimBackend::new(one, clock.clone());
        let mut b = SimBackend::new(many, clock);
        let tokens = prompt_tensor(&a.cfg.clone());
        let (la, _) =
            a.prefill("sim", QuantMode::None, &tokens, None).unwrap();
        let (lb, _) =
            b.prefill("sim", QuantMode::None, &tokens, None).unwrap();
        assert_eq!(la.as_f32().unwrap(), lb.as_f32().unwrap(),
                   "worker count changed prefill logits");
    }

    #[test]
    fn fused_and_two_step_attention_write_identical_caches() {
        // the fused packed pipeline and the two-step reference must
        // write the exact same attended vectors into the value cache,
        // for whole prefill planes and for decode steps
        let clock = Rc::new(VirtualClock::new());
        let mut a =
            SimBackend::new(SimConfig::default(), clock.clone());
        let two_cfg = SimConfig { fused_attention: false,
                                  ..SimConfig::default() };
        let mut b = SimBackend::new(two_cfg, clock);
        let tokens = prompt_tensor(&a.cfg.clone());
        let (_, mut sa) =
            a.prefill("sim", QuantMode::None, &tokens, None).unwrap();
        let (_, mut sb) =
            b.prefill("sim", QuantMode::None, &tokens, None).unwrap();
        let va = sa.vc.as_f32().unwrap();
        let vb = sb.vc.as_f32().unwrap();
        assert_eq!(va, vb, "fused prefill attention diverged");
        // the attended payload is real data: every lane finite
        assert!(va.iter().all(|x| x.is_finite()));
        a.decode("sim", QuantMode::None, &[5], &[3], &mut sa, None)
            .unwrap();
        b.decode("sim", QuantMode::None, &[5], &[3], &mut sb, None)
            .unwrap();
        assert_eq!(sa.vc.as_f32().unwrap(), sb.vc.as_f32().unwrap(),
                   "fused decode attention diverged");
    }

    #[test]
    fn latency_charge_back_reads_the_shared_constants_table() {
        // the backend charges the clock through MachineModel::default,
        // which must be the same machine the cost CLI quotes: rebuild
        // it by hand from cost::constants and demand exact agreement
        use crate::cost::{constants, CycleTable};
        let (b, _clock) = backend();
        let model = MachineModel {
            mxu_bf16_macs: constants::MXU_BF16_MACS,
            mxu_fp8_macs: constants::MXU_FP8_MACS,
            vpu_lanes: constants::VPU_LANES,
            hbm_bytes_per_cycle: constants::HBM_BYTES_PER_CYCLE,
            cycles: CycleTable {
                exp: constants::EXP_CYCLES,
                lut: constants::LUT_CYCLES,
                quant: constants::QUANT_CYCLES,
                add: constants::ADD_CYCLES,
                div: constants::DIV_CYCLES,
            },
        };
        for batch in [1usize, 4] {
            let want = model.prefill_cycles(b.cfg.shape(batch),
                                            b.cfg.gemm_precision,
                                            Some(b.cfg.shape_bits))
                / b.cfg.clock_hz;
            assert_eq!(b.prefill_seconds(batch).to_bits(),
                       want.to_bits(),
                       "prefill charge drifted from the table");
            let want = model
                .decode_step_cycles(b.cfg.shape(batch),
                                    b.cfg.gemm_precision,
                                    Some(b.cfg.shape_bits), batch,
                                    b.cfg.max_seq)
                / b.cfg.clock_hz;
            assert_eq!(b.decode_seconds(batch).to_bits(),
                       want.to_bits(),
                       "decode charge drifted from the table");
        }
    }

    #[test]
    fn streaming_attention_writes_identical_caches() {
        // the one-pass streaming kernel must land the exact same
        // attended vectors in the value cache as the fused plane, for
        // whole prefill planes and for decode steps
        let clock = Rc::new(VirtualClock::new());
        let mut a =
            SimBackend::new(SimConfig::default(), clock.clone());
        let stream_cfg = SimConfig { streaming_attention: true,
                                     ..SimConfig::default() };
        let mut b = SimBackend::new(stream_cfg, clock);
        let tokens = prompt_tensor(&a.cfg.clone());
        let (la, mut sa) =
            a.prefill("sim", QuantMode::None, &tokens, None).unwrap();
        let (lb, mut sb) =
            b.prefill("sim", QuantMode::None, &tokens, None).unwrap();
        assert_eq!(la.as_f32().unwrap(), lb.as_f32().unwrap(),
                   "streaming mode changed prefill logits");
        assert_eq!(sa.vc.as_f32().unwrap(), sb.vc.as_f32().unwrap(),
                   "streaming prefill attention diverged");
        a.decode("sim", QuantMode::None, &[5], &[3], &mut sa, None)
            .unwrap();
        b.decode("sim", QuantMode::None, &[5], &[3], &mut sb, None)
            .unwrap();
        assert_eq!(sa.vc.as_f32().unwrap(), sb.vc.as_f32().unwrap(),
                   "streaming decode attention diverged");
    }

    #[test]
    fn decode_attention_lands_at_the_slot_position() {
        // the attended vector for (slot, head, pos) must overwrite
        // exactly the layer-0 cache lanes at that position
        let (mut b, _clock) = backend();
        let mut state = DecodeState {
            kc: HostTensor::zeros_f32(&b.kv_shape(2)),
            vc: HostTensor::zeros_f32(&b.kv_shape(2)),
        };
        b.decode("sim", QuantMode::None, &[5, 9], &[3, 7],
                 &mut state, None)
            .unwrap();
        let vc = state.vc.as_f32().unwrap();
        let (heads, seq, hd) = (2usize, 64usize, 4usize);
        for (i, &p) in [3usize, 7].iter().enumerate() {
            for h in 0..heads {
                let at = ((i * heads + h) * seq + p) * hd;
                let row = &vc[at..at + hd];
                assert!(row.iter().any(|&x| x != 0.0),
                        "slot {i} head {h} untouched");
                // the neighbouring position stays zero
                let next = ((i * heads + h) * seq + p + 1) * hd;
                assert!(vc[next..next + hd].iter().all(|&x| x == 0.0));
            }
        }
    }

    #[test]
    fn eos_bias_forces_eos_argmax_somewhere() {
        let clock = Rc::new(VirtualClock::new());
        let cfg = SimConfig { eos_bias: 0.5, ..SimConfig::default() };
        let mut b = SimBackend::new(cfg, clock);
        let mut hits = 0;
        let mut row = vec![0.0f32; 64];
        for pos in 0..32 {
            b.logits_row(9, pos, &mut row);
            let argmax = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap();
            if argmax == 2 {
                hits += 1;
            }
        }
        assert!(hits >= 8, "eos bias too weak: {hits}/32");
    }

    #[test]
    fn rejects_unknown_model_and_bad_shapes() {
        let (mut b, _) = backend();
        let tokens = prompt_tensor(&b.cfg.clone());
        assert!(b.prefill("nope", QuantMode::None, &tokens, None)
            .is_err());
        let short = HostTensor::i32(vec![1; 8], &[1, 8]);
        assert!(b.prefill("sim", QuantMode::None, &short, None)
            .is_err());
        assert!(b
            .prefill("sim", QuantMode::Static { bits: 2 }, &tokens,
                     None)
            .is_err());
    }

    #[test]
    fn decode_stamps_cache_and_costs_time() {
        let (mut b, clock) = backend();
        let mut state = DecodeState {
            kc: HostTensor::zeros_f32(&b.kv_shape(8)),
            vc: HostTensor::zeros_f32(&b.kv_shape(8)),
        };
        let t0 = clock.now();
        let logits = b
            .decode("sim", QuantMode::None, &[5; 8],
                    &[3, 3, 3, 3, 3, 3, 3, 3], &mut state, None)
            .unwrap();
        assert_eq!(logits.shape, vec![8, 64]);
        assert!(clock.now() > t0);
        let kc = state.kc.as_f32().unwrap();
        // slot 2, layer 0, head 0, pos 3, dim 0
        assert_eq!(kc[(2 * 2 * 64 + 3) * 4], 5.0);
    }

    #[test]
    fn prefill_stats_rows_are_plausible() {
        let (mut b, _) = backend();
        let tokens = prompt_tensor(&b.cfg.clone());
        let (_, stats) =
            b.prefill_stats("sim", &tokens, &[64]).unwrap();
        assert_eq!(stats.shape, vec![2, 4]);
        for row in stats.as_f32().unwrap().chunks(4) {
            assert!(row[0] > 0.0);
            assert!(row[2] >= 0.0);
            assert!(row[3] <= 0.0);
        }
    }
}
