//! Host-side tensors crossing the PJRT boundary.

use crate::util::error::{bail, Result};

use super::pjrt as xla;

/// A dense host tensor (f32 or i32), row-major.
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostTensor {
    pub fn f32(data: Vec<f32>, shape: &[usize]) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        Self { shape: shape.to_vec(), data: TensorData::F32(data) }
    }

    pub fn i32(data: Vec<i32>, shape: &[usize]) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        Self { shape: shape.to_vec(), data: TensorData::I32(data) }
    }

    pub fn zeros_f32(shape: &[usize]) -> Self {
        Self::f32(vec![0.0; shape.iter().product()], shape)
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::I32(_) => bail!("tensor is i32, expected f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::I32(_) => bail!("tensor is i32, expected f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            TensorData::F32(_) => bail!("tensor is f32, expected i32"),
        }
    }

    /// Convert a PJRT literal (array, f32/s32) into a host tensor.
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize)
            .collect();
        match shape.ty() {
            xla::ElementType::F32 => {
                Ok(Self::f32(lit.to_vec::<f32>()?, &dims))
            }
            xla::ElementType::S32 => {
                Ok(Self::i32(lit.to_vec::<i32>()?, &dims))
            }
            other => bail!("unsupported literal element type {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_accessors() {
        let t = HostTensor::f32(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        assert_eq!(t.len(), 4);
        assert_eq!(t.as_f32().unwrap()[3], 4.0);
        assert!(t.as_i32().is_err());
        let t = HostTensor::i32(vec![7], &[1]);
        assert_eq!(t.as_i32().unwrap(), &[7]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        HostTensor::f32(vec![1.0], &[2, 2]);
    }
}
