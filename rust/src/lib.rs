//! exaq-repro — reproduction of "EXAQ: Exponent Aware Quantization For
//! LLMs Acceleration" (Shkolnik et al., 2024) as a three-layer
//! Rust + JAX + Pallas serving stack.
//!
//! Layer map (see DESIGN.md):
//! * [`exaq`] — the paper's method: analytic clipping (§3), LUT-based
//!   softmax (§4), quantizer and calibration-derived thresholds.
//! * [`runtime`] — execution backends behind the `InferenceBackend`
//!   trait: the PJRT engine that loads the AOT-lowered HLO artifacts
//!   produced by `python/compile/aot.py` (gated behind the `pjrt`
//!   feature; Python is never on the request path), and the
//!   deterministic `SimBackend` that drives the serving stack with
//!   seeded logits + cost-model latency and no artifacts at all.
//! * [`coordinator`] — continuous-batching serving: admission, prefill /
//!   decode scheduling, KV slot pool, metrics, scenario workload
//!   generation; timestamped through the `util::clock::Clock` trait
//!   (wall or virtual time).
//! * [`eval`] — lm-evaluation-harness-style zero-shot scoring over seven
//!   synthetic task families (Tables 2/4/5/6).
//! * [`calib`] — runtime calibration driver (Fig. 6, clip thresholds).
//! * [`cost`] — cycle-accurate cost model (Fig. 1, Table 3 accounting).
//! * [`model`] — tokenizer + sampling.
//! * [`report`] — table / CSV renderers for the experiment harness.
//! * [`lint`] — the repo-specific determinism lint pass behind
//!   `repro lint` (clock/RNG/iteration/panic/float-reduction rules;
//!   see CONTRIBUTING.md §Determinism invariants).

pub mod calib;
pub mod coordinator;
pub mod cost;
pub mod eval;
pub mod exaq;
pub mod lint;
pub mod model;
pub mod report;
pub mod runtime;
pub mod util;
