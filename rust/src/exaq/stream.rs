//! Streaming one-pass attention: QK^T fused into the packed plane —
//! the dense f32 score plane is **never materialized**.
//!
//! [`AttentionPlane::attend`](super::plane::AttentionPlane::attend)
//! already keeps scores packed from quantization to the weighted-value
//! (PV) pass, but it *receives* a fully dense `[rows × len]` f32 score
//! plane, so at long context the QK^T round trip dominates the very
//! traffic the packed layout removes. Because Algorithm 2 replaces the
//! running max-subtraction with the analytically clipped input, there
//! is no flash-attention-style rescale: a tile-by-tile pass over KV is
//! *exact*, not approximate. [`StreamingAttention`] exploits that:
//!
//! 1. **Max pass** — per `TILE_LANES`-wide KV tile, produce the QK^T
//!    strip ([`simd::qk_strip`], fixed 4-accumulator tree, mul-then-
//!    add, never FMA) into one strip buffer and fold
//!    [`simd::row_max`] over it. Algorithm 2 still max-shifts against
//!    the *final* row max, so the strip is produced twice per tile —
//!    a deliberate 2× QK^T compute trade for O(1) score memory. `max`
//!    is exact and NaN-losing at every level, so the tile-wise fold
//!    equals the whole-row scan in value, and a ±0.0 sign difference
//!    washes out in `code(x - m)`.
//! 2. **Encode pass** — regenerate each strip and quantize it straight
//!    into the row's packed keys via the shared `simd` encode lanes.
//!    Tile seams are group-aligned (`TILE_LANES` is a multiple of
//!    every LUT_sum group), the quantize is lane-local, and the
//!    partial final group can only occur in the row's last tile — so
//!    the key stream is bit-identical to the whole-row encode in
//!    `plane.rs`. Keys are folded online through the fixed-tree
//!    [`KeySumStream`], bit-identical to one
//!    [`LutSum::sum_keys`](super::lut::LutSum::sum_keys) call.
//! 3. **PV pass** — the premultiplied `lut_exp[code] * inv` decode
//!    runs fused into the value accumulation, reusing `plane.rs`'s
//!    block structure and `pv_g4` / `pv_g2` / `pv_generic` verbatim.
//!
//! Peak f32 score storage is one `TILE_LANES` strip per worker
//! (`footprint::streaming_strip_bytes()` quotes the conservative
//! `TILE_ROWS × TILE_LANES` budget) — independent of `len`, versus
//! `dense_plane_bytes(rows, len)` for the two-step and fused paths.
//!
//! **Bit-exactness contract.** [`StreamingAttention::attend_scores`]
//! is bit-identical to `AttentionPlane::attend` (and therefore to
//! quantize → `softmax_rows` → dense PV) at every M, every available
//! SIMD level, and every worker count; rows are chunked through
//! `util::pool` with regions fixed before any worker starts.
//! [`StreamingAttention::attend`] is the same kernel with the strips
//! produced by `simd::qk_strip` instead of copied from a dense input,
//! so it is bit-identical to feeding those strip scores through any
//! of the dense-input paths. `rust/tests/streaming_attention.rs`
//! sweeps both claims.

use super::batched::{BatchSoftmax, PackedCodes};
use super::lut::{KeySumStream, LutExp, LutSum, PackedKey};
use super::plane::{self, row_valid, NORM_LANES, TILE_LANES, TILE_ROWS};
use super::quant::Quantizer;
use super::simd;
use crate::util::pool;

/// The one-pass streaming attention kernel: a [`BatchSoftmax`] engine
/// for tables and policy, plus the packed key plane and per-row `inv`
/// scratch — and deliberately **no** f32 score plane.
pub struct StreamingAttention {
    engine: BatchSoftmax,
    /// The streaming path's own packed key plane.
    packed: PackedCodes,
    /// Per-row `1/Σexp` premultipliers.
    inv: Vec<f32>,
}

impl StreamingAttention {
    pub fn new(bits: u32, clip: f32) -> Self {
        Self {
            engine: BatchSoftmax::new(bits, clip),
            packed: PackedCodes::default(),
            inv: Vec::new(),
        }
    }

    pub fn bits(&self) -> u32 {
        self.engine.bits()
    }

    /// Codes per LUT_sum key (4 at M = 2, 2 at M = 3/4).
    pub fn group(&self) -> usize {
        self.engine.group()
    }

    /// Cache key check — same contract as [`BatchSoftmax::matches`].
    pub fn matches(&self, bits: u32, clip: f32) -> bool {
        self.engine.matches(bits, clip)
    }

    /// The wrapped engine (tables, scratch policy).
    pub fn engine(&self) -> &BatchSoftmax {
        &self.engine
    }

    /// Pin the worker count (0 = auto); output is bit-identical for
    /// every value.
    pub fn set_threads(&mut self, threads: usize) -> &mut Self {
        self.engine.set_threads(threads);
        self
    }

    pub fn threads(&self) -> usize {
        self.engine.threads()
    }

    /// Pin the lane level; unavailable levels fall back to scalar.
    pub fn set_simd_level(&mut self, level: simd::Level) -> &mut Self {
        self.engine.set_simd_level(level);
        self
    }

    pub fn simd_level(&self) -> simd::Level {
        self.engine.simd_level()
    }

    /// Current packed-plane footprint in bytes (both key widths).
    pub fn plane_bytes(&self) -> usize {
        self.packed.plane_bytes()
    }

    /// One-pass attention from Q/K/V: per KV tile, compute the QK^T
    /// strip (`q[r] · k[i] * scale`), quantize it into packed keys,
    /// fold the denominator online, then run the premultiplied PV
    /// decode — the `[rows × len]` f32 score plane never exists.
    /// `q` is `[rows × d_head]`, `keys_mat` and `values` are
    /// `[len × d_head]` row-major, `out` is `[rows × d_head]`.
    /// A causal mask is expressed through `valid_lens` (row `r`
    /// attends to lanes `< valid_lens[r]`); rows with `valid_len == 0`
    /// come back all-zero.
    #[allow(clippy::too_many_arguments)]
    pub fn attend(&mut self, q: &[f32], rows: usize, len: usize,
                  valid_lens: &[usize], keys_mat: &[f32],
                  values: &[f32], d_head: usize, scale: f32,
                  out: &mut [f32]) {
        assert_eq!(q.len(), rows * d_head,
                   "q is {} floats, expected rows*d_head = {}",
                   q.len(), rows * d_head);
        assert_eq!(keys_mat.len(), len * d_head,
                   "keys are {} floats, expected len*d_head = {}",
                   keys_mat.len(), len * d_head);
        check_common(rows, len, valid_lens, values, d_head, out);
        out.fill(0.0);
        if rows == 0 || len == 0 || d_head == 0 {
            return;
        }
        let level = self.engine.simd_level();
        let fill = |r: usize, t0: usize, end: usize,
                    strip: &mut [f32]| {
            simd::qk_strip(level, &q[r * d_head..(r + 1) * d_head],
                           &keys_mat[t0 * d_head..end * d_head],
                           d_head, scale, strip);
        };
        self.run_with_fill(rows, len, valid_lens, values, d_head, out,
                           fill);
    }

    /// The dense-input front: same streaming kernel, with each tile
    /// strip copied out of a caller-materialized score plane instead
    /// of computed from Q·K. Bit-identical to
    /// [`AttentionPlane::attend`](super::plane::AttentionPlane::attend)
    /// — this is the entry point `runtime::sim` and the equivalence
    /// tests drive.
    pub fn attend_scores(&mut self, scores: &[f32], rows: usize,
                         len: usize, valid_lens: &[usize],
                         values: &[f32], d_head: usize,
                         out: &mut [f32]) {
        assert_eq!(scores.len(), rows * len,
                   "score plane is {} floats, expected rows*len = {}",
                   scores.len(), rows * len);
        check_common(rows, len, valid_lens, values, d_head, out);
        out.fill(0.0);
        if rows == 0 || len == 0 || d_head == 0 {
            return;
        }
        let fill = |r: usize, t0: usize, end: usize,
                    strip: &mut [f32]| {
            strip.copy_from_slice(
                &scores[r * len + t0..r * len + end]);
        };
        self.run_with_fill(rows, len, valid_lens, values, d_head, out,
                           fill);
    }

    /// Dispatch by M, mirroring `AttentionPlane::attend`: byte keys +
    /// group-4 lanes at M = 2, u16 keys + group-2 lanes at M = 3/4,
    /// generic single-code keys otherwise.
    fn run_with_fill<F>(&mut self, rows: usize, len: usize,
                        valid_lens: &[usize], values: &[f32],
                        d_head: usize, out: &mut [f32], fill: F)
    where
        F: Fn(usize, usize, usize, &mut [f32]) + Sync,
    {
        let workers = self.engine.plan_workers(rows, len);
        let level = self.engine.simd_level();
        let (quant, lut_exp, lut_sum) = self.engine.tables();
        let group = lut_sum.group;
        let nl = lut_exp.table.len();
        let inv = &mut self.inv;
        let packed = &mut self.packed;
        let dims = (rows, len, d_head);
        match quant.bits {
            2 => drive_stream(
                packed.bytes_mut(), inv, dims, valid_lens,
                (group, nl), lut_exp, lut_sum, level, workers, out,
                &fill,
                |strip, m, keys, t0| encode_tile_g4(quant, level,
                                                    strip, m, keys,
                                                    t0),
                |keys, norm, span, orow| plane::pv_g4(level, keys,
                                                      norm, values,
                                                      d_head, span,
                                                      orow),
            ),
            3 | 4 => drive_stream(
                packed.words_mut(), inv, dims, valid_lens,
                (group, nl), lut_exp, lut_sum, level, workers, out,
                &fill,
                |strip, m, keys, t0| encode_tile_g2(quant, level,
                                                    strip, m, keys,
                                                    t0),
                |keys, norm, span, orow| plane::pv_g2(level,
                                                      quant.bits,
                                                      keys, norm,
                                                      values, d_head,
                                                      span, orow),
            ),
            b if b <= 2 => drive_stream(
                packed.bytes_mut(), inv, dims, valid_lens,
                (group, nl), lut_exp, lut_sum, level, workers, out,
                &fill,
                |strip, m, keys, t0| encode_tile_generic(quant,
                                                         lut_sum,
                                                         strip, m,
                                                         keys, t0),
                |keys, norm, span, orow| plane::pv_generic(level,
                                                           lut_sum,
                                                           keys, norm,
                                                           values,
                                                           d_head,
                                                           span,
                                                           orow),
            ),
            _ => drive_stream(
                packed.words_mut(), inv, dims, valid_lens,
                (group, nl), lut_exp, lut_sum, level, workers, out,
                &fill,
                |strip, m, keys, t0| encode_tile_generic(quant,
                                                         lut_sum,
                                                         strip, m,
                                                         keys, t0),
                |keys, norm, span, orow| plane::pv_generic(level,
                                                           lut_sum,
                                                           keys, norm,
                                                           values,
                                                           d_head,
                                                           span,
                                                           orow),
            ),
        }
    }
}

fn check_common(rows: usize, len: usize, valid_lens: &[usize],
                values: &[f32], d_head: usize, out: &[f32]) {
    assert_eq!(values.len(), len * d_head,
               "values are {} floats, expected len*d_head = {}",
               values.len(), len * d_head);
    assert_eq!(out.len(), rows * d_head,
               "out is {} floats, expected rows*d_head = {}",
               out.len(), rows * d_head);
    assert!(valid_lens.is_empty() || valid_lens.len() == rows,
            "valid_lens arity {} != rows {rows}", valid_lens.len());
}

/// Split the packed plane, `inv`, and `out` into matching row ranges
/// and run the three streaming passes over each — inline for one
/// worker, through the scoped pool otherwise. Chunk regions are fixed
/// before any worker starts (same carving as `plane::drive`), so
/// output is bit-identical for every worker count.
#[allow(clippy::too_many_arguments)]
fn drive_stream<K, F, E, P>(packed: &mut Vec<K>, inv: &mut Vec<f32>,
                            dims: (usize, usize, usize),
                            valid_lens: &[usize],
                            tables: (usize, usize), lut_exp: &LutExp,
                            lut_sum: &LutSum, level: simd::Level,
                            workers: usize, out: &mut [f32], fill: &F,
                            enc: E, pv: P)
where
    K: PackedKey + Send,
    F: Fn(usize, usize, usize, &mut [f32]) + Sync,
    E: Fn(&[f32], f32, &mut [K], usize) + Sync,
    P: Fn(&[K], &[f32], (usize, usize), &mut [f32]) + Sync,
{
    let (rows, len, d) = dims;
    let (group, _) = tables;
    let stride = len.div_ceil(group);
    packed.resize(rows * stride, K::default());
    inv.resize(rows, 0.0);
    if workers <= 1 {
        chunk_stream(0, packed, inv, out, (len, stride, d),
                     valid_lens, tables, lut_exp, lut_sum, level,
                     fill, &enc, &pv);
        return;
    }
    // Over-split by 4x for dynamic balance (same policy as
    // plane::drive and the batched kernel's drive_rows).
    let chunk_rows = rows.div_ceil(workers * 4).max(1);
    let mut chunks = Vec::new();
    let mut krest: &mut [K] = packed;
    let mut irest: &mut [f32] = inv;
    let mut orest: &mut [f32] = out;
    let mut r0 = 0usize;
    while r0 < rows {
        let take = chunk_rows.min(rows - r0);
        let (k, ktail) =
            std::mem::take(&mut krest).split_at_mut(take * stride);
        let (iv, itail) =
            std::mem::take(&mut irest).split_at_mut(take);
        let (o, otail) =
            std::mem::take(&mut orest).split_at_mut(take * d);
        chunks.push((r0, k, iv, o));
        krest = ktail;
        irest = itail;
        orest = otail;
        r0 += take;
    }
    pool::run_chunks(chunks, workers, |(r0, k, iv, o)| {
        chunk_stream(r0, k, iv, o, (len, stride, d), valid_lens,
                     tables, lut_exp, lut_sum, level, fill, &enc,
                     &pv);
    });
}

/// One chunk of rows through the three streaming passes. The only f32
/// score storage here is `strip`: one `TILE_LANES`-wide buffer reused
/// for every tile of every row — the dense plane never exists.
#[allow(clippy::too_many_arguments)]
fn chunk_stream<K, F, E, P>(r0: usize, keys: &mut [K],
                            inv: &mut [f32], out: &mut [f32],
                            geom: (usize, usize, usize),
                            valid_lens: &[usize],
                            tables: (usize, usize), lut_exp: &LutExp,
                            lut_sum: &LutSum, level: simd::Level,
                            fill: &F, enc: &E, pv: &P)
where
    K: PackedKey,
    F: Fn(usize, usize, usize, &mut [f32]),
    E: Fn(&[f32], f32, &mut [K], usize),
    P: Fn(&[K], &[f32], (usize, usize), &mut [f32]),
{
    let (len, stride, d) = geom;
    let (group, nl) = tables;
    let nrows = inv.len();
    let mut strip = [0.0f32; TILE_LANES];
    for (i, iv) in inv.iter_mut().enumerate() {
        let r = r0 + i;
        let n = row_valid(valid_lens, r, len);
        if n == 0 {
            *iv = 0.0;
            continue;
        }
        // Max pass: Algorithm 2 max-shifts against the final row max,
        // so every tile strip is produced once just to feed the fold.
        let mut m = f32::NEG_INFINITY;
        let mut t0 = 0usize;
        while t0 < n {
            let end = (t0 + TILE_LANES).min(n);
            fill(r, t0, end, &mut strip[..end - t0]);
            m = m.max(simd::row_max(level, &strip[..end - t0]));
            t0 = end;
        }
        // Encode pass: regenerate each strip, quantize it into the
        // row's packed keys, stream the keys through the fixed tree.
        let mut ks = KeySumStream::new();
        let mut t0 = 0usize;
        while t0 < n {
            let end = (t0 + TILE_LANES).min(n);
            fill(r, t0, end, &mut strip[..end - t0]);
            enc(&strip[..end - t0], m,
                &mut keys[i * stride..(i + 1) * stride], t0);
            ks.feed(lut_sum, &keys[i * stride + t0 / group
                                   ..i * stride + end.div_ceil(group)]);
            t0 = end;
        }
        let padded = n.next_multiple_of(group);
        let mut sum = ks.finish();
        sum -= (padded - n) as f32 * lut_exp.floor_value();
        *iv = 1.0 / sum.max(1e-30);
    }
    // PV pass: identical block structure to plane::chunk_attend —
    // premultiplied norm tables, TILE_ROWS rows sharing each resident
    // value tile, decode fused into the accumulate.
    let mut norm = [0.0f32; TILE_ROWS * NORM_LANES];
    let mut b0 = 0usize;
    while b0 < nrows {
        let bn = TILE_ROWS.min(nrows - b0);
        for bi in 0..bn {
            let iv = inv[b0 + bi];
            let dst = &mut norm[bi * NORM_LANES..bi * NORM_LANES + nl];
            for (nd, &e) in dst.iter_mut().zip(lut_exp.table.iter()) {
                *nd = e * iv;
            }
        }
        let mut t0 = 0usize;
        while t0 < len {
            let t1 = (t0 + TILE_LANES).min(len);
            for bi in 0..bn {
                let i = b0 + bi;
                let n = row_valid(valid_lens, r0 + i, len);
                let end = t1.min(n);
                if end <= t0 {
                    continue;
                }
                pv(&keys[i * stride..(i + 1) * stride],
                   &norm[bi * NORM_LANES..bi * NORM_LANES + nl],
                   (t0, end), &mut out[i * d..(i + 1) * d]);
            }
            t0 = t1;
        }
        b0 += bn;
    }
}

/// M = 2: quantize one strip tile straight into the row's byte keys.
/// Bit-identical to the whole-row `encode_g4` front in `plane.rs`:
/// the quantize is lane-local, `t0` is a multiple of `TILE_LANES` (so
/// key boundaries align), and the partial final group can only occur
/// in the row's last tile, where the `2*j` shifts match the whole-row
/// tail.
fn encode_tile_g4(quant: &Quantizer, level: simd::Level,
                  strip: &[f32], m: f32, keys: &mut [u8],
                  t0: usize) {
    let k0 = t0 / 4;
    let full = strip.len() / 4;
    simd::quant_pack4(level, &strip[..full * 4], m, quant,
                      &mut keys[k0..k0 + full]);
    if full * 4 < strip.len() {
        let mut key = 0usize;
        for (j, &x) in strip[full * 4..].iter().enumerate() {
            key |= (quant.code(x - m) as usize) << (2 * j);
        }
        keys[k0 + full] = key as u8;
    }
}

/// M = 3/4: the tile-wise front of `encode_g2` (u16 pair keys; an odd
/// row end leaves exactly one low-code lane).
fn encode_tile_g2(quant: &Quantizer, level: simd::Level,
                  strip: &[f32], m: f32, keys: &mut [u16],
                  t0: usize) {
    let bits = quant.bits as usize;
    let k0 = t0 / 2;
    let full = strip.len() / 2;
    simd::quant_pack2(level, &strip[..full * 2], m, quant,
                      &mut keys[k0..k0 + full], bits);
    if full * 2 < strip.len() {
        keys[k0 + full] =
            quant.code(strip[strip.len() - 1] - m) as u16;
    }
}

/// Any other grouping (M = 1 and M >= 5): the tile-wise front of
/// `encode_generic`.
fn encode_tile_generic<K: PackedKey>(quant: &Quantizer,
                                     lut_sum: &LutSum, strip: &[f32],
                                     m: f32, keys: &mut [K],
                                     t0: usize) {
    let g = lut_sum.group;
    let bits = lut_sum.bits as usize;
    let k0 = t0 / g;
    let full = strip.len() / g;
    for (k, lanes) in keys[k0..k0 + full]
        .iter_mut()
        .zip(strip[..full * g].chunks_exact(g))
    {
        let mut key = 0usize;
        for (j, &x) in lanes.iter().enumerate() {
            key |= (quant.code(x - m) as usize) << (bits * j);
        }
        *k = K::pack(key);
    }
    if full * g < strip.len() {
        let mut key = 0usize;
        for (j, &x) in strip[full * g..].iter().enumerate() {
            key |= (quant.code(x - m) as usize) << (bits * j);
        }
        keys[k0 + full] = K::pack(key);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exaq::plane::AttentionPlane;
    use crate::util::rng::SplitMix64;

    fn random(n: usize, seed: u64, scale: f32) -> Vec<f32> {
        let mut r = SplitMix64::new(seed);
        (0..n).map(|_| (r.normal() as f32) * scale).collect()
    }

    fn assert_bits_equal(a: &[f32], b: &[f32], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(),
                       "{what}: lane {i}: {x} vs {y}");
        }
    }

    #[test]
    fn streaming_scores_match_the_fused_plane_at_every_m() {
        let (rows, len, d) = (3usize, 21usize, 5usize);
        let vlens = [len, 0, 7];
        let scores = random(rows * len, 77, 2.0);
        let values = random(len * d, 78, 1.0);
        for bits in [1u32, 2, 3, 4, 5] {
            let clip = -4.5;
            let mut plane = AttentionPlane::new(bits, clip);
            let mut fused = vec![0.0f32; rows * d];
            plane.attend(&scores, rows, len, &vlens, &values, d,
                         &mut fused);
            let mut sa = StreamingAttention::new(bits, clip);
            let mut got = vec![0.0f32; rows * d];
            sa.attend_scores(&scores, rows, len, &vlens, &values, d,
                             &mut got);
            assert_bits_equal(&got, &fused, &format!("M={bits}"));
        }
    }

    #[test]
    fn streaming_matches_across_a_tile_seam() {
        // len straddles one TILE_LANES seam, so the per-row key
        // stream is fed in two KeySumStream slices
        let (rows, len, d) = (3usize, TILE_LANES + 3, 4usize);
        let scores = random(rows * len, 11, 3.0);
        let values = random(len * d, 12, 1.0);
        for bits in [2u32, 3, 4] {
            let mut plane = AttentionPlane::new(bits, -5.0);
            let mut fused = vec![0.0f32; rows * d];
            plane.attend(&scores, rows, len, &[], &values, d,
                         &mut fused);
            let mut sa = StreamingAttention::new(bits, -5.0);
            let mut got = vec![0.0f32; rows * d];
            sa.attend_scores(&scores, rows, len, &[], &values, d,
                             &mut got);
            assert_bits_equal(&got, &fused, &format!("M={bits}"));
        }
    }

    #[test]
    fn qkv_front_equals_scores_front_on_strip_scores() {
        // attend() must equal attend_scores() over a dense plane
        // built from the same qk_strip lanes.
        let (rows, len, d) = (4usize, 19usize, 6usize);
        let q = random(rows * d, 21, 1.0);
        let k = random(len * d, 22, 1.0);
        let values = random(len * d, 23, 1.0);
        let scale = 1.0 / (d as f32).sqrt();
        let mut sa = StreamingAttention::new(2, -4.0);
        let level = sa.simd_level();
        let mut scores = vec![0.0f32; rows * len];
        for r in 0..rows {
            simd::qk_strip(level, &q[r * d..(r + 1) * d], &k, d,
                           scale, &mut scores[r * len..(r + 1) * len]);
        }
        let vlens = [len, 11, 0, 5];
        let mut want = vec![0.0f32; rows * d];
        sa.attend_scores(&scores, rows, len, &vlens, &values, d,
                         &mut want);
        let mut got = vec![0.0f32; rows * d];
        sa.attend(&q, rows, len, &vlens, &k, &values, d, scale,
                  &mut got);
        assert_bits_equal(&got, &want, "qkv-vs-scores");
    }

    #[test]
    fn worker_counts_do_not_change_the_output() {
        let (rows, len, d) = (9usize, 33usize, 4usize);
        let scores = random(rows * len, 5, 3.0);
        let values = random(len * d, 6, 1.0);
        let mut sa = StreamingAttention::new(2, -4.0);
        let mut want = vec![0.0f32; rows * d];
        sa.set_threads(1)
            .attend_scores(&scores, rows, len, &[], &values, d,
                           &mut want);
        for workers in [2usize, 7, 0] {
            let mut got = vec![0.0f32; rows * d];
            sa.set_threads(workers)
                .attend_scores(&scores, rows, len, &[], &values, d,
                               &mut got);
            assert_bits_equal(&got, &want, &format!("w={workers}"));
        }
    }

    #[test]
    fn zero_geometry_is_a_no_op() {
        let mut sa = StreamingAttention::new(2, -4.0);
        let mut out: Vec<f32> = Vec::new();
        sa.attend_scores(&[], 0, 0, &[], &[], 0, &mut out);
        let mut out = vec![7.0f32; 3 * 2];
        sa.attend_scores(&[], 3, 0, &[], &[], 2, &mut out);
        assert!(out.iter().all(|&x| x == 0.0));
        let mut out = vec![7.0f32; 2 * 3];
        sa.attend(&[0.0; 6], 2, 0, &[], &[], &[], 3, 1.0, &mut out);
        assert!(out.iter().all(|&x| x == 0.0));
    }
}
