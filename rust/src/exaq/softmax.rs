//! Softmax implementations — the subjects of paper Table 3.
//!
//! * [`softmax_algo1`] — the original algorithm (Algo. 1): per-element
//!   transcendental `exp`, then N scalar accumulations, then N divides.
//! * [`softmax_algo2`] — the EXAQ algorithm (Algo. 2): quantize to M-bit
//!   codes, exponent via `LUT_exp` (one load per element), denominator via
//!   `LUT_sum` over packed code groups (N/4 loads at M = 2), then
//!   normalise. Also the L3 hot path used on sampling logits.
//!
//! Both support a `valid_len` prefix mask with the closed-form
//! denominator correction ((N − n) · exp(C), since masked lanes sit on
//! code 0) described in DESIGN.md §4.

use super::lut::{LutExp, LutSum};
use super::quant::Quantizer;

/// Plain exact softmax (used by sampling when quantization is off).
pub fn softmax_exact(row: &mut [f32]) {
    let mut m = f32::NEG_INFINITY;
    for &x in row.iter() {
        m = m.max(x);
    }
    let mut sum = 0.0f32;
    for x in row.iter_mut() {
        *x = (*x - m).exp();
        // lint:allow(float-reduction-discipline): exact-exp reference
        // path; sequential scalar accumulation IS its definition
        sum += *x;
    }
    let inv = 1.0 / sum.max(1e-30);
    for x in row.iter_mut() {
        *x *= inv;
    }
}

/// Paper Algorithm 1, structured exactly as written: separate exponent
/// loop ("multi cycle op"), accumulation loop, and normalisation loop.
/// `valid_len` lanes participate; the rest are zeroed.
pub fn softmax_algo1(row: &mut [f32], valid_len: usize) {
    let n = valid_len.min(row.len());
    if n == 0 {
        row.fill(0.0);
        return;
    }
    // line 3: normalise by the max
    let mut m = f32::NEG_INFINITY;
    for &x in &row[..n] {
        m = m.max(x);
    }
    // lines 4-6: exponent per element (the multi-cycle op)
    for x in &mut row[..n] {
        *x = (*x - m).exp();
    }
    // lines 7-12: denominator accumulation, one add per element
    let mut sum = 0.0f32;
    let mut i = 0;
    while i < n {
        // lint:allow(float-reduction-discipline): Algorithm 1 is the
        // measured baseline — its N scalar adds are the subject
        sum += row[i];
        i += 1;
    }
    // lines 13-15: normalisation
    let inv = 1.0 / sum.max(1e-30);
    for x in &mut row[..n] {
        *x *= inv;
    }
    row[n..].fill(0.0);
}

/// Scratch buffers for [`softmax_algo2`] so the decode hot loop performs
/// no allocation (DESIGN.md §7 L3 target). Holds the row's packed
/// LUT_sum key stream (u16 covers every supported key width).
#[derive(Default)]
pub struct Algo2Scratch {
    keys: Vec<u16>,
}

/// Paper Algorithm 2: M-bit quantization + LUT_exp + packed LUT_sum.
///
/// `row` is overwritten with probabilities; lanes >= `valid_len` become 0.
/// The denominator takes ceil(n/group) LUT_sum lookups over the *full*
/// padded row (masked lanes are code 0) minus the closed-form correction —
/// the same arithmetic as the Pallas kernel. The key stream and the
/// fixed-tree reduction ([`LutSum::sum_keys`]) are shared with the
/// batched plane kernel ([`crate::exaq::batched::BatchSoftmax`]), which
/// keeps the two paths bit-identical.
pub fn softmax_algo2(
    row: &mut [f32],
    valid_len: usize,
    quant: &Quantizer,
    lut_exp: &LutExp,
    lut_sum: &LutSum,
    scratch: &mut Algo2Scratch,
) {
    let len = row.len();
    let n = valid_len.min(len);
    if n == 0 {
        row.fill(0.0);
        return;
    }
    // line 3: max-shift
    let mut m = f32::NEG_INFINITY;
    for &x in &row[..n] {
        m = m.max(x);
    }
    // lines 4-13 fused single pass: quantize a group of `g` lanes,
    // store their LUT_exp values into the row, and pack the group's
    // LUT_sum key (lanes past `n` sit on code 0 — the zero pad).
    let g = lut_sum.group;
    let bits = lut_sum.bits as usize;
    let padded = n.next_multiple_of(g);
    let keys = &mut scratch.keys;
    keys.clear();
    let mut i = 0usize;
    while i < padded {
        let mut key = 0usize;
        for j in 0..g {
            let lane = i + j;
            if lane < n {
                let c = quant.code(row[lane] - m);
                row[lane] = lut_exp.get(c);
                key |= (c as usize) << (bits * j);
            }
        }
        keys.push(key as u16);
        i += g;
    }
    // denominator: shared fixed-tree reduction over the key stream,
    // then the masked-lane correction (every padded lane sits on
    // code 0 = exp(C))
    let mut sum = lut_sum.sum_keys(keys);
    sum -= (padded - n) as f32 * lut_exp.floor_value();
    let inv = 1.0 / sum.max(1e-30);

    // lines 14-16: normalise
    for x in &mut row[..n] {
        *x *= inv;
    }
    row[n..].fill(0.0);
}

/// Convenience wrapper for one-shot callers. The tables are held in a
/// thread-local cache keyed by (`bits`, `c`)
/// ([`crate::exaq::batched::with_cached_engine`]), so tests and
/// examples looping at a fixed configuration stop paying three table
/// builds per call.
pub fn softmax_algo2_once(row: &mut [f32], valid_len: usize, bits: u32,
                          c: f32) {
    crate::exaq::batched::with_cached_engine(bits, c, |engine| {
        engine.softmax_row(row, valid_len)
    });
}

/// Reference quantized softmax *without* the LUT path (direct exp of the
/// quantized values) — the oracle for algo2 in tests.
pub fn softmax_quant_direct(row: &mut [f32], valid_len: usize, bits: u32,
                            c: f32) {
    let q = Quantizer::new(bits, c);
    let n = valid_len.min(row.len());
    if n == 0 {
        row.fill(0.0);
        return;
    }
    let mut m = f32::NEG_INFINITY;
    for &x in &row[..n] {
        m = m.max(x);
    }
    let mut sum = 0.0f32;
    for x in &mut row[..n] {
        *x = q.dequant(*x - m).exp();
        // lint:allow(float-reduction-discipline): non-LUT oracle for
        // algo2 tests — deliberately independent of LutSum::sum_keys
        sum += *x;
    }
    let inv = 1.0 / sum.max(1e-30);
    for x in &mut row[..n] {
        *x *= inv;
    }
    row[n..].fill(0.0);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    fn random_row(n: usize, seed: u64, scale: f32) -> Vec<f32> {
        let mut r = SplitMix64::new(seed);
        (0..n).map(|_| (r.normal() as f32) * scale).collect()
    }

    #[test]
    fn exact_softmax_sums_to_one() {
        let mut row = random_row(64, 1, 2.0);
        softmax_exact(&mut row);
        let s: f32 = row.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
        assert!(row.iter().all(|&p| p >= 0.0));
    }

    #[test]
    fn algo1_equals_exact_on_full_rows() {
        let mut a = random_row(48, 2, 3.0);
        let mut b = a.clone();
        softmax_exact(&mut a);
        softmax_algo1(&mut b, 48);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn algo2_matches_direct_quantized_reference() {
        for bits in [2u32, 3, 4] {
            for vlen in [1usize, 5, 31, 64] {
                let mut a = random_row(64, 3 + bits as u64, 2.5);
                let mut b = a.clone();
                softmax_algo2_once(&mut a, vlen, bits, -5.0);
                softmax_quant_direct(&mut b, vlen, bits, -5.0);
                for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                    assert!((x - y).abs() < 2e-5,
                            "bits={bits} vlen={vlen} lane {i}: {x} vs {y}");
                }
            }
        }
    }

    #[test]
    fn algo2_probabilities_sum_to_one_over_valid_lanes() {
        let mut row = random_row(60, 9, 1.5);
        softmax_algo2_once(&mut row, 41, 2, -4.0);
        let s: f32 = row.iter().sum();
        assert!((s - 1.0).abs() < 1e-4, "{s}");
        assert!(row[41..].iter().all(|&p| p == 0.0));
    }

    #[test]
    fn algo2_handles_row_len_not_divisible_by_group() {
        let mut row = random_row(13, 11, 2.0);
        softmax_algo2_once(&mut row, 13, 2, -4.0);
        let s: f32 = row.iter().sum();
        assert!((s - 1.0).abs() < 1e-4, "{s}");
    }

    #[test]
    fn empty_and_degenerate_rows() {
        let mut row = vec![1.0f32; 8];
        softmax_algo1(&mut row, 0);
        assert!(row.iter().all(|&p| p == 0.0));
        let mut row = vec![0.0f32; 8];
        softmax_algo2_once(&mut row, 8, 2, -4.0);
        let s: f32 = row.iter().sum();
        assert!((s - 1.0).abs() < 1e-5); // all-equal row -> uniform
        assert!((row[0] - 0.125).abs() < 1e-5);
    }

    #[test]
    fn valid_len_beyond_row_length_is_clamped() {
        // hostile valid_len values must behave exactly like the full row
        for vlen in [64usize, 65, 1000, usize::MAX] {
            let mut a = random_row(64, 17, 2.0);
            let mut b = a.clone();
            softmax_algo2_once(&mut a, vlen, 2, -4.0);
            softmax_algo2_once(&mut b, 64, 2, -4.0);
            assert_eq!(a, b, "vlen={vlen} diverged from the clamp");
            let s: f32 = a.iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "vlen={vlen}: sum {s}");
        }
        // algo1 takes the same clamp path
        let mut a = random_row(48, 18, 2.0);
        let mut b = a.clone();
        softmax_algo1(&mut a, usize::MAX);
        softmax_algo1(&mut b, 48);
        assert_eq!(a, b);
    }

    #[test]
    fn all_neg_infinity_rows_degrade_to_uniform() {
        // (-inf) - (-inf) = NaN after the max shift; the quantizer's
        // branchless clamp collapses NaN to code 0, so both quantized
        // paths agree on a uniform distribution instead of emitting NaN
        for bits in [1u32, 2, 3, 4] {
            let n = 24usize;
            let mut row = vec![f32::NEG_INFINITY; n];
            softmax_algo2_once(&mut row, n, bits, -5.0);
            let mut direct = vec![f32::NEG_INFINITY; n];
            softmax_quant_direct(&mut direct, n, bits, -5.0);
            for (i, (&p, &d)) in row.iter().zip(&direct).enumerate() {
                assert!(p.is_finite(), "bits={bits} lane {i} is {p}");
                assert!((p - 1.0 / n as f32).abs() < 1e-5,
                        "bits={bits} lane {i}: {p} != uniform");
                assert!((p - d).abs() < 1e-6,
                        "bits={bits} lane {i}: algo2 {p} vs direct {d}");
            }
        }
        // partial masks over -inf rows stay uniform over the prefix
        let mut row = vec![f32::NEG_INFINITY; 16];
        softmax_algo2_once(&mut row, 5, 2, -4.0);
        let s: f32 = row[..5].iter().sum();
        assert!((s - 1.0).abs() < 1e-4, "{s}");
        assert!(row[5..].iter().all(|&p| p == 0.0));
    }

    #[test]
    fn one_bit_quantization_still_normalises() {
        // bits = 1: two levels {C, 0}, LUT_sum group of 1
        for vlen in [1usize, 7, 32] {
            let mut a = random_row(32, 23, 2.0);
            let mut b = a.clone();
            softmax_algo2_once(&mut a, vlen, 1, -3.0);
            softmax_quant_direct(&mut b, vlen, 1, -3.0);
            let s: f32 = a.iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "vlen={vlen}: sum {s}");
            for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                assert!((x - y).abs() < 2e-5,
                        "vlen={vlen} lane {i}: {x} vs {y}");
            }
            assert!(a[vlen.min(32)..].iter().all(|&p| p == 0.0));
        }
    }

    #[test]
    fn randomized_sweep_matches_direct_reference_across_seeds() {
        // property-style sweep (hand-rolled; the image has no
        // proptest): random lengths, masks, bit-widths and clips must
        // keep algo2 glued to the non-LUT quantized reference and
        // normalised over the valid prefix
        let mut meta = SplitMix64::new(0xA1B2);
        for trial in 0..200 {
            let n = 1 + meta.below(96);
            let vlen = 1 + meta.below(n + 8); // sometimes > n: clamped
            let bits = 1 + meta.below(4) as u32;
            let c = -1.0 - 3.0 * meta.uniform() as f32 * 2.0;
            let scale = 0.5 + meta.uniform() as f32 * 3.0;
            let mut a = random_row(n, 1000 + trial, scale);
            let mut b = a.clone();
            softmax_algo2_once(&mut a, vlen, bits, c);
            softmax_quant_direct(&mut b, vlen, bits, c);
            let s: f32 = a.iter().sum();
            assert!((s - 1.0).abs() < 1e-3,
                    "trial {trial} (n={n} vlen={vlen} bits={bits} \
                     c={c}): sum {s}");
            for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                assert!((x - y).abs() < 5e-5,
                        "trial {trial} lane {i}: {x} vs {y}");
            }
            let valid = vlen.min(n);
            assert!(a[valid..].iter().all(|&p| p == 0.0),
                    "trial {trial}: masked lanes leaked");
            assert!(a[..valid].iter().all(|&p| p >= 0.0),
                    "trial {trial}: negative probability");
        }
    }

    #[test]
    fn quantized_softmax_close_to_exact_at_reasonable_bits() {
        // at M=4 with a good clip, quantized softmax tracks the exact one
        let mut a = random_row(64, 21, 1.0);
        let mut b = a.clone();
        softmax_exact(&mut a);
        softmax_algo2_once(&mut b, 64, 4, -6.0);
        let max_err = a
            .iter()
            .zip(&b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 0.05, "max_err {max_err}");
    }
}
