//! Numeric minimisation of the distortion model: the optimal clipping
//! threshold C*(sigma, M) of paper Fig. 3.
//!
//! Strategy: coarse grid scan over a wide bracket (robust to any local
//! wiggles of the model) followed by golden-section refinement around the
//! best cell. The paper solves Eq. 12 "numerically" the same way.
//!
//! The default model is the max-subtracted protocol
//! ([`MseModel::max_shifted`]) — the only reading that reproduces the
//! paper's Fig. 3 / Table 1 scale; see the soundness note in `mse.rs`.

use super::mse::MseModel;

const GOLDEN: f64 = 0.618_033_988_749_894_8;

/// Minimise `f` over [a, b] by golden-section search.
pub fn golden_section(
    mut a: f64,
    mut b: f64,
    tol: f64,
    f: impl Fn(f64) -> f64,
) -> f64 {
    let mut c = b - GOLDEN * (b - a);
    let mut d = a + GOLDEN * (b - a);
    let (mut fc, mut fd) = (f(c), f(d));
    while (b - a).abs() > tol {
        if fc < fd {
            b = d;
            d = c;
            fd = fc;
            c = b - GOLDEN * (b - a);
            fc = f(c);
        } else {
            a = c;
            c = d;
            fc = fd;
            d = a + GOLDEN * (b - a);
            fd = f(d);
        }
    }
    0.5 * (a + b)
}

/// Minimise a model's MSE over C by scan + golden-section refinement.
pub fn minimise_clip(model: &MseModel) -> f64 {
    let lo = model.mu - 8.0 * model.sigma - 2.0;
    let hi = -1e-3;
    let n = 200usize;
    let (mut best_i, mut best) = (0usize, f64::INFINITY);
    for i in 0..=n {
        let c = lo + (hi - lo) * i as f64 / n as f64;
        let v = model.mse(c);
        if v < best {
            best = v;
            best_i = i;
        }
    }
    let cell = (hi - lo) / n as f64;
    let a = lo + cell * best_i.saturating_sub(1) as f64;
    let b = (lo + cell * (best_i + 1) as f64).min(hi);
    golden_section(a, b, 1e-6, |c| model.mse(c))
}

/// Optimal clip threshold under the max-subtracted protocol (the Fig. 3 /
/// Table 1 quantity). Returns C* < 0.
pub fn optimal_clip(sigma: f64, bits: u32) -> f64 {
    minimise_clip(&MseModel::max_shifted(sigma, bits))
}

/// Optimal clip under the equations exactly as printed (μ = 0); kept for
/// the soundness analysis in EXPERIMENTS.md.
pub fn optimal_clip_mean_zero(sigma: f64, bits: u32) -> f64 {
    minimise_clip(&MseModel::mean_zero(sigma, bits))
}

/// The (sigma, C*) series of Fig. 3 over a sigma grid.
pub fn clip_series(
    sigma_lo: f64,
    sigma_hi: f64,
    n: usize,
    bits: u32,
) -> Vec<(f64, f64)> {
    (0..n)
        .map(|i| {
            let s = sigma_lo + (sigma_hi - sigma_lo) * i as f64
                / (n - 1) as f64;
            (s, optimal_clip(s, bits))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_section_finds_parabola_min() {
        let x = golden_section(-10.0, 10.0, 1e-9, |x| (x - 3.0).powi(2));
        assert!((x - 3.0).abs() < 1e-6, "{x}");
    }

    #[test]
    fn optimal_clip_is_negative_and_monotonic_in_sigma() {
        // Wider input distributions need a more negative clip.
        let mut prev = 0.0;
        for sigma in [0.5, 1.0, 2.0, 3.0, 4.0] {
            let c = optimal_clip(sigma, 2);
            assert!(c < 0.0);
            assert!(c < prev, "C*({sigma})={c} should be < {prev}");
            prev = c;
        }
    }

    #[test]
    fn more_bits_clip_more_negative() {
        // With more levels the rounding penalty of a wide range shrinks,
        // so the optimal clip keeps more of the tail (Fig. 3 ordering).
        for sigma in [1.0, 2.0, 3.0] {
            let c2 = optimal_clip(sigma, 2);
            let c3 = optimal_clip(sigma, 3);
            assert!(c3 < c2, "sigma={sigma}: C3*={c3} !< C2*={c2}");
        }
    }

    #[test]
    fn clip_is_global_minimum_on_grid() {
        let sigma = 1.7;
        let model = MseModel::max_shifted(sigma, 2);
        let cstar = minimise_clip(&model);
        let fstar = model.mse(cstar);
        for i in 1..200 {
            let c = -20.0 * i as f64 / 200.0;
            assert!(model.mse(c) >= fstar - 1e-12,
                    "mse({c}) < mse(C*={cstar})");
        }
    }

    #[test]
    fn matches_paper_table1_at_moderate_sigma() {
        // Around sigma ∈ [1, 2] our solver lands on the paper's Table 1
        // line; at larger sigma the published line is steeper than any
        // reading of the model we could reconstruct (documented in
        // EXPERIMENTS.md — the soundness band for this paper is 0/5).
        for (bits, slope, icpt) in [(2u32, -1.66, -1.85), (3, -1.75, -2.06)] {
            for sigma in [1.0, 1.5, 2.0] {
                let c = optimal_clip(sigma, bits);
                let lin = slope * sigma + icpt;
                assert!(
                    (c - lin).abs() < 0.6,
                    "bits={bits} sigma={sigma}: C*={c:.3} vs table1 {lin:.3}"
                );
            }
        }
    }

    #[test]
    fn mean_zero_reading_is_far_from_table1() {
        // The documented discrepancy: the literal μ=0 equations give a
        // much milder clip than Table 1.
        let c = optimal_clip_mean_zero(1.0, 2);
        assert!(c > -2.0, "got {c}, expected ≈ -1.46");
    }
}
