//! Monte-Carlo validation of the analytic clipping model — the
//! "simulation" series of paper Fig. 3.
//!
//! Protocol: draw 1000 samples from N(0, sigma^2), subtract the sample
//! maximum (the softmax pipeline's numeric-stability shift, §3 — without
//! it the empirical optimum sits near −0.2 and nowhere near Table 1; see
//! the soundness note in `mse.rs`), sweep the clip threshold C, measure
//! the empirical post-exponent MSE of the clip+quantize pipeline, and
//! report the empirically optimal C. The analytic solver and this
//! simulation should agree (Fig. 3 shows them overlapping).

use crate::util::rng::SplitMix64;

/// Empirical post-exponent MSE of clipping at `c` and mid-rise M-bit
/// quantization (the paper's Δ = −C/2^M convention, matching the model).
pub fn empirical_mse(samples: &[f64], c: f64, bits: u32) -> f64 {
    let delta = -c / (1u64 << bits) as f64;
    let max_code = (1u64 << bits) as f64 - 1.0;
    let mut acc = 0.0;
    for &x in samples {
        let xc = x.clamp(c, 0.0);
        let k = ((xc - c) / delta).floor().min(max_code);
        let q = c + (k + 0.5) * delta;
        let d = q.exp() - x.exp();
        acc += d * d;
    }
    acc / samples.len() as f64
}

/// Draw the paper's simulation sample set: N(0, sigma), max-subtracted.
pub fn draw_samples(sigma: f64, n: usize, seed: u64) -> Vec<f64> {
    let mut rng = SplitMix64::new(seed);
    let mut xs: Vec<f64> = (0..n).map(|_| rng.normal() * sigma).collect();
    let mx = xs.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    for x in &mut xs {
        *x -= mx;
    }
    xs
}

/// Size of each simulation draw — the paper's Fig. 3 caption uses 1000
/// samples, and the max-subtraction shift depends on this count, so it is
/// part of the protocol (see mse.rs).
pub const DRAW_SIZE: usize = crate::exaq::mse::FIG3_N_SAMPLES;

/// Empirically optimal clip for one sigma: `reps` independent draws of
/// [`DRAW_SIZE`] samples (each max-subtracted separately), pooled
/// empirical MSE, grid search over C.
pub fn simulated_optimal_clip(sigma: f64, bits: u32, reps: usize,
                              seed: u64) -> f64 {
    let draws: Vec<Vec<f64>> = (0..reps)
        .map(|r| draw_samples(sigma, DRAW_SIZE, seed + 1 + r as u64))
        .collect();
    let lo = -10.0 * sigma - 6.0;
    let hi = -1e-3;
    let n = 400;
    let (mut best_c, mut best) = (hi, f64::INFINITY);
    for i in 0..=n {
        let c = lo + (hi - lo) * i as f64 / n as f64;
        let v: f64 = draws.iter().map(|d| empirical_mse(d, c, bits)).sum();
        if v < best {
            best = v;
            best_c = c;
        }
    }
    best_c
}

/// The Fig. 3 simulation series over a sigma grid.
pub fn simulation_series(sigma_lo: f64, sigma_hi: f64, n_points: usize,
                         bits: u32, n_samples: usize,
                         seed: u64) -> Vec<(f64, f64)> {
    (0..n_points)
        .map(|i| {
            let s = sigma_lo
                + (sigma_hi - sigma_lo) * i as f64 / (n_points - 1) as f64;
            (s, simulated_optimal_clip(s, bits, n_samples, seed + 1000 * i as u64))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exaq::solver::optimal_clip;

    #[test]
    fn empirical_mse_zero_when_exactly_representable() {
        // samples exactly on reconstruction points -> zero error
        let c = -4.0;
        let bits = 2;
        let delta = -c / 4.0;
        let samples: Vec<f64> = (0..4).map(|k| c + (k as f64 + 0.5) * delta)
            .collect();
        assert!(empirical_mse(&samples, c, bits) < 1e-30);
    }

    #[test]
    fn simulation_agrees_with_analytic_solver() {
        // Fig. 3's headline: analysis and simulation overlap. Use a large
        // sample so the empirical optimum is stable.
        for bits in [2u32, 3] {
            for sigma in [1.0, 2.0, 3.0] {
                let analytic = optimal_clip(sigma, bits);
                let sim = simulated_optimal_clip(sigma, bits, 20, 99);
                assert!(
                    (analytic - sim).abs() < 0.7,
                    "bits={bits} sigma={sigma}: {analytic:.3} vs {sim:.3}"
                );
            }
        }
    }

    #[test]
    fn simulated_clip_monotonic_in_sigma() {
        let series = simulation_series(0.5, 3.5, 7, 2, 10, 5);
        for w in series.windows(2) {
            assert!(w[1].1 < w[0].1 + 0.3,
                    "roughly decreasing: {:?}", series);
        }
    }
}
