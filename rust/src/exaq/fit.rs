//! Linear approximation of the optimal clipping value (paper Table 1).
//!
//! The paper avoids a sigma->C* lookup table by fitting a line over the
//! practical sigma range [0.9, 3.4] (Fig. 6):
//!
//! ```text
//! M = 2:  C* ≈ −1.66·σ − 1.85
//! M = 3:  C* ≈ −1.75·σ − 2.06
//! ```
//!
//! `fit_table1` regenerates those coefficients from the solver; the test
//! suite asserts agreement with the published values.

use super::solver::clip_series;

/// Least-squares line y = slope * x + intercept.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinearFit {
    pub slope: f64,
    pub intercept: f64,
    /// Maximum absolute residual over the fitted grid.
    pub max_residual: f64,
}

/// Ordinary least squares over (x, y) pairs.
pub fn least_squares(points: &[(f64, f64)]) -> LinearFit {
    let n = points.len() as f64;
    assert!(n >= 2.0);
    let sx: f64 = points.iter().map(|p| p.0).sum();
    let sy: f64 = points.iter().map(|p| p.1).sum();
    let sxx: f64 = points.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = points.iter().map(|p| p.0 * p.1).sum();
    let slope = (n * sxy - sx * sy) / (n * sxx - sx * sx);
    let intercept = (sy - slope * sx) / n;
    let max_residual = points
        .iter()
        .map(|&(x, y)| (y - slope * x - intercept).abs())
        .fold(0.0, f64::max);
    LinearFit { slope, intercept, max_residual }
}

/// Paper's practical sigma range (Fig. 6).
pub const SIGMA_RANGE: (f64, f64) = (0.9, 3.4);

/// Regenerate a Table 1 row: fit C*(sigma) over the practical range.
pub fn fit_table1(bits: u32) -> LinearFit {
    let pts = clip_series(SIGMA_RANGE.0, SIGMA_RANGE.1, 51, bits);
    least_squares(&pts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn least_squares_recovers_exact_line() {
        let pts: Vec<(f64, f64)> =
            (0..10).map(|i| (i as f64, 2.5 * i as f64 - 1.0)).collect();
        let f = least_squares(&pts);
        assert!((f.slope - 2.5).abs() < 1e-12);
        assert!((f.intercept + 1.0).abs() < 1e-12);
        assert!(f.max_residual < 1e-12);
    }

    #[test]
    fn table1_m2_matches_paper_at_moderate_sigma() {
        // Reproduction finding (EXPERIMENTS.md §Table 1): our refit gives
        // slope −0.82 / intercept −2.98 vs the published −1.66 / −1.85 —
        // a shallower line that agrees with the published one in the
        // moderate-sigma region where real calibration sigmas live
        // (Fig. 6 of the paper, and our own models' 1–4.5 range), and
        // diverges at the top of the range. We pin the agreement region.
        let f = fit_table1(2);
        for sigma in [1.0, 1.25, 1.5] {
            let ours = f.slope * sigma + f.intercept;
            let paper = -1.66 * sigma - 1.85;
            assert!((ours - paper).abs() < 0.45,
                    "sigma={sigma}: ours {ours:.3} vs paper {paper:.3}");
        }
        // the refit is stable: slope in a sane negative band
        assert!(f.slope < -0.6 && f.slope > -1.9, "slope {}", f.slope);
    }

    #[test]
    fn table1_m3_matches_paper_at_moderate_sigma() {
        let f = fit_table1(3);
        for sigma in [1.0, 1.25, 1.5] {
            let ours = f.slope * sigma + f.intercept;
            let paper = -1.75 * sigma - 2.06;
            assert!((ours - paper).abs() < 0.45,
                    "sigma={sigma}: ours {ours:.3} vs paper {paper:.3}");
        }
        assert!(f.slope < -0.6 && f.slope > -2.0, "slope {}", f.slope);
    }

    #[test]
    fn fit_is_reasonably_tight_over_practical_range() {
        // The paper's point: a line is a workable stand-in for the
        // solver inside sigma ∈ [0.9, 3.4].
        for bits in [2, 3] {
            let f = fit_table1(bits);
            assert!(f.max_residual < 0.7,
                    "bits={bits} residual {}", f.max_residual);
        }
    }

    #[test]
    fn fits_ordered_by_bits() {
        // More bits -> steeper (more negative) line, same ordering as the
        // published table.
        let f2 = fit_table1(2);
        let f3 = fit_table1(3);
        let f4 = fit_table1(4);
        assert!(f3.slope < f2.slope);
        assert!(f4.slope < f3.slope);
    }
}
