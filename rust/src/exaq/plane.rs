//! Cache-blocked packed attention plane: scores stay in
//! [`PackedCodes`] form from QK^T to the weighted-value (PV) pass.
//!
//! [`BatchSoftmax::softmax_rows`] quantizes a `[rows × len]` score
//! plane into packed LUT_sum keys — and then decodes every lane back
//! into an f32 probability plane that the attention consumer reads
//! once and throws away. That round trip (4 bytes written + 4 bytes
//! re-read per lane) is exactly the memory traffic SoftmAP argues the
//! packed layout should remove: the win is the *data layout*, not
//! just the table lookup. [`AttentionPlane::attend`] keeps the codes
//! packed end to end:
//!
//! 1. **Encode** — each row is max-shifted, quantized, and packed by
//!    the same SIMD lanes the batched kernel uses
//!    ([`simd::quant_pack4`] / [`simd::quant_pack2`]), the
//!    denominator reduced through the shared fixed-tree
//!    [`LutSum::sum_keys`], and only the scalar `inv = 1/Σ` survives
//!    per row. No f32 probability is ever written.
//! 2. **PV** — the plane is tiled into `[TILE_ROWS × TILE_LANES]`
//!    blocks: a block of rows streams over one L1-resident tile of
//!    the `[len × d_head]` value matrix at a time, and the
//!    premultiplied `lut_exp[code] * inv` decode is fused into the
//!    value accumulation ([`simd::pv_accum4`] / [`simd::pv_accum2`]):
//!    `out[j] = out[j] + norm[code] * v[k][j]`, codes read straight
//!    from the packed keys in ascending lane order.
//!
//! **Bit-exactness contract.** `attend` is bit-identical to
//! [`AttentionPlane::attend_two_step`] (quantize → `softmax_rows` →
//! dense PV over the f32 plane) at every M, every available SIMD
//! level, and every worker count: both paths produce probabilities as
//! the identical `lut_exp[code] * inv` f32, and both fold value rows
//! in ascending-`k` order through the same separately-rounded
//! multiply-then-add lanes (never FMA — see `exaq/simd.rs`). Row
//! chunks go through `util::pool` with output regions fixed before
//! any worker starts, so worker count is a throughput knob only.
//!
//! This module owns the tiling constants ([`TILE_ROWS`],
//! [`TILE_LANES`]); the byte math derived from them lives in
//! `exaq::footprint` and is re-exported here
//! ([`packed_plane_bytes`], [`dense_plane_bytes`]) so the cost
//! model's `attention_plane_*` variants keep quoting one source.
//! Packed codes may be
//! decoded to f32 in exactly two places: the batched kernel's output
//! pass (`exaq/batched.rs`) and the fused PV accumulate here —
//! anything else reintroduces the round trip this module exists to
//! delete.

use std::cell::{Cell, RefCell};

use super::batched::{BatchSoftmax, PackedCodes};
use super::lut::{LutExp, LutSum, PackedKey};
use super::quant::Quantizer;
use super::simd;
use crate::util::pool;

/// Premultiplied-table capacity per row (2^8 codes at the max M).
/// Shared with the streaming kernel (`exaq::stream`), whose PV pass
/// reuses this module's block structure.
pub(crate) const NORM_LANES: usize = 256;

/// Key lanes per value tile: one tile of V is `TILE_LANES × d_head`
/// f32s (32 KiB at d_head = 64), sized to stay L1-resident while a
/// row block streams over it. Must stay a multiple of every LUT_sum
/// group (4 at M = 2) so tile seams never split a packed key.
pub const TILE_LANES: usize = 128;

/// Score rows per row block: every row of a block accumulates against
/// the resident value tile before the tile advances, so V is fetched
/// `rows / TILE_ROWS` times instead of `rows` times.
pub const TILE_ROWS: usize = 8;

pub use super::footprint::{dense_plane_bytes, packed_plane_bytes};

/// The fused attention-score pipeline: a [`BatchSoftmax`] engine for
/// tables and policy, plus the packed plane and per-row `inv` scratch
/// the fused path reuses across calls.
pub struct AttentionPlane {
    engine: BatchSoftmax,
    /// The fused path's own packed key plane (the engine keeps a
    /// separate one for `softmax_rows`).
    packed: PackedCodes,
    /// Per-row `1/Σexp` premultipliers (the only per-row f32 state the
    /// fused path keeps — the probability plane never exists).
    inv: Vec<f32>,
    /// f32 scratch for the two-step reference path only.
    probs: Vec<f32>,
}

impl AttentionPlane {
    pub fn new(bits: u32, clip: f32) -> Self {
        Self {
            engine: BatchSoftmax::new(bits, clip),
            packed: PackedCodes::default(),
            inv: Vec::new(),
            probs: Vec::new(),
        }
    }

    pub fn bits(&self) -> u32 {
        self.engine.bits()
    }

    /// Codes per LUT_sum key (4 at M = 2, 2 at M = 3/4).
    pub fn group(&self) -> usize {
        self.engine.group()
    }

    /// Cache key check — same contract as [`BatchSoftmax::matches`].
    pub fn matches(&self, bits: u32, clip: f32) -> bool {
        self.engine.matches(bits, clip)
    }

    /// The wrapped engine (tables, scratch policy, two-step softmax).
    pub fn engine(&self) -> &BatchSoftmax {
        &self.engine
    }

    /// Pin the worker count (0 = auto); output is bit-identical for
    /// every value.
    pub fn set_threads(&mut self, threads: usize) -> &mut Self {
        self.engine.set_threads(threads);
        self
    }

    pub fn threads(&self) -> usize {
        self.engine.threads()
    }

    /// Pin the lane level; unavailable levels fall back to scalar.
    pub fn set_simd_level(&mut self, level: simd::Level) -> &mut Self {
        self.engine.set_simd_level(level);
        self
    }

    pub fn simd_level(&self) -> simd::Level {
        self.engine.simd_level()
    }

    /// Current packed-plane footprint in bytes (both key widths).
    pub fn plane_bytes(&self) -> usize {
        self.packed.plane_bytes()
    }

    /// Fused attention over one packed score plane: quantize `scores`
    /// (`[rows × len]`) once, then accumulate
    /// `out[r] = Σ_k softmax(scores[r])[k] * values[k]` with the
    /// probabilities decoded from the packed keys *inside* the
    /// accumulation tile. `values` is `[len × d_head]` row-major,
    /// `out` is `[rows × d_head]`. Rows with `valid_len == 0` come
    /// back all-zero (matching `softmax_rows`' zero fill).
    pub fn attend(&mut self, scores: &[f32], rows: usize, len: usize,
                  valid_lens: &[usize], values: &[f32], d_head: usize,
                  out: &mut [f32]) {
        check_geom(scores, rows, len, valid_lens, values, d_head, out);
        out.fill(0.0);
        if rows == 0 || len == 0 || d_head == 0 {
            return;
        }
        let workers = self.engine.plan_workers(rows, len);
        let level = self.engine.simd_level();
        let (quant, lut_exp, lut_sum) = self.engine.tables();
        let group = lut_sum.group;
        let nl = lut_exp.table.len();
        let inv = &mut self.inv;
        let packed = &mut self.packed;
        let dims = (rows, len, d_head);
        match quant.bits {
            2 => drive(
                packed.bytes_mut(), inv, scores, dims, valid_lens,
                group, nl, lut_exp, workers, out,
                |row, keys, n| encode_g4(quant, lut_exp, lut_sum,
                                         level, row, keys, n),
                |keys, norm, span, orow| pv_g4(level, keys, norm,
                                               values, d_head, span,
                                               orow),
            ),
            3 | 4 => drive(
                packed.words_mut(), inv, scores, dims, valid_lens,
                group, nl, lut_exp, workers, out,
                |row, keys, n| encode_g2(quant, lut_exp, lut_sum,
                                         level, row, keys, n),
                |keys, norm, span, orow| pv_g2(level, quant.bits,
                                               keys, norm, values,
                                               d_head, span, orow),
            ),
            b if b <= 2 => drive(
                packed.bytes_mut(), inv, scores, dims, valid_lens,
                group, nl, lut_exp, workers, out,
                |row, keys, n| encode_generic(quant, lut_exp, lut_sum,
                                              row, keys, n),
                |keys, norm, span, orow| pv_generic(level, lut_sum,
                                                    keys, norm,
                                                    values, d_head,
                                                    span, orow),
            ),
            _ => drive(
                packed.words_mut(), inv, scores, dims, valid_lens,
                group, nl, lut_exp, workers, out,
                |row, keys, n| encode_generic(quant, lut_exp, lut_sum,
                                              row, keys, n),
                |keys, norm, span, orow| pv_generic(level, lut_sum,
                                                    keys, norm,
                                                    values, d_head,
                                                    span, orow),
            ),
        }
    }

    /// The two-step reference the fused path is measured (and
    /// bit-compared) against: `softmax_rows` materializes the f32
    /// probability plane, then a dense PV pass re-reads it. Same
    /// ascending-`k` accumulation through the same [`simd::pv_axpy`]
    /// lanes, so the output is bit-identical to [`Self::attend`].
    pub fn attend_two_step(&mut self, scores: &[f32], rows: usize,
                           len: usize, valid_lens: &[usize],
                           values: &[f32], d_head: usize,
                           out: &mut [f32]) {
        check_geom(scores, rows, len, valid_lens, values, d_head, out);
        out.fill(0.0);
        if rows == 0 || len == 0 || d_head == 0 {
            return;
        }
        self.probs.clear();
        self.probs.extend_from_slice(scores);
        self.engine.softmax_rows(&mut self.probs, rows, len,
                                 valid_lens);
        let workers = self.engine.plan_workers(rows, len);
        let level = self.engine.simd_level();
        let probs = &self.probs;
        if workers <= 1 {
            dense_pv(0, out, probs, (len, d_head), valid_lens, values,
                     level);
            return;
        }
        let chunk_rows = rows.div_ceil(workers * 4).max(1);
        let mut chunks = Vec::new();
        let mut orest: &mut [f32] = out;
        let mut r0 = 0usize;
        while r0 < rows {
            let take = chunk_rows.min(rows - r0);
            let (o, otail) =
                std::mem::take(&mut orest).split_at_mut(take * d_head);
            chunks.push((r0, o));
            orest = otail;
            r0 += take;
        }
        pool::run_chunks(chunks, workers, |(r0, o)| {
            dense_pv(r0, o, probs, (len, d_head), valid_lens, values,
                     level);
        });
    }
}

fn check_geom(scores: &[f32], rows: usize, len: usize,
              valid_lens: &[usize], values: &[f32], d_head: usize,
              out: &[f32]) {
    assert_eq!(scores.len(), rows * len,
               "score plane is {} floats, expected rows*len = {}",
               scores.len(), rows * len);
    assert_eq!(values.len(), len * d_head,
               "values are {} floats, expected len*d_head = {}",
               values.len(), len * d_head);
    assert_eq!(out.len(), rows * d_head,
               "out is {} floats, expected rows*d_head = {}",
               out.len(), rows * d_head);
    assert!(valid_lens.is_empty() || valid_lens.len() == rows,
            "valid_lens arity {} != rows {rows}", valid_lens.len());
}

pub(crate) fn row_valid(valid_lens: &[usize], r: usize,
                        len: usize) -> usize {
    if valid_lens.is_empty() { len } else { valid_lens[r].min(len) }
}

/// Split the packed plane, `inv`, and `out` into matching row ranges
/// and run the encode + tiled-PV passes over each — inline for one
/// worker, through the scoped pool otherwise. Chunk regions are fixed
/// before any worker starts, and every row only reads shared tables
/// plus its own lanes, so output is bit-identical for every count.
#[allow(clippy::too_many_arguments)]
fn drive<K, E, P>(packed: &mut Vec<K>, inv: &mut Vec<f32>,
                  scores: &[f32], dims: (usize, usize, usize),
                  valid_lens: &[usize], group: usize, nl: usize,
                  lut_exp: &LutExp, workers: usize, out: &mut [f32],
                  encode: E, pv: P)
where
    K: PackedKey + Send,
    E: Fn(&[f32], &mut [K], usize) -> f32 + Sync,
    P: Fn(&[K], &[f32], (usize, usize), &mut [f32]) + Sync,
{
    let (rows, len, d) = dims;
    let stride = len.div_ceil(group);
    packed.resize(rows * stride, K::default());
    inv.resize(rows, 0.0);
    if workers <= 1 {
        chunk_attend(0, packed, inv, out, scores, (len, stride, d),
                     valid_lens, nl, lut_exp, &encode, &pv);
        return;
    }
    // Over-split by 4x for dynamic balance (same policy as the
    // batched kernel's drive_rows).
    let chunk_rows = rows.div_ceil(workers * 4).max(1);
    let mut chunks = Vec::new();
    let mut krest: &mut [K] = packed;
    let mut irest: &mut [f32] = inv;
    let mut orest: &mut [f32] = out;
    let mut r0 = 0usize;
    while r0 < rows {
        let take = chunk_rows.min(rows - r0);
        let (k, ktail) =
            std::mem::take(&mut krest).split_at_mut(take * stride);
        let (iv, itail) =
            std::mem::take(&mut irest).split_at_mut(take);
        let (o, otail) =
            std::mem::take(&mut orest).split_at_mut(take * d);
        chunks.push((r0, k, iv, o));
        krest = ktail;
        irest = itail;
        orest = otail;
        r0 += take;
    }
    pool::run_chunks(chunks, workers, |(r0, k, iv, o)| {
        chunk_attend(r0, k, iv, o, scores, (len, stride, d),
                     valid_lens, nl, lut_exp, &encode, &pv);
    });
}

/// One chunk of rows: encode every row to packed keys + `inv`, then
/// run the cache-blocked PV pass — `TILE_ROWS` rows share each
/// `TILE_LANES`-wide value tile, with the premultiplied decode fused
/// into the accumulate.
#[allow(clippy::too_many_arguments)]
fn chunk_attend<K, E, P>(r0: usize, keys: &mut [K], inv: &mut [f32],
                         out: &mut [f32], scores: &[f32],
                         geom: (usize, usize, usize),
                         valid_lens: &[usize], nl: usize,
                         lut_exp: &LutExp, encode: &E, pv: &P)
where
    K: PackedKey,
    E: Fn(&[f32], &mut [K], usize) -> f32,
    P: Fn(&[K], &[f32], (usize, usize), &mut [f32]),
{
    let (len, stride, d) = geom;
    let nrows = inv.len();
    for (i, iv) in inv.iter_mut().enumerate() {
        let r = r0 + i;
        let n = row_valid(valid_lens, r, len);
        *iv = if n == 0 {
            0.0
        } else {
            encode(&scores[r * len..(r + 1) * len],
                   &mut keys[i * stride..(i + 1) * stride], n)
        };
    }
    // Per-block premultiplied tables: norm[bi][c] = lut_exp[c] * inv —
    // the identical f32 the batched kernel's fill_norm produces, so
    // fused probabilities match the two-step plane bit-for-bit.
    let mut norm = [0.0f32; TILE_ROWS * NORM_LANES];
    let mut b0 = 0usize;
    while b0 < nrows {
        let bn = TILE_ROWS.min(nrows - b0);
        for bi in 0..bn {
            let iv = inv[b0 + bi];
            let dst = &mut norm[bi * NORM_LANES..bi * NORM_LANES + nl];
            for (nd, &e) in dst.iter_mut().zip(lut_exp.table.iter()) {
                *nd = e * iv;
            }
        }
        let mut t0 = 0usize;
        while t0 < len {
            let t1 = (t0 + TILE_LANES).min(len);
            for bi in 0..bn {
                let i = b0 + bi;
                let n = row_valid(valid_lens, r0 + i, len);
                let end = t1.min(n);
                if end <= t0 {
                    continue;
                }
                pv(&keys[i * stride..(i + 1) * stride],
                   &norm[bi * NORM_LANES..bi * NORM_LANES + nl],
                   (t0, end), &mut out[i * d..(i + 1) * d]);
            }
            t0 = t1;
        }
        b0 += bn;
    }
}

/// M = 2 encode: bit-for-bit the front half of the batched kernel's
/// `row_g4` (SIMD quantize+pack, scalar tail group, fixed-tree
/// denominator, zero-pad correction), returning `1/Σ` instead of
/// decoding.
fn encode_g4(quant: &Quantizer, lut_exp: &LutExp, lut_sum: &LutSum,
             level: simd::Level, row: &[f32], keys: &mut [u8],
             n: usize) -> f32 {
    let m = simd::row_max(level, &row[..n]);
    let padded = n.next_multiple_of(4);
    let nkeys = padded / 4;
    let full = n / 4;
    let keys = &mut keys[..nkeys];
    simd::quant_pack4(level, &row[..full * 4], m, quant,
                      &mut keys[..full]);
    if full < nkeys {
        let mut key = 0usize;
        for (j, lane) in (full * 4..n).enumerate() {
            key |= (quant.code(row[lane] - m) as usize) << (2 * j);
        }
        keys[full] = key as u8;
    }
    let mut sum = lut_sum.sum_keys(keys);
    sum -= (padded - n) as f32 * lut_exp.floor_value();
    1.0 / sum.max(1e-30)
}

/// M = 3/4 encode: the front half of `row_g2`.
fn encode_g2(quant: &Quantizer, lut_exp: &LutExp, lut_sum: &LutSum,
             level: simd::Level, row: &[f32], keys: &mut [u16],
             n: usize) -> f32 {
    let bits = quant.bits as usize;
    let m = simd::row_max(level, &row[..n]);
    let padded = n.next_multiple_of(2);
    let nkeys = padded / 2;
    let full = n / 2;
    let keys = &mut keys[..nkeys];
    simd::quant_pack2(level, &row[..full * 2], m, quant,
                      &mut keys[..full], bits);
    if full < nkeys {
        keys[full] = quant.code(row[n - 1] - m) as u16;
    }
    let mut sum = lut_sum.sum_keys(keys);
    sum -= (padded - n) as f32 * lut_exp.floor_value();
    1.0 / sum.max(1e-30)
}

/// Any other grouping (M = 1 and M >= 5): the front half of
/// `row_generic`.
fn encode_generic<K: PackedKey>(quant: &Quantizer, lut_exp: &LutExp,
                                lut_sum: &LutSum, row: &[f32],
                                keys: &mut [K], n: usize) -> f32 {
    let g = lut_sum.group;
    let bits = lut_sum.bits as usize;
    let mut m = f32::NEG_INFINITY;
    for &x in &row[..n] {
        m = m.max(x);
    }
    let padded = n.next_multiple_of(g);
    let nkeys = padded / g;
    let full = n / g;
    let keys = &mut keys[..nkeys];
    for (k, lanes) in keys[..full]
        .iter_mut()
        .zip(row[..full * g].chunks_exact(g))
    {
        let mut key = 0usize;
        for (j, &x) in lanes.iter().enumerate() {
            key |= (quant.code(x - m) as usize) << (bits * j);
        }
        *k = K::pack(key);
    }
    if full < nkeys {
        let mut key = 0usize;
        for (j, lane) in (full * g..n).enumerate() {
            key |= (quant.code(row[lane] - m) as usize) << (bits * j);
        }
        keys[full] = K::pack(key);
    }
    let mut sum = lut_sum.sum_keys(keys);
    sum -= (padded - n) as f32 * lut_exp.floor_value();
    1.0 / sum.max(1e-30)
}

/// M = 2 PV over one tile span `[t0, end)` of one row: full byte keys
/// through [`simd::pv_accum4`], the row-end partial group decoded
/// lane-by-lane (same `key & 3; key >>= 2` walk as `row_g4`'s tail).
pub(crate) fn pv_g4(level: simd::Level, keys: &[u8], norm: &[f32],
                    values: &[f32], d: usize, span: (usize, usize),
                    orow: &mut [f32]) {
    let (t0, end) = span;
    let k0 = t0 / 4;
    let nfull = (end - t0) / 4;
    simd::pv_accum4(level, &keys[k0..k0 + nfull], norm,
                    &values[t0 * d..(t0 + nfull * 4) * d], d, orow);
    let done = t0 + nfull * 4;
    if done < end {
        let mut key = keys[k0 + nfull] as usize;
        for lane in done..end {
            simd::pv_axpy(level, norm[key & 3],
                          &values[lane * d..(lane + 1) * d], orow);
            key >>= 2;
        }
    }
}

/// M = 3/4 PV over one tile span: u16 pair keys through
/// [`simd::pv_accum2`]; an odd row end leaves exactly one low-code
/// lane.
pub(crate) fn pv_g2(level: simd::Level, bits: u32, keys: &[u16],
                    norm: &[f32], values: &[f32], d: usize,
                    span: (usize, usize), orow: &mut [f32]) {
    let (t0, end) = span;
    let bits = bits as usize;
    let mask = (1usize << bits) - 1;
    let k0 = t0 / 2;
    let nfull = (end - t0) / 2;
    simd::pv_accum2(level, &keys[k0..k0 + nfull], norm,
                    &values[t0 * d..(t0 + nfull * 2) * d], d, orow,
                    bits);
    let done = t0 + nfull * 2;
    if done < end {
        let key = keys[k0 + nfull] as usize;
        simd::pv_axpy(level, norm[key & mask],
                      &values[done * d..(done + 1) * d], orow);
    }
}

/// Group-1 PV (M = 1, M >= 5): per-lane lookup + axpy.
pub(crate) fn pv_generic<K: PackedKey>(
    level: simd::Level, lut_sum: &LutSum, keys: &[K], norm: &[f32],
    values: &[f32], d: usize, span: (usize, usize),
    orow: &mut [f32]) {
    let (t0, end) = span;
    let g = lut_sum.group;
    let bits = lut_sum.bits as usize;
    let mask = (1usize << bits) - 1;
    for lane in t0..end {
        let code = (keys[lane / g].index() >> (bits * (lane % g)))
            & mask;
        simd::pv_axpy(level, norm[code],
                      &values[lane * d..(lane + 1) * d], orow);
    }
}

/// The two-step path's dense PV over one chunk of output rows: re-read
/// the materialized f32 probabilities in ascending-`k` order through
/// the same axpy lanes the fused path uses.
fn dense_pv(r0: usize, out: &mut [f32], probs: &[f32],
            geom: (usize, usize), valid_lens: &[usize],
            values: &[f32], level: simd::Level) {
    let (len, d) = geom;
    for (i, orow) in out.chunks_exact_mut(d).enumerate() {
        let r = r0 + i;
        let n = row_valid(valid_lens, r, len);
        for k in 0..n {
            simd::pv_axpy(level, probs[r * len + k],
                          &values[k * d..(k + 1) * d], orow);
        }
    }
}

thread_local! {
    /// One cached plane per caller thread — same policy (and same
    /// pool-workers-never-touch-it guarantee) as the batched engine
    /// cache in `exaq::batched`.
    static CACHED_PLANE: RefCell<Option<AttentionPlane>> =
        const { RefCell::new(None) };
    static PLANE_HITS: Cell<u64> = const { Cell::new(0) };
    static PLANE_MISSES: Cell<u64> = const { Cell::new(0) };
}

/// (hits, misses) of this thread's [`with_cached_plane`] slot —
/// surfaced in bench JSON meta so layout wins stay visible cross-PR.
pub fn plane_cache_stats() -> (u64, u64) {
    (PLANE_HITS.with(Cell::get), PLANE_MISSES.with(Cell::get))
}

pub fn reset_plane_cache_stats() {
    PLANE_HITS.with(|c| c.set(0));
    PLANE_MISSES.with(|c| c.set(0));
}

/// Run `f` with this thread's cached [`AttentionPlane`], rebuilding
/// only when `(bits, clip)` changes.
pub fn with_cached_plane<R>(bits: u32, clip: f32,
                            f: impl FnOnce(&mut AttentionPlane) -> R)
                            -> R {
    CACHED_PLANE.with(|cell| {
        let mut slot = cell.borrow_mut();
        if matches!(slot.as_ref(), Some(p) if p.matches(bits, clip)) {
            PLANE_HITS.with(|c| c.set(c.get() + 1));
        } else {
            PLANE_MISSES.with(|c| c.set(c.get() + 1));
            *slot = None;
        }
        f(slot.get_or_insert_with(|| AttentionPlane::new(bits, clip)))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exaq::softmax::softmax_algo2_once;
    use crate::util::rng::SplitMix64;

    fn random(n: usize, seed: u64, scale: f32) -> Vec<f32> {
        let mut r = SplitMix64::new(seed);
        (0..n).map(|_| (r.normal() as f32) * scale).collect()
    }

    fn assert_bits_equal(a: &[f32], b: &[f32], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(),
                       "{what}: lane {i}: {x} vs {y}");
        }
    }

    /// Plain-loop reference: scalar Algo-2 softmax per row, then the
    /// canonical `out[j] += p * v[j]` triple loop.
    fn reference(scores: &[f32], rows: usize, len: usize,
                 valid_lens: &[usize], values: &[f32], d: usize,
                 bits: u32, clip: f32) -> Vec<f32> {
        let mut probs = scores.to_vec();
        let mut out = vec![0.0f32; rows * d];
        for r in 0..rows {
            let n = if valid_lens.is_empty() {
                len
            } else {
                valid_lens[r].min(len)
            };
            let row = &mut probs[r * len..(r + 1) * len];
            if n == 0 {
                row.fill(0.0);
                continue;
            }
            softmax_algo2_once(row, n, bits, clip);
            for k in 0..n {
                let p = row[k];
                for j in 0..d {
                    out[r * d + j] += p * values[k * d + j];
                }
            }
        }
        out
    }

    #[test]
    fn fused_matches_two_step_and_reference_at_every_m() {
        let (rows, len, d) = (3usize, 21usize, 5usize);
        let vlens = [len, 0, 7];
        let scores = random(rows * len, 77, 2.0);
        let values = random(len * d, 78, 1.0);
        for bits in [1u32, 2, 3, 4, 5] {
            let clip = -4.5;
            let mut plane = AttentionPlane::new(bits, clip);
            let mut fused = vec![0.0f32; rows * d];
            plane.attend(&scores, rows, len, &vlens, &values, d,
                         &mut fused);
            let mut two = vec![0.0f32; rows * d];
            plane.attend_two_step(&scores, rows, len, &vlens, &values,
                                  d, &mut two);
            let want = reference(&scores, rows, len, &vlens, &values,
                                 d, bits, clip);
            assert_bits_equal(&fused, &two, &format!("M={bits} 2step"));
            assert_bits_equal(&fused, &want, &format!("M={bits} ref"));
        }
    }

    #[test]
    fn worker_counts_do_not_change_the_output() {
        let (rows, len, d) = (9usize, 33usize, 4usize);
        let scores = random(rows * len, 5, 3.0);
        let values = random(len * d, 6, 1.0);
        let mut plane = AttentionPlane::new(2, -4.0);
        let mut want = vec![0.0f32; rows * d];
        plane.set_threads(1)
            .attend(&scores, rows, len, &[], &values, d, &mut want);
        for workers in [2usize, 7, 0] {
            let mut got = vec![0.0f32; rows * d];
            plane.set_threads(workers)
                .attend(&scores, rows, len, &[], &values, d,
                        &mut got);
            assert_bits_equal(&got, &want, &format!("w={workers}"));
        }
    }

    #[test]
    fn hostile_scores_stay_finite_and_bit_stable() {
        let (rows, len, d) = (4usize, 11usize, 3usize);
        let mut scores = random(rows * len, 13, 2.0);
        scores[3] = f32::NAN;
        scores[len + 1] = f32::INFINITY;
        for x in &mut scores[2 * len..3 * len] {
            *x = f32::NEG_INFINITY;
        }
        let values = random(len * d, 14, 1.0);
        for bits in [2u32, 3, 4] {
            let mut plane = AttentionPlane::new(bits, -5.0);
            let mut fused = vec![0.0f32; rows * d];
            plane.attend(&scores, rows, len, &[], &values, d,
                         &mut fused);
            let mut two = vec![0.0f32; rows * d];
            plane.attend_two_step(&scores, rows, len, &[], &values, d,
                                  &mut two);
            assert_bits_equal(&fused, &two, &format!("M={bits}"));
            for (i, x) in fused.iter().enumerate() {
                assert!(x.is_finite(), "M={bits} out[{i}] = {x}");
            }
        }
    }

    #[test]
    fn packed_footprint_beats_the_dense_plane() {
        let (rows, len, d) = (4usize, 64usize, 4usize);
        let scores = random(rows * len, 3, 1.0);
        let values = random(len * d, 4, 1.0);
        for bits in [2u32, 3, 4] {
            let mut plane = AttentionPlane::new(bits, -4.0);
            let mut out = vec![0.0f32; rows * d];
            plane.attend(&scores, rows, len, &[], &values, d,
                         &mut out);
            let packed = plane.plane_bytes();
            assert_eq!(packed, packed_plane_bytes(rows, len, bits),
                       "M={bits}");
            assert!(packed < dense_plane_bytes(rows, len),
                    "M={bits}: packed {packed} >= dense");
        }
        // the helper pins the exact layout: 4 codes/byte at M = 2,
        // 2 codes per u16 at M = 3/4
        assert_eq!(packed_plane_bytes(4, 64, 2), 4 * 16);
        assert_eq!(packed_plane_bytes(4, 64, 3), 4 * 32 * 2);
    }

    #[test]
    fn cached_plane_hits_on_config_match() {
        reset_plane_cache_stats();
        with_cached_plane(2, -4.25, |p| assert_eq!(p.bits(), 2));
        with_cached_plane(2, -4.25, |p| assert!(p.matches(2, -4.25)));
        with_cached_plane(3, -6.0, |p| assert_eq!(p.bits(), 3));
        let (hits, misses) = plane_cache_stats();
        assert_eq!((hits, misses), (1, 2));
    }

    #[test]
    fn zero_geometry_is_a_no_op() {
        let mut plane = AttentionPlane::new(2, -4.0);
        let mut out: Vec<f32> = Vec::new();
        plane.attend(&[], 0, 0, &[], &[], 0, &mut out);
        let mut out = vec![7.0f32; 3 * 2];
        // len == 0: every row is all-pad, out must come back zeroed
        plane.attend(&[], 3, 0, &[], &[], 2, &mut out);
        assert!(out.iter().all(|&x| x == 0.0));
    }
}
