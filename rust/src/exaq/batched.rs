//! Batched, bit-packed EXAQ softmax — the plane-at-a-time form of
//! paper Algorithm 2 (§4, Fig. 5).
//!
//! Serving traffic arrives as whole `[rows × len]` attention / logit
//! planes, not single rows. [`BatchSoftmax`] owns prebuilt tables
//! (`Quantizer` + `LUT_exp` + `LUT_sum`) and a reusable bit-packed
//! code plane ([`PackedCodes`]) and exposes
//! [`softmax_rows`](BatchSoftmax::softmax_rows), which runs Algorithm 2
//! over every row of a plane in one call with zero steady-state
//! allocation.
//!
//! ## The packed byte *is* the LUT_sum key
//!
//! Fig. 5's insight is a storage format, not just a table: write M-bit
//! codes packed low-code-first into machine words, and each word read
//! back *verbatim* is the LUT_sum address for its code group. The
//! scalar path materialises one `u8` per 2-bit code (4x waste) and
//! rebuilds every key with a shift-or loop; here the quantize pass
//! emits the packed plane directly —
//!
//! * **M = 2**: four codes per byte (`c0 | c1<<2 | c2<<4 | c3<<6`);
//!   the code plane is `len/4` bytes per row and the denominator loop
//!   streams those bytes straight into [`LutSum::sum_keys`] — the
//!   paper's ~4x accumulation win with no per-group repacking.
//! * **M = 3/4**: one `u16` key per two codes (`c0 | c1<<M`), the 2x
//!   accumulation configuration of Table 3.
//!
//! ## Bit-exactness with the scalar path
//!
//! `softmax_rows` agrees *bit-for-bit* with per-row
//! [`softmax_algo2`]: both derive the identical key stream, reduce it
//! through the same fixed-tree [`LutSum::sum_keys`], and produce each
//! output lane as the f32 product `lut_exp[code] * inv`. The batched
//! kernel merely computes that product once per *code* (a premultiplied
//! `2^M`-entry normalisation table) instead of once per *element*, and
//! decodes output lanes from the packed keys — same values, ~40% less
//! memory traffic, no per-element divide/multiply pass.

use std::cell::RefCell;

use super::lut::{LutExp, LutSum, PackedKey};
use super::quant::Quantizer;
use super::softmax::{softmax_algo2, Algo2Scratch};

/// Reusable bit-packed code plane: one LUT_sum key per code group,
/// `rows × ceil(len/group)` keys per plane (see the module docs for
/// the M = 2 byte / M = 3-4 u16 layouts).
#[derive(Default)]
pub struct PackedCodes {
    /// M ≤ 2 plane — each byte is `group` codes and is itself the key.
    bytes: Vec<u8>,
    /// M = 3+ plane — one u16 key per group.
    words: Vec<u16>,
}

impl PackedCodes {
    /// Bytes of packed-code storage currently held (the M = 2 plane
    /// packs 4 codes/byte; tests pin the 4x saving over `u8` codes).
    pub fn plane_bytes(&self) -> usize {
        self.bytes.len() + 2 * self.words.len()
    }
}

/// Batched Algorithm-2 softmax engine: prebuilt tables + packed code
/// plane + scratch, reused across calls.
pub struct BatchSoftmax {
    quant: Quantizer,
    lut_exp: LutExp,
    lut_sum: LutSum,
    /// Requested clip before the quantizer's sanity clamp (cache key).
    req_clip: f32,
    /// Per-row premultiplied normalisation table: `lut_exp[c] * inv`.
    norm: Vec<f32>,
    packed: PackedCodes,
    /// Scratch for the scalar-compatible single-row entry point.
    scratch: Algo2Scratch,
}

impl BatchSoftmax {
    pub fn new(bits: u32, clip: f32) -> Self {
        let quant = Quantizer::new(bits, clip);
        let lut_exp = LutExp::build(&quant);
        let lut_sum = LutSum::build(&quant);
        Self {
            quant,
            lut_exp,
            lut_sum,
            req_clip: clip,
            norm: Vec::new(),
            packed: PackedCodes::default(),
            scratch: Algo2Scratch::default(),
        }
    }

    pub fn bits(&self) -> u32 {
        self.quant.bits
    }

    /// Codes per LUT_sum key — the accumulation-speedup factor the
    /// cost model must quote (4 at M = 2, 2 at M = 3/4).
    pub fn group(&self) -> usize {
        self.lut_sum.group
    }

    /// Does this engine serve the requested configuration? (Compares
    /// the *requested* clip, pre-clamp, so cache keys are exact.)
    pub fn matches(&self, bits: u32, clip: f32) -> bool {
        self.quant.bits == bits && self.req_clip == clip
    }

    pub fn tables(&self) -> (&Quantizer, &LutExp, &LutSum) {
        (&self.quant, &self.lut_exp, &self.lut_sum)
    }

    /// Current packed-plane footprint in bytes.
    pub fn plane_bytes(&self) -> usize {
        self.packed.plane_bytes()
    }

    /// Single-row entry point — exactly [`softmax_algo2`] with this
    /// engine's tables and scratch (the sampling hot path).
    pub fn softmax_row(&mut self, row: &mut [f32], valid_len: usize) {
        softmax_algo2(row, valid_len, &self.quant, &self.lut_exp,
                      &self.lut_sum, &mut self.scratch);
    }

    /// Batched Algorithm 2 over a whole `[rows × len]` plane.
    ///
    /// Row `r` is `data[r*len .. (r+1)*len]`; its valid prefix is
    /// `valid_lens[r]` clamped to `len` (`valid_lens = &[]` means every
    /// row is fully valid). Lanes past the valid prefix are zeroed,
    /// exactly like [`softmax_algo2`] — and the whole plane is
    /// bit-identical to calling [`softmax_algo2`] row by row.
    pub fn softmax_rows(&mut self, data: &mut [f32], rows: usize,
                        len: usize, valid_lens: &[usize]) {
        assert_eq!(data.len(), rows * len,
                   "plane is {} floats, expected rows*len = {}",
                   data.len(), rows * len);
        assert!(valid_lens.is_empty() || valid_lens.len() == rows,
                "valid_lens arity {} != rows {rows}", valid_lens.len());
        if rows == 0 || len == 0 {
            return;
        }
        let Self { quant, lut_exp, lut_sum, norm, packed, .. } = self;
        let tables = (&*quant, &*lut_exp, &*lut_sum);
        if quant.bits <= 2 {
            rows_kernel::<u8>(tables, norm, &mut packed.bytes, data,
                              (rows, len), valid_lens);
        } else {
            rows_kernel::<u16>(tables, norm, &mut packed.words, data,
                               (rows, len), valid_lens);
        }
    }
}

/// The plane kernel, monomorphised per key width. Per row: max-shift,
/// quantize-and-pack (no f32 writes), fixed-tree key reduction,
/// premultiplied-table decode. See the module docs for why each step
/// is bit-identical to the scalar path.
fn rows_kernel<K: PackedKey>(
    tables: (&Quantizer, &LutExp, &LutSum), norm: &mut Vec<f32>,
    plane: &mut Vec<K>, data: &mut [f32], dims: (usize, usize),
    valid_lens: &[usize],
) {
    let (quant, lut_exp, lut_sum) = tables;
    let (rows, len) = dims;
    let g = lut_sum.group;
    let bits = lut_sum.bits as usize;
    let mask = (1usize << bits) - 1;
    let stride = len.div_ceil(g);
    plane.resize(rows * stride, K::default());

    for (r, row) in data.chunks_exact_mut(len).enumerate() {
        let n = if valid_lens.is_empty() { len } else { valid_lens[r] }
            .min(len);
        if n == 0 {
            row.fill(0.0);
            continue;
        }
        // max-shift (same linear scan as the scalar path)
        let mut m = f32::NEG_INFINITY;
        for &x in &row[..n] {
            m = m.max(x);
        }
        let padded = n.next_multiple_of(g);
        let nkeys = padded / g;
        let full = n / g; // groups whose lanes are all < n
        let keys = &mut plane[r * stride..r * stride + nkeys];

        // ---- quantize + pack: emit the key plane, touch no f32 lanes
        if g == 4 {
            // M = 2: the packed byte is the key (Fig. 5)
            for (k, lanes) in keys[..full]
                .iter_mut()
                .zip(row[..full * 4].chunks_exact(4))
            {
                let c0 = quant.code(lanes[0] - m) as usize;
                let c1 = quant.code(lanes[1] - m) as usize;
                let c2 = quant.code(lanes[2] - m) as usize;
                let c3 = quant.code(lanes[3] - m) as usize;
                *k = K::pack(c0 | (c1 << 2) | (c2 << 4) | (c3 << 6));
            }
        } else if g == 2 {
            // M = 3/4: two codes per u16 key
            for (k, lanes) in keys[..full]
                .iter_mut()
                .zip(row[..full * 2].chunks_exact(2))
            {
                let c0 = quant.code(lanes[0] - m) as usize;
                let c1 = quant.code(lanes[1] - m) as usize;
                *k = K::pack(c0 | (c1 << bits));
            }
        } else {
            for (k, lanes) in keys[..full]
                .iter_mut()
                .zip(row[..full * g].chunks_exact(g))
            {
                let mut key = 0usize;
                for (j, &x) in lanes.iter().enumerate() {
                    key |= (quant.code(x - m) as usize) << (bits * j);
                }
                *k = K::pack(key);
            }
        }
        // tail group: lanes in [full*g, n) quantized, the padding
        // lanes sit on code 0 (exactly the scalar path's zero pad)
        if full < nkeys {
            let mut key = 0usize;
            for (j, lane) in (full * g..n).enumerate() {
                key |= (quant.code(row[lane] - m) as usize)
                    << (bits * j);
            }
            keys[full] = K::pack(key);
        }

        // ---- denominator: the shared fixed-tree reduction
        let mut sum = lut_sum.sum_keys(&keys[..nkeys]);
        sum -= (padded - n) as f32 * lut_exp.floor_value();
        let inv = 1.0 / sum.max(1e-30);

        // ---- decode: norm[c] = lut_exp[c] * inv, computed once per
        // code — bit-identical to the scalar per-lane `exp * inv`
        norm.clear();
        norm.extend(lut_exp.table.iter().map(|&e| e * inv));
        let full_lanes = full * g;
        if g == 4 {
            for (lanes, &k) in row[..full_lanes]
                .chunks_exact_mut(4)
                .zip(keys[..full].iter())
            {
                let k = k.index();
                lanes[0] = norm[k & 3];
                lanes[1] = norm[(k >> 2) & 3];
                lanes[2] = norm[(k >> 4) & 3];
                lanes[3] = norm[(k >> 6) & 3];
            }
        } else if g == 2 {
            for (lanes, &k) in row[..full_lanes]
                .chunks_exact_mut(2)
                .zip(keys[..full].iter())
            {
                let k = k.index();
                lanes[0] = norm[k & mask];
                lanes[1] = norm[(k >> bits) & mask];
            }
        } else {
            for (lanes, &k) in row[..full_lanes]
                .chunks_exact_mut(g)
                .zip(keys[..full].iter())
            {
                let mut k = k.index();
                for x in lanes {
                    *x = norm[k & mask];
                    k >>= bits;
                }
            }
        }
        if full_lanes < n {
            let mut k = keys[full].index();
            for x in &mut row[full_lanes..n] {
                *x = norm[k & mask];
                k >>= bits;
            }
        }
        row[n..].fill(0.0);
    }
}

thread_local! {
    /// Per-thread engine cache backing [`with_cached_engine`] (and,
    /// through it, `softmax_algo2_once`): loops over a fixed (bits,
    /// clip) stop paying the three table builds per call.
    static CACHED_ENGINE: RefCell<Option<BatchSoftmax>> =
        const { RefCell::new(None) };
}

/// Find-or-rebuild an engine slot for (`bits`, `clip`) — the one
/// cache policy shared by the sampler scratch and the thread-local
/// [`with_cached_engine`] cache, so key semantics cannot drift.
pub fn ensure_engine(slot: &mut Option<BatchSoftmax>, bits: u32,
                     clip: f32) -> &mut BatchSoftmax {
    if !matches!(slot, Some(e) if e.matches(bits, clip)) {
        *slot = None;
    }
    slot.get_or_insert_with(|| BatchSoftmax::new(bits, clip))
}

/// Run `f` with a thread-cached [`BatchSoftmax`] for (`bits`, `clip`),
/// rebuilding the tables only when the configuration changes.
pub fn with_cached_engine<R>(
    bits: u32, clip: f32, f: impl FnOnce(&mut BatchSoftmax) -> R,
) -> R {
    CACHED_ENGINE.with(|cell| {
        let mut slot = cell.borrow_mut();
        f(ensure_engine(&mut slot, bits, clip))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exaq::lut::lut_group;
    use crate::exaq::softmax::softmax_algo2_once;
    use crate::util::rng::SplitMix64;

    fn random_plane(rows: usize, len: usize, seed: u64,
                    scale: f32) -> Vec<f32> {
        let mut r = SplitMix64::new(seed);
        (0..rows * len).map(|_| (r.normal() as f32) * scale).collect()
    }

    fn assert_bit_exact(plane: &[f32], reference: &[f32], tag: &str) {
        for (i, (a, b)) in plane.iter().zip(reference).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(),
                       "{tag}: lane {i} diverged: {a} vs {b}");
        }
    }

    #[test]
    fn batched_plane_is_bit_exact_with_scalar_rows() {
        for bits in [1u32, 2, 3, 4] {
            let (rows, len) = (6usize, 50usize); // 50 % 4 != 0
            let mut plane = random_plane(rows, len, 77 + bits as u64, 2.0);
            let mut reference = plane.clone();
            let vlens: Vec<usize> = (0..rows)
                .map(|r| [len, 1, 7, len + 100, 0, 33][r])
                .collect();
            let mut eng = BatchSoftmax::new(bits, -4.5);
            eng.softmax_rows(&mut plane, rows, len, &vlens);
            for (r, row) in reference.chunks_mut(len).enumerate() {
                softmax_algo2_once(row, vlens[r], bits, -4.5);
            }
            assert_bit_exact(&plane, &reference, &format!("bits={bits}"));
        }
    }

    #[test]
    fn empty_valid_lens_means_full_rows() {
        let (rows, len) = (3usize, 31usize);
        let mut a = random_plane(rows, len, 5, 1.5);
        let mut b = a.clone();
        let mut eng = BatchSoftmax::new(2, -4.0);
        eng.softmax_rows(&mut a, rows, len, &[]);
        let full = vec![len; rows];
        let mut eng2 = BatchSoftmax::new(2, -4.0);
        eng2.softmax_rows(&mut b, rows, len, &full);
        assert_bit_exact(&a, &b, "full-row default");
        for row in a.chunks(len) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "{s}");
        }
    }

    #[test]
    fn zero_rows_and_zero_len_are_noops() {
        let mut eng = BatchSoftmax::new(2, -4.0);
        let mut empty: Vec<f32> = Vec::new();
        eng.softmax_rows(&mut empty, 0, 128, &[]);
        eng.softmax_rows(&mut empty, 0, 0, &[]);
        let mut rows_of_nothing: Vec<f32> = Vec::new();
        eng.softmax_rows(&mut rows_of_nothing, 4, 0, &[0, 0, 0, 0]);
    }

    #[test]
    fn m2_plane_packs_four_codes_per_byte() {
        let (rows, len) = (8usize, 256usize);
        let mut plane = random_plane(rows, len, 9, 2.0);
        let mut eng = BatchSoftmax::new(2, -4.0);
        eng.softmax_rows(&mut plane, rows, len, &[]);
        // one byte per 4 codes — the scalar scratch would hold
        // rows*len = 2048 bytes of codes; the packed plane holds 512
        assert_eq!(eng.plane_bytes(), rows * len / 4);
    }

    #[test]
    fn plane_reuse_shrinks_and_regrows() {
        let mut eng = BatchSoftmax::new(2, -4.0);
        let mut big = random_plane(16, 64, 11, 1.0);
        eng.softmax_rows(&mut big, 16, 64, &[]);
        let bytes_big = eng.plane_bytes();
        let mut small = random_plane(2, 8, 12, 1.0);
        eng.softmax_rows(&mut small, 2, 8, &[]);
        assert!(eng.plane_bytes() < bytes_big);
        let mut reference = random_plane(16, 64, 11, 1.0);
        let mut fresh = BatchSoftmax::new(2, -4.0);
        let mut again = reference.clone();
        fresh.softmax_rows(&mut again, 16, 64, &[]);
        eng.softmax_rows(&mut reference, 16, 64, &[]);
        // a reused engine and a fresh one agree bit-for-bit
        assert_bit_exact(&reference, &again, "reuse");
    }

    #[test]
    fn all_neg_infinity_rows_stay_uniform_and_finite() {
        for bits in [2u32, 3, 4] {
            let (rows, len) = (3usize, 24usize);
            let mut plane = vec![f32::NEG_INFINITY; rows * len];
            let mut eng = BatchSoftmax::new(bits, -5.0);
            eng.softmax_rows(&mut plane, rows, len, &[len, 5, len]);
            for (i, &p) in plane.iter().take(len).enumerate() {
                assert!(p.is_finite(), "bits={bits} lane {i}: {p}");
                assert!((p - 1.0 / len as f32).abs() < 1e-5);
            }
            let s: f32 = plane[len..len + 5].iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "bits={bits}: {s}");
            assert!(plane[len + 5..2 * len].iter().all(|&p| p == 0.0));
        }
    }

    #[test]
    fn cached_engine_is_reused_and_rebuilt_on_config_change() {
        // grow the cached engine's packed plane, then observe that the
        // same configuration gets the same (still-grown) engine back
        // while a config change gets a fresh one
        with_cached_engine(2, -4.25, |e| {
            let mut plane = vec![0.5f32; 8 * 64];
            e.softmax_rows(&mut plane, 8, 64, &[]);
            assert!(e.plane_bytes() > 0);
        });
        with_cached_engine(2, -4.25, |e| {
            assert!(e.matches(2, -4.25));
            assert!(e.plane_bytes() > 0,
                    "cache miss: engine was rebuilt for the same config");
        });
        with_cached_engine(3, -6.0, |e| {
            assert_eq!(e.bits(), 3);
            assert!(!e.matches(2, -4.25));
            assert_eq!(e.plane_bytes(), 0, "expected a fresh engine");
        });
    }

    #[test]
    fn group_matches_lut_group_for_all_bit_widths() {
        for bits in 1u32..=4 {
            let eng = BatchSoftmax::new(bits, -4.0);
            assert_eq!(eng.group(), lut_group(bits), "bits={bits}");
        }
    }
}
