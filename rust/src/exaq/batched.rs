//! Batched, bit-packed EXAQ softmax — the plane-at-a-time form of
//! paper Algorithm 2 (§4, Fig. 5).
//!
//! Serving traffic arrives as whole `[rows × len]` attention / logit
//! planes, not single rows. [`BatchSoftmax`] owns prebuilt tables
//! (`Quantizer` + `LUT_exp` + `LUT_sum`) and a reusable bit-packed
//! code plane ([`PackedCodes`]) and exposes
//! [`softmax_rows`](BatchSoftmax::softmax_rows), which runs Algorithm 2
//! over every row of a plane in one call with zero steady-state
//! allocation.
//!
//! ## The packed byte *is* the LUT_sum key
//!
//! Fig. 5's insight is a storage format, not just a table: write M-bit
//! codes packed low-code-first into machine words, and each word read
//! back *verbatim* is the LUT_sum address for its code group. The
//! scalar path materialises one `u8` per 2-bit code (4x waste) and
//! rebuilds every key with a shift-or loop; here the quantize pass
//! emits the packed plane directly —
//!
//! * **M = 2**: four codes per byte (`c0 | c1<<2 | c2<<4 | c3<<6`);
//!   the code plane is `len/4` bytes per row and the denominator loop
//!   streams those bytes straight into [`LutSum::sum_keys`] — the
//!   paper's ~4x accumulation win with no per-group repacking.
//! * **M = 3/4**: one `u16` key per two codes (`c0 | c1<<M`), the 2x
//!   accumulation configuration of Table 3.
//!
//! ## SIMD lanes and the row pool
//!
//! The per-row passes (max-shift, quantize+pack, premultiplied decode)
//! dispatch through [`simd`] — explicit sse2/avx2/neon lanes with the
//! always-compiled scalar reference (`EXAQ_SIMD` overrides the level
//! process-wide, [`set_simd_level`](BatchSoftmax::set_simd_level) per
//! engine). Across rows, large planes are split into row-range chunks
//! and drained by the scoped worker pool in [`util::pool`]
//! (`EXAQ_THREADS` caps the auto default,
//! [`set_threads`](BatchSoftmax::set_threads) pins an engine). Each
//! chunk owns a disjoint `&mut` slice of both the f32 plane and the
//! packed key plane plus its own `norm` scratch, and rows are pure
//! functions of their input lanes — so the output is bit-identical
//! for every level, every thread count, and every interleaving.
//! Workers never touch the thread-local [`with_cached_engine`] cache:
//! they borrow the engine's tables directly.
//!
//! ## Bit-exactness with the scalar path
//!
//! `softmax_rows` agrees *bit-for-bit* with per-row
//! [`softmax_algo2`]: both derive the identical key stream, reduce it
//! through the same fixed-tree [`LutSum::sum_keys`], and produce each
//! output lane as the f32 product `lut_exp[code] * inv`. The batched
//! kernel merely computes that product once per *code* (a premultiplied
//! `2^M`-entry normalisation table) instead of once per *element*, and
//! decodes output lanes from the packed keys — same values, ~40% less
//! memory traffic, no per-element divide/multiply pass.

use std::cell::{Cell, RefCell};

use super::lut::{LutExp, LutSum, PackedKey};
use super::quant::Quantizer;
use super::simd;
use super::softmax::{softmax_algo2, Algo2Scratch};
use crate::util::pool;

/// Largest `2^M` the per-chunk premultiplied table must hold.
const NORM_LANES: usize = 256;

/// Auto mode: do not parallelise planes smaller than this many lanes
/// (scoped spawns cost ~tens of µs — a decode tick over a small vocab
/// must stay inline).
const PAR_MIN_LANES: usize = 16_384;

/// Auto mode: at least this many lanes per worker before adding one.
const PAR_LANES_PER_WORKER: usize = 8_192;

/// Reusable bit-packed code plane: one LUT_sum key per code group,
/// `rows × ceil(len/group)` keys per plane (see the module docs for
/// the M = 2 byte / M = 3-4 u16 layouts).
#[derive(Default)]
pub struct PackedCodes {
    /// M ≤ 2 plane — each byte is `group` codes and is itself the key.
    bytes: Vec<u8>,
    /// M = 3+ plane — one u16 key per group.
    words: Vec<u16>,
}

impl PackedCodes {
    /// True footprint of the M ≤ 2 byte-key plane (4 codes per byte
    /// at M = 2 — the packed byte *is* the LUT_sum key).
    pub fn byte_plane_bytes(&self) -> usize {
        self.bytes.len() * std::mem::size_of::<u8>()
    }

    /// True footprint of the M = 3+ u16-key plane (2 codes per
    /// two-byte key at M = 3/4, one code per key otherwise).
    pub fn word_plane_bytes(&self) -> usize {
        self.words.len() * std::mem::size_of::<u16>()
    }

    /// Bytes of packed-code storage currently held — the sum of both
    /// key planes' true footprints (tests pin the 4x saving over `u8`
    /// codes at M = 2 and the byte-per-code saving at M = 3/4).
    pub fn plane_bytes(&self) -> usize {
        self.byte_plane_bytes() + self.word_plane_bytes()
    }

    /// Mutable byte-key storage, for kernels (`exaq::plane`) that keep
    /// codes packed across passes instead of decoding after softmax.
    pub(crate) fn bytes_mut(&mut self) -> &mut Vec<u8> {
        &mut self.bytes
    }

    /// Mutable u16-key storage; see [`PackedCodes::bytes_mut`].
    pub(crate) fn words_mut(&mut self) -> &mut Vec<u16> {
        &mut self.words
    }
}

/// Batched Algorithm-2 softmax engine: prebuilt tables + packed code
/// plane + scratch, reused across calls.
pub struct BatchSoftmax {
    quant: Quantizer,
    lut_exp: LutExp,
    lut_sum: LutSum,
    /// Requested clip before the quantizer's sanity clamp (cache key).
    req_clip: f32,
    packed: PackedCodes,
    /// Scratch for the scalar-compatible single-row entry point.
    scratch: Algo2Scratch,
    /// Worker-count override; 0 = auto (pool default + size heuristic).
    threads: usize,
    /// Lane-specialisation level for the per-row passes.
    level: simd::Level,
}

impl BatchSoftmax {
    pub fn new(bits: u32, clip: f32) -> Self {
        let quant = Quantizer::new(bits, clip);
        let lut_exp = LutExp::build(&quant);
        let lut_sum = LutSum::build(&quant);
        Self {
            quant,
            lut_exp,
            lut_sum,
            req_clip: clip,
            packed: PackedCodes::default(),
            scratch: Algo2Scratch::default(),
            threads: 0,
            level: simd::default_level(),
        }
    }

    pub fn bits(&self) -> u32 {
        self.quant.bits
    }

    /// Codes per LUT_sum key — the accumulation-speedup factor the
    /// cost model must quote (4 at M = 2, 2 at M = 3/4).
    pub fn group(&self) -> usize {
        self.lut_sum.group
    }

    /// Does this engine serve the requested configuration? (Compares
    /// the *requested* clip, pre-clamp, so cache keys are exact.
    /// Thread count and SIMD level are *not* part of the key — every
    /// combination produces bit-identical output.)
    pub fn matches(&self, bits: u32, clip: f32) -> bool {
        self.quant.bits == bits && self.req_clip == clip
    }

    pub fn tables(&self) -> (&Quantizer, &LutExp, &LutSum) {
        (&self.quant, &self.lut_exp, &self.lut_sum)
    }

    /// Current packed-plane footprint in bytes.
    pub fn plane_bytes(&self) -> usize {
        self.packed.plane_bytes()
    }

    /// Pin the worker count. Explicit values (>= 1) parallelise any
    /// plane with >= 2 rows — the determinism tests rely on that; 0
    /// restores auto mode (pool default capped by the plane-size
    /// heuristic, so decode ticks over small vocabs stay inline).
    pub fn set_threads(&mut self, threads: usize) -> &mut Self {
        self.threads = threads;
        self
    }

    /// The effective worker cap (auto mode reports the pool default).
    pub fn threads(&self) -> usize {
        if self.threads == 0 {
            pool::default_threads()
        } else {
            self.threads
        }
    }

    /// Pin the lane level; an unavailable level falls back to scalar
    /// (never faults). Output is bit-identical across levels.
    pub fn set_simd_level(&mut self, level: simd::Level) -> &mut Self {
        self.level = if simd::available_levels().contains(&level) {
            level
        } else {
            simd::Level::Scalar
        };
        self
    }

    pub fn simd_level(&self) -> simd::Level {
        self.level
    }

    /// Workers to use for a `[rows × len]` plane (shared with the
    /// fused attention plane so both paths split rows identically).
    pub(crate) fn plan_workers(&self, rows: usize, len: usize) -> usize {
        if rows < 2 {
            return 1;
        }
        if self.threads > 0 {
            return self.threads.min(rows);
        }
        let cap = pool::default_threads();
        let lanes = rows * len;
        if cap <= 1 || lanes < PAR_MIN_LANES {
            return 1;
        }
        cap.min(rows).min((lanes / PAR_LANES_PER_WORKER).max(1))
    }

    /// Single-row entry point — exactly [`softmax_algo2`] with this
    /// engine's tables and scratch (the sampling hot path).
    pub fn softmax_row(&mut self, row: &mut [f32], valid_len: usize) {
        softmax_algo2(row, valid_len, &self.quant, &self.lut_exp,
                      &self.lut_sum, &mut self.scratch);
    }

    /// Batched Algorithm 2 over a whole `[rows × len]` plane.
    ///
    /// Row `r` is `data[r*len .. (r+1)*len]`; its valid prefix is
    /// `valid_lens[r]` clamped to `len` (`valid_lens = &[]` means every
    /// row is fully valid). Lanes past the valid prefix are zeroed,
    /// exactly like [`softmax_algo2`] — and the whole plane is
    /// bit-identical to calling [`softmax_algo2`] row by row, at any
    /// SIMD level and any thread count.
    pub fn softmax_rows(&mut self, data: &mut [f32], rows: usize,
                        len: usize, valid_lens: &[usize]) {
        assert_eq!(data.len(), rows * len,
                   "plane is {} floats, expected rows*len = {}",
                   data.len(), rows * len);
        assert!(valid_lens.is_empty() || valid_lens.len() == rows,
                "valid_lens arity {} != rows {rows}", valid_lens.len());
        if rows == 0 || len == 0 {
            return;
        }
        let workers = self.plan_workers(rows, len);
        let Self { quant, lut_exp, lut_sum, packed, level, .. } = self;
        let tables = (&*quant, &*lut_exp, &*lut_sum);
        let level = *level;
        let g = lut_sum.group;
        let dims = (rows, len);
        match quant.bits {
            2 => drive_rows(
                &mut packed.bytes, data, dims, g, valid_lens, workers,
                |row, keys, n, norm| {
                    row_g4(tables, level, row, keys, n, norm)
                },
            ),
            3 | 4 => drive_rows(
                &mut packed.words, data, dims, g, valid_lens, workers,
                |row, keys, n, norm| {
                    row_g2(tables, level, row, keys, n, norm)
                },
            ),
            b if b <= 2 => drive_rows(
                &mut packed.bytes, data, dims, g, valid_lens, workers,
                |row, keys, n, norm| row_generic(tables, row, keys, n, norm),
            ),
            _ => drive_rows(
                &mut packed.words, data, dims, g, valid_lens, workers,
                |row, keys, n, norm| row_generic(tables, row, keys, n, norm),
            ),
        }
    }
}

/// Split the f32 plane and the packed key plane into matching row
/// ranges and run `row_fn` over every valid row — inline for one
/// worker, through the scoped pool otherwise. Each chunk carries its
/// own `norm` scratch; output locations are fixed by the split before
/// any worker starts, so the plane is bit-identical for every worker
/// count.
fn drive_rows<K, F>(plane: &mut Vec<K>, data: &mut [f32],
                    dims: (usize, usize), g: usize,
                    valid_lens: &[usize], workers: usize, row_fn: F)
where
    K: PackedKey + Send,
    F: Fn(&mut [f32], &mut [K], usize, &mut [f32; NORM_LANES]) + Sync,
{
    let (rows, len) = dims;
    let stride = len.div_ceil(g);
    plane.resize(rows * stride, K::default());
    if workers <= 1 {
        let mut norm = [0.0f32; NORM_LANES];
        chunk_pass(0, data, plane, (len, stride), valid_lens,
                   &mut norm, &row_fn);
        return;
    }
    // Over-split by 4x for dynamic balance; chunk identity still fixes
    // every output location.
    let chunk_rows = rows.div_ceil(workers * 4).max(1);
    let mut chunks = Vec::new();
    let mut drest: &mut [f32] = data;
    let mut krest: &mut [K] = plane;
    let mut r0 = 0usize;
    while r0 < rows {
        let take = chunk_rows.min(rows - r0);
        let (d, dtail) =
            std::mem::take(&mut drest).split_at_mut(take * len);
        let (k, ktail) =
            std::mem::take(&mut krest).split_at_mut(take * stride);
        chunks.push((r0, d, k));
        drest = dtail;
        krest = ktail;
        r0 += take;
    }
    pool::run_chunks(chunks, workers, |(r0, d, k)| {
        let mut norm = [0.0f32; NORM_LANES];
        chunk_pass(r0, d, k, (len, stride), valid_lens, &mut norm,
                   &row_fn);
    });
}

/// Run `row_fn` over every row of one chunk (`r0` = first global row,
/// for `valid_lens` addressing).
fn chunk_pass<K, F>(r0: usize, data: &mut [f32], keys: &mut [K],
                    geom: (usize, usize), valid_lens: &[usize],
                    norm: &mut [f32; NORM_LANES], row_fn: &F)
where
    K: PackedKey,
    F: Fn(&mut [f32], &mut [K], usize, &mut [f32; NORM_LANES]),
{
    let (len, stride) = geom;
    for (i, row) in data.chunks_exact_mut(len).enumerate() {
        let r = r0 + i;
        let n = if valid_lens.is_empty() { len } else { valid_lens[r] }
            .min(len);
        if n == 0 {
            row.fill(0.0);
            continue;
        }
        let krow = &mut keys[i * stride..(i + 1) * stride];
        row_fn(row, krow, n, norm);
    }
}

/// Fill `norm[..2^M]` with the premultiplied `lut_exp[c] * inv` table.
fn fill_norm(lut_exp: &LutExp, inv: f32,
             norm: &mut [f32; NORM_LANES]) -> usize {
    let nl = lut_exp.table.len();
    for (d, &e) in norm[..nl].iter_mut().zip(lut_exp.table.iter()) {
        *d = e * inv;
    }
    nl
}

/// M = 2 row: the packed byte is the key (Fig. 5). SIMD-dispatched
/// quantize+pack and decode; fixed-tree denominator.
fn row_g4(tables: (&Quantizer, &LutExp, &LutSum), level: simd::Level,
          row: &mut [f32], keys: &mut [u8], n: usize,
          norm: &mut [f32; NORM_LANES]) {
    let (quant, lut_exp, lut_sum) = tables;
    let m = simd::row_max(level, &row[..n]);
    let padded = n.next_multiple_of(4);
    let nkeys = padded / 4;
    let full = n / 4; // groups whose lanes are all < n
    let keys = &mut keys[..nkeys];

    simd::quant_pack4(level, &row[..full * 4], m, quant,
                      &mut keys[..full]);
    // tail group: lanes in [full*4, n) quantized, the padding lanes
    // sit on code 0 (exactly the scalar path's zero pad)
    if full < nkeys {
        let mut key = 0usize;
        for (j, lane) in (full * 4..n).enumerate() {
            key |= (quant.code(row[lane] - m) as usize) << (2 * j);
        }
        keys[full] = key as u8;
    }

    let mut sum = lut_sum.sum_keys(keys);
    sum -= (padded - n) as f32 * lut_exp.floor_value();
    let inv = 1.0 / sum.max(1e-30);

    let nl = fill_norm(lut_exp, inv, norm);
    simd::decode4(level, &keys[..full], &norm[..nl],
                  &mut row[..full * 4]);
    if full * 4 < n {
        let mut k = keys[full] as usize;
        for x in &mut row[full * 4..n] {
            *x = norm[k & 3];
            k >>= 2;
        }
    }
    row[n..].fill(0.0);
}

/// M = 3/4 row: two codes per u16 key. SIMD-dispatched quantize+pack
/// and decode; fixed-tree denominator.
fn row_g2(tables: (&Quantizer, &LutExp, &LutSum), level: simd::Level,
          row: &mut [f32], keys: &mut [u16], n: usize,
          norm: &mut [f32; NORM_LANES]) {
    let (quant, lut_exp, lut_sum) = tables;
    let bits = quant.bits as usize;
    let mask = (1usize << bits) - 1;
    let m = simd::row_max(level, &row[..n]);
    let padded = n.next_multiple_of(2);
    let nkeys = padded / 2;
    let full = n / 2;
    let keys = &mut keys[..nkeys];

    simd::quant_pack2(level, &row[..full * 2], m, quant,
                      &mut keys[..full], bits);
    if full < nkeys {
        // odd n: one real lane, one zero-pad lane on code 0
        keys[full] = quant.code(row[n - 1] - m) as u16;
    }

    let mut sum = lut_sum.sum_keys(keys);
    sum -= (padded - n) as f32 * lut_exp.floor_value();
    let inv = 1.0 / sum.max(1e-30);

    let nl = fill_norm(lut_exp, inv, norm);
    simd::decode2(level, &keys[..full], &norm[..nl],
                  &mut row[..full * 2], bits);
    if full * 2 < n {
        let k = keys[full] as usize;
        row[n - 1] = norm[k & mask];
    }
    row[n..].fill(0.0);
}

/// Any other grouping (M = 1 and M >= 5 run at group 1): the original
/// scalar loops, still the shape every specialisation mirrors.
fn row_generic<K: PackedKey>(tables: (&Quantizer, &LutExp, &LutSum),
                             row: &mut [f32], keys: &mut [K],
                             n: usize,
                             norm: &mut [f32; NORM_LANES]) {
    let (quant, lut_exp, lut_sum) = tables;
    let g = lut_sum.group;
    let bits = lut_sum.bits as usize;
    let mask = (1usize << bits) - 1;
    let mut m = f32::NEG_INFINITY;
    for &x in &row[..n] {
        m = m.max(x);
    }
    let padded = n.next_multiple_of(g);
    let nkeys = padded / g;
    let full = n / g;
    let keys = &mut keys[..nkeys];

    for (k, lanes) in keys[..full]
        .iter_mut()
        .zip(row[..full * g].chunks_exact(g))
    {
        let mut key = 0usize;
        for (j, &x) in lanes.iter().enumerate() {
            key |= (quant.code(x - m) as usize) << (bits * j);
        }
        *k = K::pack(key);
    }
    if full < nkeys {
        let mut key = 0usize;
        for (j, lane) in (full * g..n).enumerate() {
            key |= (quant.code(row[lane] - m) as usize) << (bits * j);
        }
        keys[full] = K::pack(key);
    }

    let mut sum = lut_sum.sum_keys(keys);
    sum -= (padded - n) as f32 * lut_exp.floor_value();
    let inv = 1.0 / sum.max(1e-30);

    fill_norm(lut_exp, inv, norm);
    let full_lanes = full * g;
    for (lanes, &k) in row[..full_lanes]
        .chunks_exact_mut(g)
        .zip(keys[..full].iter())
    {
        let mut k = k.index();
        for x in lanes {
            *x = norm[k & mask];
            k >>= bits;
        }
    }
    if full_lanes < n {
        let mut k = keys[full].index();
        for x in &mut row[full_lanes..n] {
            *x = norm[k & mask];
            k >>= bits;
        }
    }
    row[n..].fill(0.0);
}

thread_local! {
    /// Per-thread engine cache backing [`with_cached_engine`] (and,
    /// through it, `softmax_algo2_once`): loops over a fixed (bits,
    /// clip) stop paying the three table builds per call. Pool workers
    /// never consult this cache — `softmax_rows` hands them the owning
    /// engine's tables by reference — so worker threads cannot trigger
    /// per-tick rebuilds.
    static CACHED_ENGINE: RefCell<Option<BatchSoftmax>> =
        const { RefCell::new(None) };
    static CACHE_HITS: Cell<u64> = const { Cell::new(0) };
    static CACHE_MISSES: Cell<u64> = const { Cell::new(0) };
}

/// This thread's `(hits, misses)` counters for [`with_cached_engine`]
/// — tests pin that steady-state serving re-uses tables instead of
/// rebuilding them every tick.
pub fn cache_stats() -> (u64, u64) {
    (CACHE_HITS.with(Cell::get), CACHE_MISSES.with(Cell::get))
}

/// Zero this thread's [`cache_stats`] counters.
pub fn reset_cache_stats() {
    CACHE_HITS.with(|c| c.set(0));
    CACHE_MISSES.with(|c| c.set(0));
}

/// Find-or-rebuild an engine slot for (`bits`, `clip`) — the one
/// cache policy shared by the sampler scratch and the thread-local
/// [`with_cached_engine`] cache, so key semantics cannot drift.
pub fn ensure_engine(slot: &mut Option<BatchSoftmax>, bits: u32,
                     clip: f32) -> &mut BatchSoftmax {
    if !matches!(slot, Some(e) if e.matches(bits, clip)) {
        *slot = None;
    }
    slot.get_or_insert_with(|| BatchSoftmax::new(bits, clip))
}

/// Run `f` with a thread-cached [`BatchSoftmax`] for (`bits`, `clip`),
/// rebuilding the tables only when the configuration changes.
pub fn with_cached_engine<R>(
    bits: u32, clip: f32, f: impl FnOnce(&mut BatchSoftmax) -> R,
) -> R {
    CACHED_ENGINE.with(|cell| {
        let mut slot = cell.borrow_mut();
        if matches!(slot.as_ref(), Some(e) if e.matches(bits, clip)) {
            CACHE_HITS.with(|c| c.set(c.get() + 1));
        } else {
            CACHE_MISSES.with(|c| c.set(c.get() + 1));
        }
        f(ensure_engine(&mut slot, bits, clip))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exaq::lut::lut_group;
    use crate::exaq::softmax::softmax_algo2_once;
    use crate::util::rng::SplitMix64;

    fn random_plane(rows: usize, len: usize, seed: u64,
                    scale: f32) -> Vec<f32> {
        let mut r = SplitMix64::new(seed);
        (0..rows * len).map(|_| (r.normal() as f32) * scale).collect()
    }

    fn assert_bit_exact(plane: &[f32], reference: &[f32], tag: &str) {
        for (i, (a, b)) in plane.iter().zip(reference).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(),
                       "{tag}: lane {i} diverged: {a} vs {b}");
        }
    }

    #[test]
    fn batched_plane_is_bit_exact_with_scalar_rows() {
        for bits in [1u32, 2, 3, 4] {
            let (rows, len) = (6usize, 50usize); // 50 % 4 != 0
            let mut plane = random_plane(rows, len, 77 + bits as u64, 2.0);
            let mut reference = plane.clone();
            let vlens: Vec<usize> = (0..rows)
                .map(|r| [len, 1, 7, len + 100, 0, 33][r])
                .collect();
            let mut eng = BatchSoftmax::new(bits, -4.5);
            eng.softmax_rows(&mut plane, rows, len, &vlens);
            for (r, row) in reference.chunks_mut(len).enumerate() {
                softmax_algo2_once(row, vlens[r], bits, -4.5);
            }
            assert_bit_exact(&plane, &reference, &format!("bits={bits}"));
        }
    }

    #[test]
    fn empty_valid_lens_means_full_rows() {
        let (rows, len) = (3usize, 31usize);
        let mut a = random_plane(rows, len, 5, 1.5);
        let mut b = a.clone();
        let mut eng = BatchSoftmax::new(2, -4.0);
        eng.softmax_rows(&mut a, rows, len, &[]);
        let full = vec![len; rows];
        let mut eng2 = BatchSoftmax::new(2, -4.0);
        eng2.softmax_rows(&mut b, rows, len, &full);
        assert_bit_exact(&a, &b, "full-row default");
        for row in a.chunks(len) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "{s}");
        }
    }

    #[test]
    fn zero_rows_and_zero_len_are_noops() {
        let mut eng = BatchSoftmax::new(2, -4.0);
        let mut empty: Vec<f32> = Vec::new();
        eng.softmax_rows(&mut empty, 0, 128, &[]);
        eng.softmax_rows(&mut empty, 0, 0, &[]);
        let mut rows_of_nothing: Vec<f32> = Vec::new();
        eng.softmax_rows(&mut rows_of_nothing, 4, 0, &[0, 0, 0, 0]);
    }

    #[test]
    fn m2_plane_packs_four_codes_per_byte() {
        let (rows, len) = (8usize, 256usize);
        let mut plane = random_plane(rows, len, 9, 2.0);
        let mut eng = BatchSoftmax::new(2, -4.0);
        eng.softmax_rows(&mut plane, rows, len, &[]);
        // one byte per 4 codes — the scalar scratch would hold
        // rows*len = 2048 bytes of codes; the packed plane holds 512
        assert_eq!(eng.plane_bytes(), rows * len / 4);
    }

    #[test]
    fn plane_reuse_shrinks_and_regrows() {
        let mut eng = BatchSoftmax::new(2, -4.0);
        let mut big = random_plane(16, 64, 11, 1.0);
        eng.softmax_rows(&mut big, 16, 64, &[]);
        let bytes_big = eng.plane_bytes();
        let mut small = random_plane(2, 8, 12, 1.0);
        eng.softmax_rows(&mut small, 2, 8, &[]);
        assert!(eng.plane_bytes() < bytes_big);
        let mut reference = random_plane(16, 64, 11, 1.0);
        let mut fresh = BatchSoftmax::new(2, -4.0);
        let mut again = reference.clone();
        fresh.softmax_rows(&mut again, 16, 64, &[]);
        eng.softmax_rows(&mut reference, 16, 64, &[]);
        // a reused engine and a fresh one agree bit-for-bit
        assert_bit_exact(&reference, &again, "reuse");
    }

    #[test]
    fn all_neg_infinity_rows_stay_uniform_and_finite() {
        for bits in [2u32, 3, 4] {
            let (rows, len) = (3usize, 24usize);
            let mut plane = vec![f32::NEG_INFINITY; rows * len];
            let mut eng = BatchSoftmax::new(bits, -5.0);
            eng.softmax_rows(&mut plane, rows, len, &[len, 5, len]);
            for (i, &p) in plane.iter().take(len).enumerate() {
                assert!(p.is_finite(), "bits={bits} lane {i}: {p}");
                assert!((p - 1.0 / len as f32).abs() < 1e-5);
            }
            let s: f32 = plane[len..len + 5].iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "bits={bits}: {s}");
            assert!(plane[len + 5..2 * len].iter().all(|&p| p == 0.0));
        }
    }

    #[test]
    fn pooled_plane_is_bit_identical_to_inline() {
        // Small plane on purpose: miri walks the pool + split machinery
        // for UB while staying fast. Hostile valid_lens included.
        let (rows, len) = (9usize, 21usize);
        let vlens = [21usize, 1, 0, 5, 21, 2, 7, 20, 3];
        for bits in [2u32, 3] {
            let mut a = random_plane(rows, len, 31 + bits as u64, 2.0);
            let mut b = a.clone();
            let mut inline_eng = BatchSoftmax::new(bits, -4.0);
            inline_eng.set_threads(1);
            inline_eng.softmax_rows(&mut a, rows, len, &vlens);
            let mut pooled = BatchSoftmax::new(bits, -4.0);
            pooled.set_threads(3);
            pooled.softmax_rows(&mut b, rows, len, &vlens);
            assert_bit_exact(&a, &b, &format!("pooled bits={bits}"));
        }
    }

    #[test]
    fn cache_stats_count_hits_misses_and_ignore_pool_workers() {
        reset_cache_stats();
        with_cached_engine(4, -3.5, |_| ());
        with_cached_engine(4, -3.5, |_| ());
        with_cached_engine(4, -3.5, |_| ());
        assert_eq!(cache_stats(), (2, 1));
        with_cached_engine(2, -3.5, |_| ());
        assert_eq!(cache_stats(), (2, 2));
        // Pooled plane calls borrow the engine's tables directly;
        // worker threads must not touch the thread-local cache.
        let mut eng = BatchSoftmax::new(2, -4.0);
        eng.set_threads(4);
        let mut plane = vec![0.25f32; 8 * 32];
        eng.softmax_rows(&mut plane, 8, 32, &[]);
        assert_eq!(cache_stats(), (2, 2),
                   "pool workers leaked into the engine cache");
    }

    #[test]
    fn cached_engine_is_reused_and_rebuilt_on_config_change() {
        // grow the cached engine's packed plane, then observe that the
        // same configuration gets the same (still-grown) engine back
        // while a config change gets a fresh one
        with_cached_engine(2, -4.25, |e| {
            let mut plane = vec![0.5f32; 8 * 64];
            e.softmax_rows(&mut plane, 8, 64, &[]);
            assert!(e.plane_bytes() > 0);
        });
        with_cached_engine(2, -4.25, |e| {
            assert!(e.matches(2, -4.25));
            assert!(e.plane_bytes() > 0,
                    "cache miss: engine was rebuilt for the same config");
        });
        with_cached_engine(3, -6.0, |e| {
            assert_eq!(e.bits(), 3);
            assert!(!e.matches(2, -4.25));
            assert_eq!(e.plane_bytes(), 0, "expected a fresh engine");
        });
    }

    #[test]
    fn group_matches_lut_group_for_all_bit_widths() {
        for bits in 1u32..=4 {
            let eng = BatchSoftmax::new(bits, -4.0);
            assert_eq!(eng.group(), lut_group(bits), "bits={bits}");
        }
    }
}
