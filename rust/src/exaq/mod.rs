//! The paper's method (EXAQ, §3–§4) implemented natively in Rust.
//!
//! * [`gauss`]  — Gauss–Legendre quadrature + Gaussian pdf substrate.
//! * [`mse`]    — the analytic distortion model: `MSE(C) = MSE_clip +
//!   MSE_quant` (paper Eq. 1–14, Fig. 2).
//! * [`solver`] — numeric minimisation of `MSE(C)` -> optimal clip
//!   `C*(sigma, M)` (Fig. 3).
//! * [`fit`]    — linear approximation of `C*(sigma)` over the practical
//!   sigma range (Table 1).
//! * [`mc`]     — Monte-Carlo validation of the analytic model (the
//!   "simulation" series of Fig. 3).
//! * [`quant`]  — the runtime mid-tread quantizer (spec shared with
//!   `python/compile/kernels/ref.py`).
//! * [`lut`]    — LUT_exp / LUT_sum construction and key packing (Fig. 5).
//! * [`softmax`]— Algorithm 1 (original) and Algorithm 2 (2-bit LUT)
//!   softmax implementations — the Table 3 subjects and the L3 sampling
//!   hot path.
//! * [`batched`]— the batched, bit-packed plane form of Algorithm 2:
//!   [`BatchSoftmax`] runs whole `[rows × len]` logit/attention planes
//!   through a packed code plane whose bytes *are* the LUT_sum keys
//!   (Fig. 5's storage layout), bit-identical to the scalar path.
//! * [`simd`]   — explicit-SIMD quantize+pack / decode lanes
//!   (sse2/avx2/neon behind `cfg(target_arch)`) with the always-compiled
//!   scalar reference; the batched kernel dispatches through these.
//! * [`plane`]  — the cache-blocked packed attention plane:
//!   [`AttentionPlane`] keeps scores in `PackedCodes` form from QK^T
//!   through the weighted-value pass, fusing the premultiplied decode
//!   into the accumulation tile (bit-identical to softmax + dense PV).
//! * [`stream`] — the streaming one-pass form of the plane:
//!   [`StreamingAttention`] fuses QK^T into the packed plane, quantizing
//!   each `TILE_LANES` score strip straight into keys so the dense f32
//!   score plane is never materialized (peak score scratch is one strip,
//!   independent of context length) — bit-identical to
//!   [`AttentionPlane::attend`].
//! * [`footprint`] — shared byte math for the score paths (packed plane,
//!   dense plane, streaming strip), quoted by cost/benches/tests alike.
//! * [`clip`]   — calibration-statistics -> per-layer clip thresholds
//!   (EXAQ via Table 1; NAIVE via min/max midpoint).

pub mod batched;
pub mod clip;
pub mod fit;
pub mod footprint;
pub mod gauss;
pub mod lut;
pub mod mc;
pub mod mse;
pub mod plane;
pub mod quant;
pub mod simd;
pub mod softmax;
pub mod solver;
pub mod stream;

pub use batched::BatchSoftmax;
pub use plane::AttentionPlane;
pub use stream::StreamingAttention;
pub use clip::{clip_exaq, clip_naive, Table1};
pub use lut::{LutExp, LutSum};
pub use quant::Quantizer;
pub use solver::optimal_clip;
