//! Explicit-SIMD lanes for the batched Algorithm-2 kernel, and the one
//! sanctioned `cfg(target_arch)` site (`thread-discipline` lint).
//!
//! Three passes of [`BatchSoftmax`](super::batched::BatchSoftmax) are
//! lane-parallel with *no* cross-lane f32 arithmetic, so they can go
//! wide without touching the bit-exactness story:
//!
//! * [`row_max`] — the max-shift scan. `max` over reals is associative
//!   and exact, vector `max` drops NaN lanes exactly like the scalar
//!   `m.max(x)` fold, and a ±0.0 sign difference in the result is
//!   absorbed by the subsequent `x - m` / `xs - c` subtractions.
//! * [`quant_pack4`] / [`quant_pack2`] — quantize-and-pack. Each lane
//!   runs the *same* op sequence as [`Quantizer::code`]: subtract `m`,
//!   subtract `c`, multiply by the stored `inv_step`, add 0.5, clamp
//!   at zero (NaN → 0, matching `f32::max`), truncate, clamp at
//!   `max_code`. No FMA contraction, no reassociation — every
//!   intermediate is the identical IEEE f32, so the packed key stream
//!   is bit-identical to the scalar path.
//! * [`decode4`] / [`decode2`] — the premultiplied `lut_exp*inv`
//!   output pass is a pure table *selection* (no arithmetic), so any
//!   vector permute that copies the same `norm[code]` entries is
//!   trivially bit-exact.
//! * [`pv_axpy`] / [`pv_accum4`] / [`pv_accum2`] — the fused
//!   weighted-value (PV) pass of
//!   [`AttentionPlane`](super::plane::AttentionPlane). Every output
//!   element `out[j]` is an *independent* accumulation chain
//!   `out[j] = out[j] + p_k * v_kj` in ascending-`k` order; vector
//!   lanes split over `j`, never over `k`, so no f32 sum is ever
//!   reassociated. Each step is a separate IEEE multiply then add —
//!   never an FMA (`vfmadd` / `vmla`), whose single rounding would
//!   change the bits versus the scalar reference.
//! * [`qk_strip`] — the QK^T front of
//!   [`StreamingAttention`](super::stream::StreamingAttention). Each
//!   output score is one dot product folded through a fixed
//!   4-accumulator tree (`((a0+a1)+(a2+a3))+tail`, the same shape as
//!   `LutSum::sum_keys`), separate multiply then add per step, scaled
//!   once at the end. The SSE2 lane is the *identical* tree with the
//!   four accumulators living in one vector register; AVX2 deliberately
//!   delegates to it, because an 8-wide accumulator would be a
//!   different tree and therefore different bits.
//!
//! The denominator reduction is deliberately **not** here: f32
//! addition is order-sensitive, so summation stays in the fixed-tree
//! [`LutSum::sum_keys`](super::lut::LutSum::sum_keys) for every level.
//!
//! [`Level::Scalar`] is always compiled and is the reference the
//! randomized sweeps in `rust/tests/batched_softmax.rs` pin every
//! other level against. x86-64 gets an always-available SSE2 path and
//! a runtime-detected AVX2 path; aarch64 gets NEON. `EXAQ_SIMD`
//! (`scalar` / `sse2` / `avx2` / `neon`) overrides the default pick;
//! an unavailable request falls back to scalar rather than faulting.

use std::sync::OnceLock;

use super::quant::Quantizer;

/// A lane-specialisation level. All variants exist on every arch (so
/// configuration code is portable); dispatch falls back to scalar for
/// levels the current binary does not implement.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Level {
    /// The always-compiled reference implementation.
    Scalar,
    /// x86-64 baseline vectors (always available on x86-64).
    Sse2,
    /// x86-64 256-bit vectors (runtime-detected).
    Avx2,
    /// aarch64 baseline vectors (always available on aarch64).
    Neon,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Scalar => "scalar",
            Level::Sse2 => "sse2",
            Level::Avx2 => "avx2",
            Level::Neon => "neon",
        }
    }

    pub fn parse(s: &str) -> Option<Level> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(Level::Scalar),
            "sse2" => Some(Level::Sse2),
            "avx2" => Some(Level::Avx2),
            "neon" => Some(Level::Neon),
            _ => None,
        }
    }
}

/// Levels usable in this process, in ascending preference order
/// (always starts with [`Level::Scalar`]).
pub fn available_levels() -> Vec<Level> {
    let mut v = vec![Level::Scalar];
    if cfg!(miri) {
        // Keep the interpreter on the reference path.
        return v;
    }
    #[cfg(target_arch = "x86_64")]
    {
        v.push(Level::Sse2);
        if is_x86_feature_detected!("avx2") {
            v.push(Level::Avx2);
        }
    }
    #[cfg(target_arch = "aarch64")]
    v.push(Level::Neon);
    v
}

/// Process-wide default level: `EXAQ_SIMD` if set and available, else
/// the best available. Read once; engines can override per-instance.
pub fn default_level() -> Level {
    static CACHED: OnceLock<Level> = OnceLock::new();
    *CACHED.get_or_init(|| {
        let avail = available_levels();
        match std::env::var("EXAQ_SIMD").ok()
            .and_then(|v| Level::parse(&v))
        {
            Some(l) if avail.contains(&l) => l,
            Some(_) => Level::Scalar,
            None => avail.last().copied().unwrap_or(Level::Scalar),
        }
    })
}

/// Max over `xs`, seeded at `NEG_INFINITY`; NaN lanes are ignored,
/// exactly like the scalar `m = m.max(x)` fold.
pub fn row_max(level: Level, xs: &[f32]) -> f32 {
    match level {
        #[cfg(target_arch = "x86_64")]
        Level::Sse2 => unsafe { x86::row_max_sse2(xs) },
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { x86::row_max_avx2(xs) },
        #[cfg(target_arch = "aarch64")]
        Level::Neon => unsafe { neon::row_max(xs) },
        _ => scalar::row_max(xs),
    }
}

/// Quantize `lanes` (after subtracting `m`) and pack four 2-bit codes
/// per byte key: `c0 | c1<<2 | c2<<4 | c3<<6`. Requires
/// `lanes.len() == 4 * keys.len()`.
pub fn quant_pack4(level: Level, lanes: &[f32], m: f32, q: &Quantizer,
                   keys: &mut [u8]) {
    debug_assert_eq!(lanes.len(), 4 * keys.len());
    match level {
        #[cfg(target_arch = "x86_64")]
        Level::Sse2 => unsafe { x86::quant_pack4_sse2(lanes, m, q, keys) },
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { x86::quant_pack4_avx2(lanes, m, q, keys) },
        #[cfg(target_arch = "aarch64")]
        Level::Neon => unsafe { neon::quant_pack4(lanes, m, q, keys) },
        _ => scalar::quant_pack4(lanes, m, q, keys),
    }
}

/// Quantize `lanes` (after subtracting `m`) and pack two M-bit codes
/// per u16 key: `c0 | c1<<bits`. Requires
/// `lanes.len() == 2 * keys.len()`.
pub fn quant_pack2(level: Level, lanes: &[f32], m: f32, q: &Quantizer,
                   keys: &mut [u16], bits: usize) {
    debug_assert_eq!(lanes.len(), 2 * keys.len());
    match level {
        #[cfg(target_arch = "x86_64")]
        Level::Sse2 => unsafe {
            x86::quant_pack2_sse2(lanes, m, q, keys, bits)
        },
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe {
            x86::quant_pack2_avx2(lanes, m, q, keys, bits)
        },
        #[cfg(target_arch = "aarch64")]
        Level::Neon => unsafe { neon::quant_pack2(lanes, m, q, keys, bits) },
        _ => scalar::quant_pack2(lanes, m, q, keys, bits),
    }
}

/// Decode byte keys (four 2-bit codes each) through the premultiplied
/// `norm` table (>= 4 entries). Requires `lanes.len() == 4 * keys.len()`.
pub fn decode4(level: Level, keys: &[u8], norm: &[f32],
               lanes: &mut [f32]) {
    debug_assert_eq!(lanes.len(), 4 * keys.len());
    match level {
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { x86::decode4_avx2(keys, norm, lanes) },
        // A 4-entry in-register LUT needs a variable permute, which
        // SSE2/NEON lack cheaply; the table lives in L1 either way.
        _ => scalar::decode4(keys, norm, lanes),
    }
}

/// Decode u16 keys (two M-bit codes each) through the premultiplied
/// `norm` table (>= 2^bits entries). Requires
/// `lanes.len() == 2 * keys.len()`.
pub fn decode2(level: Level, keys: &[u16], norm: &[f32],
               lanes: &mut [f32], bits: usize) {
    debug_assert_eq!(lanes.len(), 2 * keys.len());
    match (level, bits) {
        // M = 3: the whole 8-entry table fits one 256-bit register.
        #[cfg(target_arch = "x86_64")]
        (Level::Avx2, 3) => unsafe { x86::decode2_avx2(keys, norm, lanes) },
        _ => scalar::decode2(keys, norm, lanes, bits),
    }
}

/// One weighted value row folded into the output accumulator:
/// `out[j] = out[j] + p * v[j]` per lane, separate multiply then add
/// (never FMA). The per-`j` chains are independent, so vectorising
/// over `j` is bit-exact for any width.
pub fn pv_axpy(level: Level, p: f32, v: &[f32], out: &mut [f32]) {
    debug_assert_eq!(v.len(), out.len());
    match level {
        #[cfg(target_arch = "x86_64")]
        Level::Sse2 => unsafe { x86::pv_axpy_sse2(p, v, out) },
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { x86::pv_axpy_avx2(p, v, out) },
        #[cfg(target_arch = "aarch64")]
        Level::Neon => unsafe { neon::pv_axpy(p, v, out) },
        _ => scalar::pv_axpy(p, v, out),
    }
}

/// Fused packed-PV accumulation over byte keys (four 2-bit codes
/// each): for every code, decode through the premultiplied `norm`
/// table (>= 4 entries) and fold its `d`-wide value row into `out`,
/// codes in ascending lane order. Requires
/// `vtile.len() == 4 * keys.len() * d` and `out.len() == d`.
pub fn pv_accum4(level: Level, keys: &[u8], norm: &[f32],
                 vtile: &[f32], d: usize, out: &mut [f32]) {
    debug_assert_eq!(vtile.len(), 4 * keys.len() * d);
    debug_assert_eq!(out.len(), d);
    match level {
        #[cfg(target_arch = "x86_64")]
        Level::Sse2 => unsafe {
            x86::pv_accum4_sse2(keys, norm, vtile, d, out)
        },
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe {
            x86::pv_accum4_avx2(keys, norm, vtile, d, out)
        },
        #[cfg(target_arch = "aarch64")]
        Level::Neon => unsafe {
            neon::pv_accum4(keys, norm, vtile, d, out)
        },
        _ => scalar::pv_accum4(keys, norm, vtile, d, out),
    }
}

/// Fused packed-PV accumulation over u16 keys (two M-bit codes each);
/// same contract as [`pv_accum4`] with
/// `vtile.len() == 2 * keys.len() * d`.
pub fn pv_accum2(level: Level, keys: &[u16], norm: &[f32],
                 vtile: &[f32], d: usize, out: &mut [f32],
                 bits: usize) {
    debug_assert_eq!(vtile.len(), 2 * keys.len() * d);
    debug_assert_eq!(out.len(), d);
    match level {
        #[cfg(target_arch = "x86_64")]
        Level::Sse2 => unsafe {
            x86::pv_accum2_sse2(keys, norm, vtile, d, out, bits)
        },
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe {
            x86::pv_accum2_avx2(keys, norm, vtile, d, out, bits)
        },
        #[cfg(target_arch = "aarch64")]
        Level::Neon => unsafe {
            neon::pv_accum2(keys, norm, vtile, d, out, bits)
        },
        _ => scalar::pv_accum2(keys, norm, vtile, d, out, bits),
    }
}

/// One QK^T strip: `out[i] = dot(q, k_tile[i*d..][..d]) * scale` for
/// every key row resident in the tile. Requires `q.len() == d` and
/// `k_tile.len() == out.len() * d`.
///
/// The dot product is a *reduction*, so unlike the lane-parallel
/// passes above it fixes its own summation tree: 4 independent
/// accumulators over ascending 4-chunks of `d`, a sequential scalar
/// tail, combined as `((a0+a1)+(a2+a3))+tail`, then exactly one
/// multiply by `scale`. The SSE2 lane keeps the four accumulators in
/// one vector register (separate `mulps` + `addps`, never FMA) and is
/// bit-identical to the scalar tree by construction; AVX2 delegates to
/// SSE2 because 8 accumulators would be a different tree.
pub fn qk_strip(level: Level, q: &[f32], k_tile: &[f32], d: usize,
                scale: f32, out: &mut [f32]) {
    debug_assert_eq!(q.len(), d);
    debug_assert_eq!(k_tile.len(), out.len() * d);
    match level {
        #[cfg(target_arch = "x86_64")]
        Level::Sse2 | Level::Avx2 => unsafe {
            x86::qk_strip_sse2(q, k_tile, d, scale, out)
        },
        #[cfg(target_arch = "aarch64")]
        Level::Neon => unsafe {
            neon::qk_strip(q, k_tile, d, scale, out)
        },
        _ => scalar::qk_strip(q, k_tile, d, scale, out),
    }
}

/// The reference lanes: bit-for-bit the loops of the pre-SIMD batched
/// kernel. Every other level is tested against these.
mod scalar {
    use super::Quantizer;

    pub(super) fn row_max(xs: &[f32]) -> f32 {
        let mut m = f32::NEG_INFINITY;
        for &x in xs {
            m = m.max(x);
        }
        m
    }

    pub(super) fn quant_pack4(lanes: &[f32], m: f32, q: &Quantizer,
                              keys: &mut [u8]) {
        for (k, c) in keys.iter_mut().zip(lanes.chunks_exact(4)) {
            let c0 = q.code(c[0] - m) as usize;
            let c1 = q.code(c[1] - m) as usize;
            let c2 = q.code(c[2] - m) as usize;
            let c3 = q.code(c[3] - m) as usize;
            *k = (c0 | (c1 << 2) | (c2 << 4) | (c3 << 6)) as u8;
        }
    }

    pub(super) fn quant_pack2(lanes: &[f32], m: f32, q: &Quantizer,
                              keys: &mut [u16], bits: usize) {
        for (k, c) in keys.iter_mut().zip(lanes.chunks_exact(2)) {
            let c0 = q.code(c[0] - m) as usize;
            let c1 = q.code(c[1] - m) as usize;
            *k = (c0 | (c1 << bits)) as u16;
        }
    }

    pub(super) fn decode4(keys: &[u8], norm: &[f32], lanes: &mut [f32]) {
        for (c, &k) in lanes.chunks_exact_mut(4).zip(keys) {
            let k = k as usize;
            c[0] = norm[k & 3];
            c[1] = norm[(k >> 2) & 3];
            c[2] = norm[(k >> 4) & 3];
            c[3] = norm[(k >> 6) & 3];
        }
    }

    pub(super) fn decode2(keys: &[u16], norm: &[f32],
                          lanes: &mut [f32], bits: usize) {
        let mask = (1usize << bits) - 1;
        for (c, &k) in lanes.chunks_exact_mut(2).zip(keys) {
            let k = k as usize;
            c[0] = norm[k & mask];
            c[1] = norm[(k >> bits) & mask];
        }
    }

    pub(super) fn pv_axpy(p: f32, v: &[f32], out: &mut [f32]) {
        for (o, &x) in out.iter_mut().zip(v) {
            *o += p * x;
        }
    }

    pub(super) fn pv_accum4(keys: &[u8], norm: &[f32], vtile: &[f32],
                            d: usize, out: &mut [f32]) {
        for (&k, vg) in keys.iter().zip(vtile.chunks_exact(4 * d)) {
            let k = k as usize;
            pv_axpy(norm[k & 3], &vg[..d], out);
            pv_axpy(norm[(k >> 2) & 3], &vg[d..2 * d], out);
            pv_axpy(norm[(k >> 4) & 3], &vg[2 * d..3 * d], out);
            pv_axpy(norm[(k >> 6) & 3], &vg[3 * d..], out);
        }
    }

    pub(super) fn pv_accum2(keys: &[u16], norm: &[f32], vtile: &[f32],
                            d: usize, out: &mut [f32], bits: usize) {
        let mask = (1usize << bits) - 1;
        for (&k, vg) in keys.iter().zip(vtile.chunks_exact(2 * d)) {
            let k = k as usize;
            pv_axpy(norm[k & mask], &vg[..d], out);
            pv_axpy(norm[(k >> bits) & mask], &vg[d..], out);
        }
    }

    /// The reference dot-product tree: 4 accumulators over ascending
    /// 4-chunks (separate multiply, then add), sequential scalar tail,
    /// fixed combine `((a0+a1)+(a2+a3))+tail` — the `sum_keys` shape.
    fn dot_tree(q: &[f32], k: &[f32]) -> f32 {
        let (mut a0, mut a1, mut a2, mut a3) =
            (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        let mut qc = q.chunks_exact(4);
        let mut kc = k.chunks_exact(4);
        for (qs, ks) in qc.by_ref().zip(kc.by_ref()) {
            a0 += qs[0] * ks[0];
            a1 += qs[1] * ks[1];
            a2 += qs[2] * ks[2];
            a3 += qs[3] * ks[3];
        }
        let mut tail = 0.0f32;
        for (&qx, &kx) in qc.remainder().iter().zip(kc.remainder()) {
            tail += qx * kx;
        }
        ((a0 + a1) + (a2 + a3)) + tail
    }

    pub(super) fn qk_strip(q: &[f32], k_tile: &[f32], d: usize,
                           scale: f32, out: &mut [f32]) {
        for (o, krow) in out.iter_mut().zip(k_tile.chunks_exact(d)) {
            *o = dot_tree(q, krow) * scale;
        }
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use core::arch::x86_64::*;

    use super::Quantizer;

    /// Broadcast constants of the quantize pass (one build per call,
    /// hoisted out of the lane loop).
    #[derive(Clone, Copy)]
    struct Consts {
        m: __m128,
        c: __m128,
        inv: __m128,
        half: __m128,
        zero: __m128,
        maxc: __m128i,
    }

    unsafe fn consts(m: f32, q: &Quantizer) -> Consts {
        Consts {
            m: _mm_set1_ps(m),
            c: _mm_set1_ps(q.c),
            inv: _mm_set1_ps(q.inv_step()),
            half: _mm_set1_ps(0.5),
            zero: _mm_setzero_ps(),
            maxc: _mm_set1_epi32(q.max_code() as i32),
        }
    }

    /// Four codes at once, each the exact op sequence of
    /// `Quantizer::code` applied to `lane - m`:
    /// sub, sub, mul, add 0.5, max(…, 0) with NaN → 0 (maxps returns
    /// its second operand on NaN, like `f32::max(NaN, 0.0)`), truncate
    /// (`cvttps` = `as u32` in range), clamp at `max_code` (emulated
    /// compare+select — `_mm_min_epi32` is SSE4.1, not SSE2).
    unsafe fn quant4_sse2(ptr: *const f32, k: &Consts) -> __m128i {
        let v = _mm_loadu_ps(ptr);
        let v = _mm_sub_ps(v, k.m);
        let v = _mm_sub_ps(v, k.c);
        let v = _mm_mul_ps(v, k.inv);
        let v = _mm_add_ps(v, k.half);
        let v = _mm_max_ps(v, k.zero);
        let c = _mm_cvttps_epi32(v);
        let gt = _mm_cmpgt_epi32(c, k.maxc);
        _mm_or_si128(_mm_and_si128(gt, k.maxc), _mm_andnot_si128(gt, c))
    }

    pub(super) unsafe fn row_max_sse2(xs: &[f32]) -> f32 {
        let mut acc = _mm_set1_ps(f32::NEG_INFINITY);
        let mut it = xs.chunks_exact(4);
        for chunk in it.by_ref() {
            // (x, acc) order: maxps keeps acc on a NaN lane, exactly
            // like the scalar `m.max(x)` fold ignoring NaN.
            acc = _mm_max_ps(_mm_loadu_ps(chunk.as_ptr()), acc);
        }
        let mut tmp = [0f32; 4];
        _mm_storeu_ps(tmp.as_mut_ptr(), acc);
        let mut m = tmp[0].max(tmp[1]).max(tmp[2].max(tmp[3]));
        for &x in it.remainder() {
            m = m.max(x);
        }
        m
    }

    pub(super) unsafe fn quant_pack4_sse2(lanes: &[f32], m: f32,
                                          q: &Quantizer,
                                          keys: &mut [u8]) {
        let k = consts(m, q);
        let mut tmp = [0i32; 4];
        for (key, c) in keys.iter_mut().zip(lanes.chunks_exact(4)) {
            let v = quant4_sse2(c.as_ptr(), &k);
            _mm_storeu_si128(tmp.as_mut_ptr() as *mut __m128i, v);
            *key = (tmp[0] | (tmp[1] << 2) | (tmp[2] << 4)
                    | (tmp[3] << 6)) as u8;
        }
    }

    pub(super) unsafe fn quant_pack2_sse2(lanes: &[f32], m: f32,
                                          q: &Quantizer,
                                          keys: &mut [u16],
                                          bits: usize) {
        let k = consts(m, q);
        let mut tmp = [0i32; 4];
        let pairs = keys.len() / 2;
        let (kmain, krest) = keys.split_at_mut(pairs * 2);
        let (lmain, lrest) = lanes.split_at(pairs * 4);
        for (kc, c) in kmain.chunks_exact_mut(2)
            .zip(lmain.chunks_exact(4))
        {
            let v = quant4_sse2(c.as_ptr(), &k);
            _mm_storeu_si128(tmp.as_mut_ptr() as *mut __m128i, v);
            kc[0] = (tmp[0] | (tmp[1] << bits)) as u16;
            kc[1] = (tmp[2] | (tmp[3] << bits)) as u16;
        }
        super::scalar::quant_pack2(lrest, m, q, krest, bits);
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn row_max_avx2(xs: &[f32]) -> f32 {
        let mut acc = _mm256_set1_ps(f32::NEG_INFINITY);
        let mut it = xs.chunks_exact(8);
        for chunk in it.by_ref() {
            acc = _mm256_max_ps(_mm256_loadu_ps(chunk.as_ptr()), acc);
        }
        let mut tmp = [0f32; 8];
        _mm256_storeu_ps(tmp.as_mut_ptr(), acc);
        let mut m = f32::NEG_INFINITY;
        for &t in &tmp {
            m = m.max(t);
        }
        for &x in it.remainder() {
            m = m.max(x);
        }
        m
    }

    #[target_feature(enable = "avx2")]
    unsafe fn quant8_avx2(ptr: *const f32, m: __m256, c: __m256,
                          inv: __m256, maxc: __m256i) -> __m256i {
        let v = _mm256_loadu_ps(ptr);
        let v = _mm256_sub_ps(v, m);
        let v = _mm256_sub_ps(v, c);
        let v = _mm256_mul_ps(v, inv);
        let v = _mm256_add_ps(v, _mm256_set1_ps(0.5));
        let v = _mm256_max_ps(v, _mm256_setzero_ps());
        _mm256_min_epi32(_mm256_cvttps_epi32(v), maxc)
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn quant_pack4_avx2(lanes: &[f32], m: f32,
                                          q: &Quantizer,
                                          keys: &mut [u8]) {
        let mv = _mm256_set1_ps(m);
        let cv = _mm256_set1_ps(q.c);
        let iv = _mm256_set1_ps(q.inv_step());
        let maxc = _mm256_set1_epi32(q.max_code() as i32);
        let mut tmp = [0i32; 8];
        let pairs = keys.len() / 2;
        let (kmain, krest) = keys.split_at_mut(pairs * 2);
        let (lmain, lrest) = lanes.split_at(pairs * 8);
        for (kc, c) in kmain.chunks_exact_mut(2)
            .zip(lmain.chunks_exact(8))
        {
            let v = quant8_avx2(c.as_ptr(), mv, cv, iv, maxc);
            _mm256_storeu_si256(tmp.as_mut_ptr() as *mut __m256i, v);
            kc[0] = (tmp[0] | (tmp[1] << 2) | (tmp[2] << 4)
                     | (tmp[3] << 6)) as u8;
            kc[1] = (tmp[4] | (tmp[5] << 2) | (tmp[6] << 4)
                     | (tmp[7] << 6)) as u8;
        }
        quant_pack4_sse2(lrest, m, q, krest);
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn quant_pack2_avx2(lanes: &[f32], m: f32,
                                          q: &Quantizer,
                                          keys: &mut [u16],
                                          bits: usize) {
        let mv = _mm256_set1_ps(m);
        let cv = _mm256_set1_ps(q.c);
        let iv = _mm256_set1_ps(q.inv_step());
        let maxc = _mm256_set1_epi32(q.max_code() as i32);
        let mut tmp = [0i32; 8];
        let quads = keys.len() / 4;
        let (kmain, krest) = keys.split_at_mut(quads * 4);
        let (lmain, lrest) = lanes.split_at(quads * 8);
        for (kc, c) in kmain.chunks_exact_mut(4)
            .zip(lmain.chunks_exact(8))
        {
            let v = quant8_avx2(c.as_ptr(), mv, cv, iv, maxc);
            _mm256_storeu_si256(tmp.as_mut_ptr() as *mut __m256i, v);
            kc[0] = (tmp[0] | (tmp[1] << bits)) as u16;
            kc[1] = (tmp[2] | (tmp[3] << bits)) as u16;
            kc[2] = (tmp[4] | (tmp[5] << bits)) as u16;
            kc[3] = (tmp[6] | (tmp[7] << bits)) as u16;
        }
        quant_pack2_sse2(lrest, m, q, krest, bits);
    }

    /// Decode is pure selection: `vpermps` copies `norm` entries by
    /// code index — bit-identical to the scalar lookups by definition.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn decode4_avx2(keys: &[u8], norm: &[f32],
                                      lanes: &mut [f32]) {
        let t = _mm256_setr_ps(norm[0], norm[1], norm[2], norm[3],
                               norm[0], norm[1], norm[2], norm[3]);
        let pairs = keys.len() / 2;
        let (kmain, krest) = keys.split_at(pairs * 2);
        let (lmain, lrest) = lanes.split_at_mut(pairs * 8);
        for (kc, c) in kmain.chunks_exact(2)
            .zip(lmain.chunks_exact_mut(8))
        {
            let a = kc[0] as i32;
            let b = kc[1] as i32;
            let idx = _mm256_setr_epi32(
                a & 3, (a >> 2) & 3, (a >> 4) & 3, (a >> 6) & 3,
                b & 3, (b >> 2) & 3, (b >> 4) & 3, (b >> 6) & 3,
            );
            _mm256_storeu_ps(c.as_mut_ptr(),
                             _mm256_permutevar8x32_ps(t, idx));
        }
        super::scalar::decode4(krest, norm, lrest);
    }

    /// `out[j] = out[j] + p * v[j]`, four lanes of `j` at a time via
    /// `mulps` then `addps` — two separately-rounded IEEE ops, exactly
    /// the scalar chain. `vfmadd` would fuse the rounding and change
    /// the bits, so it is never emitted here (intrinsics lower to
    /// their own instructions; LLVM does not contract them).
    pub(super) unsafe fn pv_axpy_sse2(p: f32, v: &[f32],
                                      out: &mut [f32]) {
        let pv = _mm_set1_ps(p);
        let n = v.len();
        let mut i = 0usize;
        while i + 4 <= n {
            let x = _mm_loadu_ps(v.as_ptr().add(i));
            let o = _mm_loadu_ps(out.as_ptr().add(i));
            let r = _mm_add_ps(o, _mm_mul_ps(pv, x));
            _mm_storeu_ps(out.as_mut_ptr().add(i), r);
            i += 4;
        }
        super::scalar::pv_axpy(p, &v[i..], &mut out[i..]);
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn pv_axpy_avx2(p: f32, v: &[f32],
                                      out: &mut [f32]) {
        let pv = _mm256_set1_ps(p);
        let n = v.len();
        let mut i = 0usize;
        while i + 8 <= n {
            let x = _mm256_loadu_ps(v.as_ptr().add(i));
            let o = _mm256_loadu_ps(out.as_ptr().add(i));
            let r = _mm256_add_ps(o, _mm256_mul_ps(pv, x));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), r);
            i += 8;
        }
        pv_axpy_sse2(p, &v[i..], &mut out[i..]);
    }

    pub(super) unsafe fn pv_accum4_sse2(keys: &[u8], norm: &[f32],
                                        vtile: &[f32], d: usize,
                                        out: &mut [f32]) {
        for (&k, vg) in keys.iter().zip(vtile.chunks_exact(4 * d)) {
            let k = k as usize;
            pv_axpy_sse2(norm[k & 3], &vg[..d], out);
            pv_axpy_sse2(norm[(k >> 2) & 3], &vg[d..2 * d], out);
            pv_axpy_sse2(norm[(k >> 4) & 3], &vg[2 * d..3 * d], out);
            pv_axpy_sse2(norm[(k >> 6) & 3], &vg[3 * d..], out);
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn pv_accum4_avx2(keys: &[u8], norm: &[f32],
                                        vtile: &[f32], d: usize,
                                        out: &mut [f32]) {
        for (&k, vg) in keys.iter().zip(vtile.chunks_exact(4 * d)) {
            let k = k as usize;
            pv_axpy_avx2(norm[k & 3], &vg[..d], out);
            pv_axpy_avx2(norm[(k >> 2) & 3], &vg[d..2 * d], out);
            pv_axpy_avx2(norm[(k >> 4) & 3], &vg[2 * d..3 * d], out);
            pv_axpy_avx2(norm[(k >> 6) & 3], &vg[3 * d..], out);
        }
    }

    pub(super) unsafe fn pv_accum2_sse2(keys: &[u16], norm: &[f32],
                                        vtile: &[f32], d: usize,
                                        out: &mut [f32], bits: usize) {
        let mask = (1usize << bits) - 1;
        for (&k, vg) in keys.iter().zip(vtile.chunks_exact(2 * d)) {
            let k = k as usize;
            pv_axpy_sse2(norm[k & mask], &vg[..d], out);
            pv_axpy_sse2(norm[(k >> bits) & mask], &vg[d..], out);
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn pv_accum2_avx2(keys: &[u16], norm: &[f32],
                                        vtile: &[f32], d: usize,
                                        out: &mut [f32], bits: usize) {
        let mask = (1usize << bits) - 1;
        for (&k, vg) in keys.iter().zip(vtile.chunks_exact(2 * d)) {
            let k = k as usize;
            pv_axpy_avx2(norm[k & mask], &vg[..d], out);
            pv_axpy_avx2(norm[(k >> bits) & mask], &vg[d..], out);
        }
    }

    /// The scalar `dot_tree` with a0..a3 living in one vector
    /// register: each 4-chunk is a separate `mulps` then `addps`
    /// (never contracted to FMA), so lane `i` of `acc` holds exactly
    /// the scalar accumulator `a_i`. The horizontal combine and the
    /// tail run in scalar f32, in the reference order. AVX2 calls this
    /// too: an 8-wide accumulator would be a different tree.
    pub(super) unsafe fn qk_strip_sse2(q: &[f32], k_tile: &[f32],
                                       d: usize, scale: f32,
                                       out: &mut [f32]) {
        let full = d / 4;
        let mut tmp = [0f32; 4];
        for (o, krow) in out.iter_mut().zip(k_tile.chunks_exact(d)) {
            let mut acc4 = _mm_setzero_ps();
            for ch in 0..full {
                let qv = _mm_loadu_ps(q.as_ptr().add(ch * 4));
                let kv = _mm_loadu_ps(krow.as_ptr().add(ch * 4));
                acc4 = _mm_add_ps(acc4, _mm_mul_ps(qv, kv));
            }
            _mm_storeu_ps(tmp.as_mut_ptr(), acc4);
            let mut tail = 0.0f32;
            for j in full * 4..d {
                tail += q[j] * krow[j];
            }
            *o = (((tmp[0] + tmp[1]) + (tmp[2] + tmp[3])) + tail)
                * scale;
        }
    }

    /// M = 3 only: the 8-entry premultiplied table is exactly one
    /// 256-bit register.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn decode2_avx2(keys: &[u16], norm: &[f32],
                                      lanes: &mut [f32]) {
        let t = _mm256_loadu_ps(norm.as_ptr());
        let quads = keys.len() / 4;
        let (kmain, krest) = keys.split_at(quads * 4);
        let (lmain, lrest) = lanes.split_at_mut(quads * 8);
        for (kc, c) in kmain.chunks_exact(4)
            .zip(lmain.chunks_exact_mut(8))
        {
            let (a, b) = (kc[0] as i32, kc[1] as i32);
            let (d, e) = (kc[2] as i32, kc[3] as i32);
            let idx = _mm256_setr_epi32(
                a & 7, (a >> 3) & 7, b & 7, (b >> 3) & 7,
                d & 7, (d >> 3) & 7, e & 7, (e >> 3) & 7,
            );
            _mm256_storeu_ps(c.as_mut_ptr(),
                             _mm256_permutevar8x32_ps(t, idx));
        }
        super::scalar::decode2(krest, norm, lrest, 3);
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use core::arch::aarch64::*;

    use super::Quantizer;

    #[derive(Clone, Copy)]
    struct Consts {
        m: float32x4_t,
        c: float32x4_t,
        inv: float32x4_t,
        half: float32x4_t,
        zero: float32x4_t,
        maxc: uint32x4_t,
    }

    unsafe fn consts(m: f32, q: &Quantizer) -> Consts {
        Consts {
            m: vdupq_n_f32(m),
            c: vdupq_n_f32(q.c),
            inv: vdupq_n_f32(q.inv_step()),
            half: vdupq_n_f32(0.5),
            zero: vdupq_n_f32(0.0),
            maxc: vdupq_n_u32(q.max_code() as u32),
        }
    }

    /// `vmaxq` propagates NaN (unlike maxps), but `vcvtq_u32_f32`
    /// (FCVTZU) then maps NaN to 0 — the same final code the scalar
    /// `k.max(0.0) as u32` produces. Truncation and saturation match
    /// the Rust `as` cast.
    unsafe fn quant4(ptr: *const f32, k: &Consts) -> uint32x4_t {
        let v = vld1q_f32(ptr);
        let v = vsubq_f32(v, k.m);
        let v = vsubq_f32(v, k.c);
        let v = vmulq_f32(v, k.inv);
        let v = vaddq_f32(v, k.half);
        let v = vmaxq_f32(v, k.zero);
        vminq_u32(vcvtq_u32_f32(v), k.maxc)
    }

    pub(super) unsafe fn row_max(xs: &[f32]) -> f32 {
        let mut acc = vdupq_n_f32(f32::NEG_INFINITY);
        let mut it = xs.chunks_exact(4);
        for chunk in it.by_ref() {
            // FMAXNM = IEEE maxNum: NaN lanes lose, like `f32::max`.
            acc = vmaxnmq_f32(acc, vld1q_f32(chunk.as_ptr()));
        }
        let mut m = vmaxnmvq_f32(acc);
        for &x in it.remainder() {
            m = m.max(x);
        }
        m
    }

    pub(super) unsafe fn quant_pack4(lanes: &[f32], m: f32,
                                     q: &Quantizer, keys: &mut [u8]) {
        let k = consts(m, q);
        let mut tmp = [0u32; 4];
        for (key, c) in keys.iter_mut().zip(lanes.chunks_exact(4)) {
            vst1q_u32(tmp.as_mut_ptr(), quant4(c.as_ptr(), &k));
            *key = (tmp[0] | (tmp[1] << 2) | (tmp[2] << 4)
                    | (tmp[3] << 6)) as u8;
        }
    }

    pub(super) unsafe fn quant_pack2(lanes: &[f32], m: f32,
                                     q: &Quantizer, keys: &mut [u16],
                                     bits: usize) {
        let k = consts(m, q);
        let mut tmp = [0u32; 4];
        let pairs = keys.len() / 2;
        let (kmain, krest) = keys.split_at_mut(pairs * 2);
        let (lmain, lrest) = lanes.split_at(pairs * 4);
        for (kc, c) in kmain.chunks_exact_mut(2)
            .zip(lmain.chunks_exact(4))
        {
            vst1q_u32(tmp.as_mut_ptr(), quant4(c.as_ptr(), &k));
            kc[0] = (tmp[0] | (tmp[1] << bits)) as u16;
            kc[1] = (tmp[2] | (tmp[3] << bits)) as u16;
        }
        super::scalar::quant_pack2(lrest, m, q, krest, bits);
    }

    /// Separate `vmulq` + `vaddq` per step — `vmlaq_f32` lowers to
    /// FMLA (fused, single rounding) and would break bit-exactness
    /// against the scalar chain, so it is never used.
    pub(super) unsafe fn pv_axpy(p: f32, v: &[f32], out: &mut [f32]) {
        let pv = vdupq_n_f32(p);
        let n = v.len();
        let mut i = 0usize;
        while i + 4 <= n {
            let x = vld1q_f32(v.as_ptr().add(i));
            let o = vld1q_f32(out.as_ptr().add(i));
            let r = vaddq_f32(o, vmulq_f32(pv, x));
            vst1q_f32(out.as_mut_ptr().add(i), r);
            i += 4;
        }
        super::scalar::pv_axpy(p, &v[i..], &mut out[i..]);
    }

    pub(super) unsafe fn pv_accum4(keys: &[u8], norm: &[f32],
                                   vtile: &[f32], d: usize,
                                   out: &mut [f32]) {
        for (&k, vg) in keys.iter().zip(vtile.chunks_exact(4 * d)) {
            let k = k as usize;
            pv_axpy(norm[k & 3], &vg[..d], out);
            pv_axpy(norm[(k >> 2) & 3], &vg[d..2 * d], out);
            pv_axpy(norm[(k >> 4) & 3], &vg[2 * d..3 * d], out);
            pv_axpy(norm[(k >> 6) & 3], &vg[3 * d..], out);
        }
    }

    pub(super) unsafe fn pv_accum2(keys: &[u16], norm: &[f32],
                                   vtile: &[f32], d: usize,
                                   out: &mut [f32], bits: usize) {
        let mask = (1usize << bits) - 1;
        for (&k, vg) in keys.iter().zip(vtile.chunks_exact(2 * d)) {
            let k = k as usize;
            pv_axpy(norm[k & mask], &vg[..d], out);
            pv_axpy(norm[(k >> bits) & mask], &vg[d..], out);
        }
    }

    /// The scalar `dot_tree` with a0..a3 in one vector register:
    /// separate `vmulq` + `vaddq` per 4-chunk (`vmlaq` lowers to FMLA
    /// and would change the bits), scalar combine and tail in the
    /// reference order.
    pub(super) unsafe fn qk_strip(q: &[f32], k_tile: &[f32], d: usize,
                                  scale: f32, out: &mut [f32]) {
        let full = d / 4;
        let mut tmp = [0f32; 4];
        for (o, krow) in out.iter_mut().zip(k_tile.chunks_exact(d)) {
            let mut acc4 = vdupq_n_f32(0.0);
            for ch in 0..full {
                let qv = vld1q_f32(q.as_ptr().add(ch * 4));
                let kv = vld1q_f32(krow.as_ptr().add(ch * 4));
                acc4 = vaddq_f32(acc4, vmulq_f32(qv, kv));
            }
            vst1q_f32(tmp.as_mut_ptr(), acc4);
            let mut tail = 0.0f32;
            for j in full * 4..d {
                tail += q[j] * krow[j];
            }
            *o = (((tmp[0] + tmp[1]) + (tmp[2] + tmp[3])) + tail)
                * scale;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    fn hostile_lanes(n: usize, seed: u64) -> Vec<f32> {
        let mut r = SplitMix64::new(seed);
        (0..n)
            .map(|i| match i % 11 {
                7 => f32::NAN,
                5 => f32::NEG_INFINITY,
                3 => f32::INFINITY,
                _ => (r.normal() as f32) * 3.0,
            })
            .collect()
    }

    #[test]
    fn every_level_matches_scalar_quant_pack4() {
        let q = Quantizer::new(2, -4.5);
        for level in available_levels() {
            // 13 groups: exercises the avx2 odd-pair remainder
            let lanes = hostile_lanes(13 * 4, 42);
            let m = scalar::row_max(&lanes);
            let mut want = vec![0u8; 13];
            scalar::quant_pack4(&lanes, m, &q, &mut want);
            let mut got = vec![0u8; 13];
            quant_pack4(level, &lanes, m, &q, &mut got);
            assert_eq!(got, want, "level {}", level.name());
        }
    }

    #[test]
    fn every_level_matches_scalar_quant_pack2() {
        for bits in [3usize, 4] {
            let q = Quantizer::new(bits as u32, -6.0);
            for level in available_levels() {
                // 9 keys: odd counts hit every remainder path
                let lanes = hostile_lanes(9 * 2, 7 + bits as u64);
                let m = scalar::row_max(&lanes);
                let mut want = vec![0u16; 9];
                scalar::quant_pack2(&lanes, m, &q, &mut want, bits);
                let mut got = vec![0u16; 9];
                quant_pack2(level, &lanes, m, &q, &mut got, bits);
                assert_eq!(got, want,
                           "level {} bits {bits}", level.name());
            }
        }
    }

    #[test]
    fn every_level_matches_scalar_row_max() {
        for len in [0usize, 1, 3, 4, 5, 8, 17, 64, 65] {
            let xs = hostile_lanes(len, 1000 + len as u64);
            let want = scalar::row_max(&xs);
            for level in available_levels() {
                let got = row_max(level, &xs);
                assert_eq!(got.to_bits(), want.to_bits(),
                           "level {} len {len}", level.name());
            }
        }
    }

    #[test]
    fn every_level_matches_scalar_decode() {
        let mut r = SplitMix64::new(9);
        let norm4: Vec<f32> =
            (0..4).map(|_| r.uniform() as f32).collect();
        let norm8: Vec<f32> =
            (0..8).map(|_| r.uniform() as f32).collect();
        let keys4: Vec<u8> = (0..13).map(|_| r.below(256) as u8).collect();
        let keys2: Vec<u16> =
            (0..9).map(|_| r.below(64) as u16).collect();
        for level in available_levels() {
            let mut want = vec![0f32; 13 * 4];
            scalar::decode4(&keys4, &norm4, &mut want);
            let mut got = vec![0f32; 13 * 4];
            decode4(level, &keys4, &norm4, &mut got);
            assert_eq!(got, want, "decode4 level {}", level.name());

            let mut want = vec![0f32; 9 * 2];
            scalar::decode2(&keys2, &norm8, &mut want, 3);
            let mut got = vec![0f32; 9 * 2];
            decode2(level, &keys2, &norm8, &mut got, 3);
            assert_eq!(got, want, "decode2 level {}", level.name());
        }
    }

    #[test]
    fn every_level_matches_scalar_pv_axpy() {
        let mut r = SplitMix64::new(21);
        // 1..=17 covers the scalar tail, one sse2 vector + tail, and
        // one avx2 vector + sse2 vector + tail
        for d in [1usize, 2, 3, 4, 5, 7, 8, 9, 12, 16, 17] {
            let v: Vec<f32> =
                (0..d).map(|_| (r.normal() as f32) * 2.0).collect();
            let p = r.normal() as f32;
            let base: Vec<f32> =
                (0..d).map(|_| r.normal() as f32).collect();
            let mut want = base.clone();
            scalar::pv_axpy(p, &v, &mut want);
            for level in available_levels() {
                let mut got = base.clone();
                pv_axpy(level, p, &v, &mut got);
                let wb: Vec<u32> =
                    want.iter().map(|x| x.to_bits()).collect();
                let gb: Vec<u32> =
                    got.iter().map(|x| x.to_bits()).collect();
                assert_eq!(gb, wb, "level {} d {d}", level.name());
            }
        }
    }

    #[test]
    fn every_level_matches_scalar_pv_accum() {
        let mut r = SplitMix64::new(33);
        let norm4: Vec<f32> =
            (0..4).map(|_| r.uniform() as f32).collect();
        let norm16: Vec<f32> =
            (0..16).map(|_| r.uniform() as f32).collect();
        for d in [1usize, 3, 4, 5, 8, 11, 16] {
            let keys4: Vec<u8> =
                (0..5).map(|_| r.below(256) as u8).collect();
            let keys2: Vec<u16> =
                (0..5).map(|_| r.below(256) as u16).collect();
            let vtile4: Vec<f32> = (0..keys4.len() * 4 * d)
                .map(|_| r.normal() as f32)
                .collect();
            let vtile2: Vec<f32> = (0..keys2.len() * 2 * d)
                .map(|_| r.normal() as f32)
                .collect();
            let base: Vec<f32> =
                (0..d).map(|_| r.normal() as f32).collect();

            let mut want = base.clone();
            scalar::pv_accum4(&keys4, &norm4, &vtile4, d, &mut want);
            for level in available_levels() {
                let mut got = base.clone();
                pv_accum4(level, &keys4, &norm4, &vtile4, d, &mut got);
                let wb: Vec<u32> =
                    want.iter().map(|x| x.to_bits()).collect();
                let gb: Vec<u32> =
                    got.iter().map(|x| x.to_bits()).collect();
                assert_eq!(gb, wb,
                           "pv_accum4 level {} d {d}", level.name());
            }

            let mut want = base.clone();
            scalar::pv_accum2(&keys2, &norm16, &vtile2, d, &mut want,
                              4);
            for level in available_levels() {
                let mut got = base.clone();
                pv_accum2(level, &keys2, &norm16, &vtile2, d,
                          &mut got, 4);
                let wb: Vec<u32> =
                    want.iter().map(|x| x.to_bits()).collect();
                let gb: Vec<u32> =
                    got.iter().map(|x| x.to_bits()).collect();
                assert_eq!(gb, wb,
                           "pv_accum2 level {} d {d}", level.name());
            }
        }
    }

    #[test]
    fn every_level_matches_scalar_qk_strip() {
        let mut r = SplitMix64::new(77);
        // d sweep covers the scalar-only tail, full vectors, and
        // vector + tail combinations
        for d in [1usize, 2, 3, 4, 5, 7, 8, 9, 12, 16, 17] {
            let rows = 5usize;
            let q = hostile_lanes(d, 200 + d as u64);
            let k_tile = hostile_lanes(rows * d, 300 + d as u64);
            let scale = (r.normal() as f32).abs() + 0.25;
            let mut want = vec![0f32; rows];
            scalar::qk_strip(&q, &k_tile, d, scale, &mut want);
            for level in available_levels() {
                let mut got = vec![0f32; rows];
                qk_strip(level, &q, &k_tile, d, scale, &mut got);
                let wb: Vec<u32> =
                    want.iter().map(|x| x.to_bits()).collect();
                let gb: Vec<u32> =
                    got.iter().map(|x| x.to_bits()).collect();
                assert_eq!(gb, wb, "level {} d {d}", level.name());
            }
        }
    }

    #[test]
    fn level_names_parse_back() {
        for l in [Level::Scalar, Level::Sse2, Level::Avx2, Level::Neon] {
            assert_eq!(Level::parse(l.name()), Some(l));
        }
        assert_eq!(Level::parse(" AVX2 "), Some(Level::Avx2));
        assert_eq!(Level::parse("mmx"), None);
    }

    #[test]
    fn scalar_is_always_available_and_default_is_available() {
        let avail = available_levels();
        assert_eq!(avail[0], Level::Scalar);
        assert!(avail.contains(&default_level()));
    }
}
