//! Runtime mid-tread quantizer — the Rust mirror of the kernel spec in
//! `python/compile/kernels/ref.py` (keep the two in sync).
//!
//! Levels are uniform on [C, 0] *inclusive*: step = −C/(2^M − 1),
//! v_k = C + k·step. The row maximum (x = 0 after shift) is exactly
//! representable, which matters at M = 2. Codes are produced by
//! round-to-nearest with clamping; inputs below C saturate to code 0
//! (value exactly C).

/// Clamp bound shared with the Python side (ref.CLIP_EPS).
pub const CLIP_EPS: f32 = 1e-3;

/// An M-bit mid-tread quantizer over [C, 0].
#[derive(Clone, Copy, Debug)]
pub struct Quantizer {
    pub bits: u32,
    /// Clip threshold (negative, magnitude >= CLIP_EPS).
    pub c: f32,
    step: f32,
    inv_step: f32,
    max_code: u8,
}

impl Quantizer {
    pub fn new(bits: u32, c: f32) -> Self {
        assert!((1..=8).contains(&bits), "bits out of range");
        let c = c.min(-CLIP_EPS);
        let nlev = ((1u32 << bits) - 1) as f32;
        let step = -c / nlev;
        Self {
            bits,
            c,
            step,
            inv_step: 1.0 / step,
            max_code: ((1u32 << bits) - 1) as u8,
        }
    }

    #[inline]
    pub fn step(&self) -> f32 {
        self.step
    }

    /// The exact reciprocal step [`code`](Self::code) multiplies by.
    /// SIMD lanes must use *this* value (not `1.0 / step()` recomputed)
    /// to stay bit-identical with the scalar path.
    #[inline]
    pub fn inv_step(&self) -> f32 {
        self.inv_step
    }

    /// Largest code (`2^M − 1`) — the clamp bound of [`code`](Self::code).
    #[inline]
    pub fn max_code(&self) -> u8 {
        self.max_code
    }

    #[inline]
    pub fn n_levels(&self) -> usize {
        1usize << self.bits
    }

    /// Quantize a (max-shifted, <= 0) value to its code.
    /// Branchless round-to-nearest: add 0.5 and truncate (argument is
    /// clamped non-negative first), which the hot loops rely on — `round`
    /// is an order of magnitude slower than a float->int cast on x86.
    #[inline]
    pub fn code(&self, xs: f32) -> u8 {
        let k = (xs - self.c) * self.inv_step + 0.5;
        (k.max(0.0) as u32).min(self.max_code as u32) as u8
    }

    /// Reconstruction value of a code.
    #[inline]
    pub fn value(&self, code: u8) -> f32 {
        self.c + code as f32 * self.step
    }

    /// Quantize a whole row in place into a code buffer.
    pub fn encode_row(&self, xs: &[f32], out: &mut Vec<u8>) {
        out.clear();
        out.extend(xs.iter().map(|&x| self.code(x)));
    }

    /// Round-trip a value through quantization.
    #[inline]
    pub fn dequant(&self, xs: f32) -> f32 {
        self.value(self.code(xs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoints_are_exact() {
        for bits in [2u32, 3, 4] {
            let q = Quantizer::new(bits, -6.0);
            assert_eq!(q.code(0.0), ((1u32 << bits) - 1) as u8);
            assert_eq!(q.value(q.code(0.0)), 0.0);
            assert_eq!(q.code(-6.0), 0);
            assert_eq!(q.value(0), -6.0);
        }
    }

    #[test]
    fn saturates_below_clip() {
        let q = Quantizer::new(2, -4.0);
        assert_eq!(q.code(-100.0), 0);
        assert_eq!(q.dequant(-100.0), -4.0);
    }

    #[test]
    fn max_error_half_step_inside_range() {
        let q = Quantizer::new(3, -5.0);
        let half = q.step() / 2.0 + 1e-6;
        let mut x = -5.0f32;
        while x <= 0.0 {
            let err = (q.dequant(x) - x).abs();
            assert!(err <= half, "x={x} err={err} > {half}");
            x += 0.01;
        }
    }

    #[test]
    fn codes_are_monotonic() {
        let q = Quantizer::new(2, -8.0);
        let mut prev = 0u8;
        let mut x = -9.0f32;
        while x <= 0.0 {
            let c = q.code(x);
            assert!(c >= prev, "non-monotonic at {x}");
            prev = c;
            x += 0.05;
        }
    }

    #[test]
    fn degenerate_clip_is_clamped() {
        let q = Quantizer::new(2, 0.5); // nonsense input
        assert!(q.c <= -CLIP_EPS);
        assert!(q.step() > 0.0);
    }

    #[test]
    fn matches_python_spec_examples() {
        // Golden values mirrored from ref.quant_codes semantics:
        // bits=2, C=-3 -> levels {-3, -2, -1, 0}
        let q = Quantizer::new(2, -3.0);
        assert_eq!(q.code(-3.0), 0);
        assert_eq!(q.code(-2.4), 1);
        assert_eq!(q.code(-1.1), 2);
        assert_eq!(q.code(-0.4), 3);
        assert_eq!(q.value(1), -2.0);
    }
}
