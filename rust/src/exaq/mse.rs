//! The analytic distortion model of EXAQ (paper §3.1, Eqs. 1–14, Fig. 2).
//!
//! Inputs to the softmax exponent are modelled as Gaussian after
//! max-subtraction. Clipping at C < 0 and M-bit uniform quantization of
//! [C, 0] produce two error terms:
//!
//!   MSE_quant(C) = Δ²/12 ∫_C^0 e^{2x} f(x) dx        (Eq. 11)
//!   MSE_clip(C)  = ∫_{-∞}^C (e^C − e^x)² f(x) dx     (Eq. 2)
//!   Δ = −C / 2^M                                      (paper's mid-rise)
//!
//! # Reproduction note (soundness)
//!
//! The paper states f = N(μ, σ) and the Fig. 3 caption says the
//! validation simulation draws "1000 samples from a normal distribution
//! with mean 0". Taken literally (μ = 0, no shift), minimising Eq. 12
//! yields C*(σ=1, M=2) ≈ −1.46 — nowhere near Table 1's −3.51. The
//! published coefficients are only recovered when the samples are
//! max-subtracted first (as the softmax pipeline in §3 prescribes),
//! which shifts the effective mean to −E[max of n]·σ ≈ −3.24σ for
//! n = 1000. We therefore expose both variants:
//!
//! * [`MseModel::mean_zero`]  — the equations exactly as printed.
//! * [`MseModel::max_shifted`] — the protocol that reproduces Fig. 3 /
//!   Table 1 (mean = −E[max_n]·σ). This is the default used by the
//!   solver, the Fig. 3 bench and the Table 1 fit.
//!
//! The mismatch of the literal reading is recorded in EXPERIMENTS.md.
//!
//! Integrals are evaluated with panel-subdivided Gauss–Legendre; the
//! lower clip integral is truncated 12σ below the mean, where the
//! Gaussian mass (< 1e-32) is negligible against the bounded integrand.

use super::gauss::{normal_pdf, GaussLegendre};

/// E[max of n iid standard normals], by numeric integration of
/// x · n·φ(x)·Φ(x)^{n−1}. Used to derive the max-subtraction shift.
pub fn expected_max_std_normal(n: usize) -> f64 {
    assert!(n >= 1);
    let gl = GaussLegendre::new(64);
    // Φ via integral of φ from -12 (adequate for the range we integrate).
    let phi_cdf = |x: f64| -> f64 {
        0.5 * (1.0 + erf_approx(x / std::f64::consts::SQRT_2))
    };
    gl.integrate_panels(-8.0, 8.0, 32, |x| {
        let cdf = phi_cdf(x);
        x * n as f64 * normal_pdf(x, 1.0) * cdf.powi(n as i32 - 1)
    })
}

/// Abramowitz–Stegun 7.1.26 rational approximation of erf (|err| < 1.5e-7,
/// ample for the shift constant and pdf tails we need).
pub fn erf_approx(x: f64) -> f64 {
    const A: [f64; 5] = [
        0.254_829_592,
        -0.284_496_736,
        1.421_413_741,
        -1.453_152_027,
        1.061_405_429,
    ];
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.327_591_1 * x);
    let poly = t * (A[0] + t * (A[1] + t * (A[2] + t * (A[3] + t * A[4]))));
    sign * (1.0 - poly * (-x * x).exp())
}

/// Distortion model for a given sigma and bit-width.
pub struct MseModel {
    pub sigma: f64,
    pub bits: u32,
    /// Mean of the Gaussian input model (0 for the literal paper model;
    /// −E[max_n]·σ for the max-subtracted protocol).
    pub mu: f64,
    gl: GaussLegendre,
}

/// Sample count of the paper's Fig. 3 simulation (caption: 1000 samples).
pub const FIG3_N_SAMPLES: usize = 1000;

impl MseModel {
    /// Paper Eqs. 1–14 with f = N(mu, sigma).
    pub fn with_mean(sigma: f64, bits: u32, mu: f64) -> Self {
        assert!(sigma > 0.0, "sigma must be positive");
        assert!((1..=8).contains(&bits));
        Self { sigma, bits, mu, gl: GaussLegendre::new(48) }
    }

    /// The equations exactly as printed (μ = 0).
    pub fn mean_zero(sigma: f64, bits: u32) -> Self {
        Self::with_mean(sigma, bits, 0.0)
    }

    /// The max-subtracted protocol that reproduces Fig. 3 / Table 1:
    /// μ = −E[max of FIG3_N_SAMPLES]·σ ≈ −3.24σ.
    pub fn max_shifted(sigma: f64, bits: u32) -> Self {
        let shift = expected_max_std_normal(FIG3_N_SAMPLES);
        Self::with_mean(sigma, bits, -shift * sigma)
    }

    /// Quantization step for clip threshold C (paper: Δ = −C / 2^M).
    pub fn step(&self, c: f64) -> f64 {
        -c / (1u32 << self.bits) as f64
    }

    fn pdf(&self, x: f64) -> f64 {
        normal_pdf(x - self.mu, self.sigma)
    }

    /// Eq. 11: rounding error inside the kept range [C, 0].
    pub fn mse_quant(&self, c: f64) -> f64 {
        assert!(c < 0.0);
        let d = self.step(c);
        let integral = self.gl.integrate_panels(c, 0.0, 6, |x| {
            (2.0 * x).exp() * self.pdf(x)
        });
        d * d / 12.0 * integral
    }

    /// Eq. 2: saturation error below the clip threshold.
    pub fn mse_clip(&self, c: f64) -> f64 {
        assert!(c < 0.0);
        let lo = (self.mu - 12.0 * self.sigma).min(c);
        if lo >= c {
            return 0.0;
        }
        let ec = c.exp();
        self.gl.integrate_panels(lo, c, 8, |x| {
            let d = ec - x.exp();
            d * d * self.pdf(x)
        })
    }

    /// Eq. 12: total distortion at clip threshold C.
    pub fn mse(&self, c: f64) -> f64 {
        self.mse_quant(c) + self.mse_clip(c)
    }

    /// The (C, MSE_quant, MSE_clip, MSE_total) curve used by Fig. 2.
    pub fn curve(&self, c_lo: f64, c_hi: f64, n: usize) -> Vec<MsePoint> {
        assert!(c_lo < c_hi && c_hi < 0.0);
        (0..n)
            .map(|i| {
                let c = c_lo + (c_hi - c_lo) * i as f64 / (n - 1) as f64;
                let q = self.mse_quant(c);
                let cl = self.mse_clip(c);
                MsePoint { c, quant: q, clip: cl, total: q + cl }
            })
            .collect()
    }
}

/// One sample of the Fig. 2 distortion curve.
#[derive(Clone, Copy, Debug)]
pub struct MsePoint {
    pub c: f64,
    pub quant: f64,
    pub clip: f64,
    pub total: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expected_max_reference_values() {
        // Known values: E[max of 1] = 0; E[max of 2] = 1/sqrt(pi);
        // E[max of 1000] ≈ 3.2414.
        assert!(expected_max_std_normal(1).abs() < 1e-6);
        let m2 = expected_max_std_normal(2);
        assert!((m2 - 1.0 / std::f64::consts::PI.sqrt()).abs() < 1e-5,
                "{m2}");
        let m1000 = expected_max_std_normal(1000);
        assert!((m1000 - 3.2414).abs() < 0.01, "{m1000}");
    }

    #[test]
    fn erf_reference_values() {
        assert!(erf_approx(0.0).abs() < 1e-7);
        assert!((erf_approx(1.0) - 0.842_700_79).abs() < 2e-7);
        assert!((erf_approx(-2.0) + 0.995_322_27).abs() < 2e-7);
        assert!((erf_approx(5.0) - 1.0).abs() < 1e-7);
    }

    #[test]
    fn quant_error_grows_with_coarser_clip() {
        // A more negative C widens Δ, so the rounding term must grow.
        let m = MseModel::max_shifted(1.0, 2);
        assert!(m.mse_quant(-8.0) > m.mse_quant(-2.0));
    }

    #[test]
    fn clip_error_shrinks_with_more_negative_clip() {
        let m = MseModel::max_shifted(1.0, 2);
        assert!(m.mse_clip(-2.0) > m.mse_clip(-4.0));
        assert!(m.mse_clip(-4.0) > m.mse_clip(-8.0));
        // far below the distribution the clip error vanishes
        assert!(m.mse_clip(-16.0) < 1e-12);
    }

    #[test]
    fn more_bits_reduce_quant_error_fourfold() {
        // Δ halves per extra bit -> Δ²/12 term drops 4x at equal C.
        let c = -4.0;
        let m2 = MseModel::max_shifted(1.5, 2).mse_quant(c);
        let m3 = MseModel::max_shifted(1.5, 3).mse_quant(c);
        let ratio = m2 / m3;
        assert!((ratio - 4.0).abs() < 1e-9, "ratio {ratio}");
    }

    #[test]
    fn total_curve_has_interior_minimum() {
        let m = MseModel::max_shifted(2.0, 2);
        let pts = m.curve(-20.0, -0.5, 80);
        let (mut best_i, mut best) = (0usize, f64::INFINITY);
        for (i, p) in pts.iter().enumerate() {
            if p.total < best {
                best = p.total;
                best_i = i;
            }
        }
        assert!(best_i > 0 && best_i < pts.len() - 1,
                "minimum should be interior, got index {best_i}");
    }

    #[test]
    fn literal_mean_zero_model_disagrees_with_table1() {
        // The documented soundness finding: the equations as printed
        // (μ = 0) place the optimum far above Table 1's magnitude.
        let m = MseModel::mean_zero(1.0, 2);
        let shifted = MseModel::max_shifted(1.0, 2);
        // compare total at the paper's C* = -3.51 vs a mild clip:
        assert!(m.mse(-1.46) < m.mse(-3.51),
                "mean-zero model should prefer a mild clip");
        assert!(shifted.mse(-3.51) < shifted.mse(-1.46),
                "max-shifted model should prefer the paper's clip");
    }

    #[test]
    fn mse_matches_monte_carlo() {
        // Validate the analytic model against a direct simulation of the
        // max-subtracted quantize+clip pipeline with the paper's mid-rise
        // quantizer.
        use crate::util::rng::SplitMix64;
        let sigma = 1.5;
        let bits = 2u32;
        let c = -6.0_f64;
        let model = MseModel::max_shifted(sigma, bits);
        let analytic = model.mse(c);

        let mut rng = SplitMix64::new(123);
        let reps = 600;
        let n = FIG3_N_SAMPLES;
        let delta = -c / (1u32 << bits) as f64;
        let mut acc = 0.0;
        let mut count = 0usize;
        for _ in 0..reps {
            let xs: Vec<f64> =
                (0..n).map(|_| rng.normal() * sigma).collect();
            let mx = xs.iter().copied().fold(f64::NEG_INFINITY, f64::max);
            for &x0 in &xs {
                let x = x0 - mx;
                let xc = x.clamp(c, 0.0);
                let k = ((xc - c) / delta)
                    .floor()
                    .min((1 << bits) as f64 - 1.0);
                let q = c + (k + 0.5) * delta; // mid-rise reconstruction
                let d = q.exp() - x.exp();
                acc += d * d;
                count += 1;
            }
        }
        let mc = acc / count as f64;
        let rel = (analytic - mc).abs() / mc;
        // The analytic model linearises e^{x+ε} (Eq. 7) and idealises the
        // max-shift as a fixed mean move, so the tolerance is generous
        // but still meaningfully binding (order-of-magnitude + shape).
        assert!(rel < 0.5, "analytic {analytic} vs mc {mc} (rel {rel})");
    }
}
