//! Gauss–Legendre quadrature and the Gaussian density — the numeric
//! substrate of the analytic clipping model (paper §3.1).

/// Gaussian pdf with mean 0 and standard deviation `sigma`.
#[inline]
pub fn normal_pdf(x: f64, sigma: f64) -> f64 {
    let z = x / sigma;
    (-(z * z) / 2.0).exp() / (sigma * (2.0 * std::f64::consts::PI).sqrt())
}

/// Nodes and weights of the n-point Gauss–Legendre rule on [-1, 1],
/// computed by Newton iteration on the Legendre polynomial (standard
/// Golub-free construction; accurate to ~1e-15 for n <= 128).
pub fn legendre_nodes(n: usize) -> (Vec<f64>, Vec<f64>) {
    let mut xs = vec![0.0; n];
    let mut ws = vec![0.0; n];
    let m = n.div_ceil(2);
    for i in 0..m {
        // Chebyshev-like initial guess
        let mut x = (std::f64::consts::PI * (i as f64 + 0.75)
            / (n as f64 + 0.5))
            .cos();
        let mut dp;
        loop {
            // evaluate P_n(x) and P'_n(x) by recurrence
            let (mut p0, mut p1) = (1.0_f64, x);
            for k in 2..=n {
                let p2 = ((2 * k - 1) as f64 * x * p1
                    - (k - 1) as f64 * p0)
                    / k as f64;
                p0 = p1;
                p1 = p2;
            }
            dp = n as f64 * (x * p1 - p0) / (x * x - 1.0);
            let dx = p1 / dp;
            x -= dx;
            if dx.abs() < 1e-15 {
                break;
            }
        }
        xs[i] = -x;
        xs[n - 1 - i] = x;
        let w = 2.0 / ((1.0 - x * x) * dp * dp);
        ws[i] = w;
        ws[n - 1 - i] = w;
    }
    (xs, ws)
}

/// Fixed-order Gauss–Legendre integrator, reusable across many intervals.
pub struct GaussLegendre {
    nodes: Vec<f64>,
    weights: Vec<f64>,
}

impl GaussLegendre {
    pub fn new(n: usize) -> Self {
        let (nodes, weights) = legendre_nodes(n);
        Self { nodes, weights }
    }

    /// ∫_a^b f(x) dx.
    pub fn integrate(&self, a: f64, b: f64, f: impl Fn(f64) -> f64) -> f64 {
        if a >= b {
            return 0.0;
        }
        let c = 0.5 * (a + b);
        let h = 0.5 * (b - a);
        let mut acc = 0.0;
        for (x, w) in self.nodes.iter().zip(&self.weights) {
            acc += w * f(c + h * x);
        }
        acc * h
    }

    /// Panel-subdivided integration (for wide or peaked integrands).
    pub fn integrate_panels(
        &self,
        a: f64,
        b: f64,
        panels: usize,
        f: impl Fn(f64) -> f64,
    ) -> f64 {
        let step = (b - a) / panels as f64;
        (0..panels)
            .map(|i| {
                self.integrate(a + i as f64 * step,
                               a + (i + 1) as f64 * step, &f)
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn integrates_polynomials_exactly() {
        let gl = GaussLegendre::new(16);
        // 16-point rule is exact through degree 31
        let got = gl.integrate(0.0, 2.0, |x| 3.0 * x * x);
        assert!((got - 8.0).abs() < 1e-12, "{got}");
        let got = gl.integrate(-1.0, 3.0, |x| x.powi(5) - x);
        let want = (3.0f64.powi(6) - 1.0) / 6.0 - (9.0 - 1.0) / 2.0;
        assert!((got - want).abs() < 1e-10, "{got} vs {want}");
    }

    #[test]
    fn integrates_exp() {
        let gl = GaussLegendre::new(32);
        let got = gl.integrate(0.0, 1.0, f64::exp);
        assert!((got - (std::f64::consts::E - 1.0)).abs() < 1e-12);
    }

    #[test]
    fn gaussian_mass_is_one() {
        let gl = GaussLegendre::new(64);
        for sigma in [0.5, 1.0, 3.0] {
            let got = gl.integrate_panels(-12.0 * sigma, 12.0 * sigma, 8,
                                          |x| normal_pdf(x, sigma));
            assert!((got - 1.0).abs() < 1e-10, "sigma={sigma} got {got}");
        }
    }

    #[test]
    fn gaussian_second_moment() {
        let gl = GaussLegendre::new(64);
        let sigma = 2.5;
        let got = gl.integrate_panels(-30.0, 30.0, 16,
                                      |x| x * x * normal_pdf(x, sigma));
        assert!((got - sigma * sigma).abs() < 1e-8, "{got}");
    }
}
