//! Shared memory-footprint accounting for the attention-score paths.
//!
//! One home for the byte math that the cost model, the benches, and
//! the tests all quote, so the three never drift: the packed key
//! plane ([`packed_plane_bytes`]), the dense f32 probability plane
//! the two-step path materializes ([`dense_plane_bytes`]), and the
//! streaming path's peak score scratch ([`streaming_strip_bytes`]),
//! which is a constant — independent of `rows` and `len` — because
//! [`StreamingAttention`](super::stream::StreamingAttention) never
//! writes the dense plane at all.
//!
//! The tiling constants themselves stay owned by `exaq::plane`
//! (CONTRIBUTING.md: don't duplicate them); this module only derives
//! bytes from them. `plane` re-exports the two plane helpers so the
//! historical `exaq::plane::{packed,dense}_plane_bytes` paths keep
//! working.

use super::lut::lut_group;
use super::plane::{TILE_LANES, TILE_ROWS};

/// Bytes of packed-key storage for a `[rows × len]` plane at `bits`:
/// one byte per 4 codes at M = 2, one u16 per 2 codes at M = 3/4
/// (mirrors the `PackedCodes` layout the engine builds).
pub fn packed_plane_bytes(rows: usize, len: usize, bits: u32) -> usize {
    let group = lut_group(bits);
    let width = if bits <= 2 { 1 } else { 2 };
    rows * len.div_ceil(group) * width
}

/// Bytes of the f32 probability plane the two-step path materializes.
pub fn dense_plane_bytes(rows: usize, len: usize) -> usize {
    rows * len * std::mem::size_of::<f32>()
}

/// Peak f32 score storage on the streaming path: one
/// `TILE_ROWS × TILE_LANES` strip budget, independent of `rows` and
/// `len`. The kernel actually keeps a single `TILE_LANES`-wide row
/// strip per worker (`TILE_ROWS`× under this budget); the block
/// figure is the contract the bench asserts against.
pub fn streaming_strip_bytes() -> usize {
    TILE_ROWS * TILE_LANES * std::mem::size_of::<f32>()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plane_byte_math_is_pinned() {
        // 4 codes/byte at M = 2, 2 codes per u16 at M = 3/4
        assert_eq!(packed_plane_bytes(4, 64, 2), 4 * 16);
        assert_eq!(packed_plane_bytes(4, 64, 3), 4 * 32 * 2);
        assert_eq!(packed_plane_bytes(1, 5, 2), 2);
        assert_eq!(dense_plane_bytes(4, 64), 4 * 64 * 4);
    }

    #[test]
    fn streaming_strip_is_constant_and_beats_every_dense_plane() {
        assert_eq!(streaming_strip_bytes(), TILE_ROWS * TILE_LANES * 4);
        // the whole point: the strip does not grow with context
        for len in [TILE_LANES, 1024, 65_536] {
            assert!(streaming_strip_bytes()
                    <= dense_plane_bytes(TILE_ROWS, len));
        }
    }
}
