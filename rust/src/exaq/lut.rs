//! The paper's two lookup tables (§4, Fig. 5).
//!
//! * `LUT_exp`  — code -> exp(value(code)). 2^M entries (4 at M = 2).
//! * `LUT_sum`  — packed key of `group` consecutive codes -> the sum of
//!   their exponents. At M = 2 a byte holds 4 codes (group = 4, 256
//!   entries); at M = 3/4 a byte holds 2 codes (group = 2).
//!
//! Key layout matches `python/compile/kernels/ref.py::lut_sum_table`:
//! low code first — key = Σ_j code[j] << (bits · j).
//!
//! The packed key is not just an index: it is the paper's *storage
//! format* for quantized rows. Fig. 5's pipeline writes 2-bit codes
//! four-to-a-byte, and that byte — read back verbatim — addresses
//! LUT_sum, turning `group` accumulations into one load. The batched
//! kernel ([`crate::exaq::batched`]) keeps whole `[rows × len]` planes
//! in this form (`PackedCodes`): at M = 2 the code plane is len/4
//! bytes per row and the denominator loop streams the bytes straight
//! into [`LutSum::sum_keys`] with no per-group repacking. M = 3/4
//! rows carry one u16 key per two codes for the same zero-repack
//! property.
//!
//! [`LutSum::sum_keys`] is the single reduction used by both the
//! scalar path ([`crate::exaq::softmax::softmax_algo2`]) and the
//! batched kernel: its 4-accumulator tree fixes the f32 summation
//! order, which is what makes the two paths bit-identical.

use super::quant::Quantizer;

/// A stored LUT_sum key: `u8` when the packed byte is itself the key
/// (M ≤ 2, Fig. 5), `u16` for the two-codes-per-word planes (M = 3/4).
pub trait PackedKey: Copy + Default {
    /// Truncate a freshly packed key into the stored width.
    fn pack(raw: usize) -> Self;
    /// Widen back to a table index.
    fn index(self) -> usize;
}

impl PackedKey for u8 {
    #[inline(always)]
    fn pack(raw: usize) -> Self {
        raw as u8
    }
    #[inline(always)]
    fn index(self) -> usize {
        self as usize
    }
}

impl PackedKey for u16 {
    #[inline(always)]
    fn pack(raw: usize) -> Self {
        raw as u16
    }
    #[inline(always)]
    fn index(self) -> usize {
        self as usize
    }
}

/// LUT_exp: single-cycle exponent lookup (paper §4.1).
#[derive(Clone, Debug)]
pub struct LutExp {
    pub table: Vec<f32>,
    pub bits: u32,
}

impl LutExp {
    pub fn build(q: &Quantizer) -> Self {
        let table = (0..q.n_levels())
            .map(|k| q.value(k as u8).exp())
            .collect();
        Self { table, bits: q.bits }
    }

    #[inline]
    pub fn get(&self, code: u8) -> f32 {
        self.table[code as usize]
    }

    /// exp(C) — the contribution of a masked/saturated lane (code 0).
    #[inline]
    pub fn floor_value(&self) -> f32 {
        self.table[0]
    }
}

/// How many codes pack into one LUT_sum key at each bit-width (paper:
/// byte-keys -> 4 codes at 2 bits, 2 codes at 3/4 bits).
pub fn lut_group(bits: u32) -> usize {
    match bits {
        2 => 4,
        3 | 4 => 2,
        _ => 1,
    }
}

/// LUT_sum: packed multi-code accumulation table (paper §4.2).
#[derive(Clone, Debug)]
pub struct LutSum {
    pub table: Vec<f32>,
    pub bits: u32,
    pub group: usize,
}

impl LutSum {
    pub fn build(q: &Quantizer) -> Self {
        let bits = q.bits;
        let group = lut_group(bits);
        let n = q.n_levels();
        let size = n.pow(group as u32);
        let exp: Vec<f32> = (0..n).map(|k| q.value(k as u8).exp()).collect();
        let mut table = vec![0.0f32; size];
        for (key, slot) in table.iter_mut().enumerate() {
            let mut acc = 0.0f32;
            for j in 0..group {
                let digit = (key >> (bits as usize * j)) & (n - 1);
                acc += exp[digit];
            }
            *slot = acc;
        }
        Self { table, bits, group }
    }

    /// Pack `group` codes into a key (low code first).
    #[inline]
    pub fn pack(&self, codes: &[u8]) -> usize {
        debug_assert_eq!(codes.len(), self.group);
        let mut key = 0usize;
        for (j, &c) in codes.iter().enumerate() {
            key |= (c as usize) << (self.bits as usize * j);
        }
        key
    }

    #[inline]
    pub fn get(&self, key: usize) -> f32 {
        self.table[key]
    }

    /// Sum of exponents of a packed code group — one "cycle" instead of
    /// `group` accumulations (Fig. 5).
    #[inline]
    pub fn lookup(&self, codes: &[u8]) -> f32 {
        self.table[self.pack(codes)]
    }

    /// Denominator reduction over a row's key stream: Σ table[key].
    ///
    /// 4 independent accumulators break the float add dependency chain
    /// (the paper's "accumulation phase" is latency-bound), combined in
    /// a fixed tree `((a0+a1)+(a2+a3))+tail`. Every caller — scalar
    /// `softmax_algo2` and the batched `BatchSoftmax` plane kernel —
    /// funnels through this one function so the f32 summation order,
    /// and therefore the result, is bit-identical across paths.
    #[inline]
    pub fn sum_keys<K: PackedKey>(&self, keys: &[K]) -> f32 {
        let t = &self.table[..];
        let (mut a0, mut a1, mut a2, mut a3) =
            (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        let mut chunks = keys.chunks_exact(4);
        for ch in chunks.by_ref() {
            a0 += t[ch[0].index()];
            a1 += t[ch[1].index()];
            a2 += t[ch[2].index()];
            a3 += t[ch[3].index()];
        }
        let mut tail = 0.0f32;
        for &k in chunks.remainder() {
            tail += t[k.index()];
        }
        ((a0 + a1) + (a2 + a3)) + tail
    }
}

/// Streaming form of [`LutSum::sum_keys`]: feed a row's key stream in
/// arbitrarily sized slices (the streaming attention kernel feeds one
/// KV tile at a time) and obtain the **bit-identical** result of a
/// single `sum_keys` call over the concatenation.
///
/// The trick is that the fixed tree only depends on each key's
/// position in the whole stream, not on feed boundaries: complete
/// 4-chunks go to the same `a0..a3` accumulators in the same order, so
/// the stream buffers at most 3 looked-up values until a chunk
/// completes, and `finish` folds the final partial chunk as the
/// sequential `tail` — exactly `sum_keys`' remainder handling.
#[derive(Clone, Debug, Default)]
pub struct KeySumStream {
    a: [f32; 4],
    buf: [f32; 4],
    pending: usize,
}

impl KeySumStream {
    pub fn new() -> Self {
        Self::default()
    }

    /// Absorb the next `keys` of the stream (any length, including 0).
    #[inline]
    pub fn feed<K: PackedKey>(&mut self, lut: &LutSum, keys: &[K]) {
        let t = &lut.table[..];
        let mut keys = keys;
        if self.pending > 0 {
            let take = (4 - self.pending).min(keys.len());
            for &k in &keys[..take] {
                self.buf[self.pending] = t[k.index()];
                self.pending += 1;
            }
            keys = &keys[take..];
            if self.pending == 4 {
                self.a[0] += self.buf[0];
                self.a[1] += self.buf[1];
                self.a[2] += self.buf[2];
                self.a[3] += self.buf[3];
                self.pending = 0;
            }
        }
        let mut chunks = keys.chunks_exact(4);
        for ch in chunks.by_ref() {
            self.a[0] += t[ch[0].index()];
            self.a[1] += t[ch[1].index()];
            self.a[2] += t[ch[2].index()];
            self.a[3] += t[ch[3].index()];
        }
        for &k in chunks.remainder() {
            self.buf[self.pending] = t[k.index()];
            self.pending += 1;
        }
    }

    /// Combine: `((a0+a1)+(a2+a3)) + tail`, as in `sum_keys`.
    #[inline]
    pub fn finish(self) -> f32 {
        let mut tail = 0.0f32;
        for &v in &self.buf[..self.pending] {
            tail += v;
        }
        ((self.a[0] + self.a[1]) + (self.a[2] + self.a[3])) + tail
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lut_exp_matches_direct_exp() {
        let q = Quantizer::new(2, -3.0);
        let lut = LutExp::build(&q);
        assert_eq!(lut.table.len(), 4);
        for k in 0..4u8 {
            let want = q.value(k).exp();
            assert!((lut.get(k) - want).abs() < 1e-7);
        }
        assert!((lut.floor_value() - (-3.0f32).exp()).abs() < 1e-7);
    }

    #[test]
    fn lut_sum_sizes() {
        assert_eq!(LutSum::build(&Quantizer::new(2, -4.0)).table.len(), 256);
        assert_eq!(LutSum::build(&Quantizer::new(3, -4.0)).table.len(), 64);
        assert_eq!(LutSum::build(&Quantizer::new(4, -4.0)).table.len(), 256);
    }

    #[test]
    fn lut_sum_equals_sum_of_lut_exp() {
        // The Fig. 5 identity: LUT_sum[pack(c0..c3)] == Σ LUT_exp[ci].
        for bits in [2u32, 3, 4] {
            let q = Quantizer::new(bits, -5.5);
            let le = LutExp::build(&q);
            let ls = LutSum::build(&q);
            let n = q.n_levels();
            // exhaustive over all keys
            for key in 0..ls.table.len() {
                let mut want = 0.0f32;
                for j in 0..ls.group {
                    let digit = ((key >> (bits as usize * j)) & (n - 1)) as u8;
                    want += le.get(digit);
                }
                assert!((ls.get(key) - want).abs() < 1e-6,
                        "bits={bits} key={key}");
            }
        }
    }

    #[test]
    fn sum_keys_matches_sequential_sum_and_is_width_invariant() {
        for bits in [2u32, 3, 4] {
            let q = Quantizer::new(bits, -5.0);
            let ls = LutSum::build(&q);
            let nkeys = ls.table.len();
            // key streams of awkward lengths incl. the unroll remainder
            for len in [0usize, 1, 3, 4, 5, 7, 8, 41] {
                let keys8: Vec<u8> =
                    (0..len).map(|i| ((i * 37 + 11) % nkeys) as u8).collect();
                let keys16: Vec<u16> =
                    keys8.iter().map(|&k| k as u16).collect();
                let got8 = ls.sum_keys(&keys8);
                let got16 = ls.sum_keys(&keys16);
                // identical keys at different storage widths must agree
                // bit-for-bit (the batched kernel relies on this)
                assert_eq!(got8.to_bits(), got16.to_bits(),
                           "bits={bits} len={len}");
                let want: f64 = keys8.iter()
                    .map(|&k| ls.get(k as usize) as f64)
                    .sum();
                assert!((got8 as f64 - want).abs() < 1e-4 * want.max(1.0),
                        "bits={bits} len={len}: {got8} vs {want}");
            }
        }
    }

    #[test]
    fn key_sum_stream_is_bit_identical_for_any_feed_split() {
        // The streaming attention kernel feeds tile-sized key slices;
        // whatever the slice sizes, the fold must equal one sum_keys
        // call over the whole row, bit for bit, at both key widths.
        for bits in [2u32, 3, 4] {
            let q = Quantizer::new(bits, -5.0);
            let ls = LutSum::build(&q);
            let nkeys = ls.table.len();
            for len in [0usize, 1, 3, 4, 5, 7, 8, 13, 41, 96] {
                let keys8: Vec<u8> =
                    (0..len).map(|i| ((i * 37 + 11) % nkeys) as u8).collect();
                let keys16: Vec<u16> =
                    keys8.iter().map(|&k| k as u16).collect();
                let want = ls.sum_keys(&keys8).to_bits();
                // hostile feed patterns: one-shot, singletons, tiles of
                // 3/4/5/32, and a lopsided head+tail split
                let mut plans: Vec<Vec<usize>> = vec![vec![len]];
                for chunk in [1usize, 2, 3, 4, 5, 32] {
                    let mut plan = Vec::new();
                    let mut left = len;
                    while left > 0 {
                        let take = chunk.min(left);
                        plan.push(take);
                        left -= take;
                    }
                    plans.push(plan);
                }
                if len > 1 {
                    plans.push(vec![len - 1, 1]);
                }
                for plan in plans {
                    let mut s8 = KeySumStream::new();
                    let mut s16 = KeySumStream::new();
                    let mut at = 0usize;
                    for take in &plan {
                        s8.feed(&ls, &keys8[at..at + take]);
                        s16.feed(&ls, &keys16[at..at + take]);
                        at += take;
                    }
                    assert_eq!(at, len);
                    assert_eq!(s8.finish().to_bits(), want,
                               "bits={bits} len={len} plan={plan:?}");
                    assert_eq!(s16.finish().to_bits(), want,
                               "bits={bits} len={len} plan={plan:?}");
                }
            }
        }
    }

    #[test]
    fn pack_matches_paper_fig5_example() {
        // Fig. 5: codes [0,3,0,3] at 2 bits -> key byte 0b11001100 = 204
        // with low-code-first layout: 0 | 3<<2 | 0<<4 | 3<<6 = 12 + 192.
        let q = Quantizer::new(2, -4.0);
        let ls = LutSum::build(&q);
        assert_eq!(ls.pack(&[0, 3, 0, 3]), 0b1100_1100);
        let want = 2.0 * q.value(0).exp() + 2.0 * q.value(3).exp();
        assert!((ls.lookup(&[0, 3, 0, 3]) - want).abs() < 1e-6);
    }
}
