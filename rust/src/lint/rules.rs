//! The determinism rules and their token-level matchers.
//!
//! Every rule is named, scoped to the paths where its invariant
//! matters, and skips test code (`#[cfg(test)]` / `#[test]` items and
//! everything under `rust/tests/`). A violation can be suppressed with
//! a `// lint:allow(<rule>): <reason>` comment on the same line or on
//! the line directly above; the reason is mandatory. CONTRIBUTING.md
//! documents each rule's rationale.

use std::fmt;

use super::lexer::{LexedFile, Spanned, Tok};

/// One rule violation, spanned to the offending token.
#[derive(Clone, Debug)]
pub struct Violation {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub col: usize,
    pub message: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}:{}: {}: {}", self.file, self.line, self.col,
               self.rule, self.message)
    }
}

/// Rule registry entry (drives `repro lint --list` and the
/// unknown-rule check on `lint:allow` comments).
pub struct RuleInfo {
    pub name: &'static str,
    pub summary: &'static str,
}

pub const CLOCK: &str = "clock-discipline";
pub const RNG: &str = "seeded-rng";
pub const ITER: &str = "deterministic-iteration";
pub const PANIC: &str = "no-panic-hot-path";
pub const FLOAT: &str = "float-reduction-discipline";
pub const THREAD: &str = "thread-discipline";
pub const ALLOW_SYNTAX: &str = "lint-allow-syntax";

pub const RULES: &[RuleInfo] = &[
    RuleInfo {
        name: CLOCK,
        summary: "no raw Instant/SystemTime outside util::clock \
                  (host time must flow through Clock or Stopwatch)",
    },
    RuleInfo {
        name: RNG,
        summary: "no ambient randomness (thread_rng, rand::random, \
                  OsRng, ...) outside util::rng — SplitMix64 only",
    },
    RuleInfo {
        name: ITER,
        summary: "no HashMap/HashSet in coordinator/, runtime/ or \
                  model/ — iteration order must be deterministic",
    },
    RuleInfo {
        name: PANIC,
        summary: "no unwrap()/expect()/panic-family macros on the \
                  decode-tick and kernel hot paths — use util::error",
    },
    RuleInfo {
        name: FLOAT,
        summary: "f32 reductions in the softmax kernels must route \
                  through LutSum::sum_keys (no .sum()/.fold()/manual \
                  accumulators that could reassociate)",
    },
    RuleInfo {
        name: THREAD,
        summary: "raw std::thread::spawn/scope only in util::pool; \
                  cfg(target_arch) intrinsics only in exaq::simd — \
                  both keep the bit-identical fallback story auditable",
    },
    RuleInfo {
        name: ALLOW_SYNTAX,
        summary: "lint:allow comments must name a known rule and give \
                  a reason",
    },
];

/// Files exempt from [`CLOCK`]: the one sanctioned wall-time module.
const CLOCK_HOME: &str = "rust/src/util/clock.rs";
/// Files exempt from [`RNG`]: the seeded-RNG home itself.
const RNG_HOME: &str = "rust/src/util/rng.rs";

/// Path prefixes where [`ITER`] applies (serving-visible state).
const ITER_SCOPE: &[&str] = &[
    "rust/src/coordinator/",
    "rust/src/runtime/",
    "rust/src/model/",
];

/// Exact files forming the decode-tick / kernel hot path for [`PANIC`].
const HOT_PATHS: &[&str] = &[
    "rust/src/coordinator/batcher.rs",
    "rust/src/coordinator/replica.rs",
    "rust/src/coordinator/router.rs",
    "rust/src/runtime/sim.rs",
    "rust/src/runtime/engine.rs",
    "rust/src/model/sampling.rs",
    "rust/src/exaq/softmax.rs",
    "rust/src/exaq/batched.rs",
    "rust/src/exaq/plane.rs",
    "rust/src/exaq/stream.rs",
    "rust/src/exaq/simd.rs",
    "rust/src/exaq/lut.rs",
    "rust/src/util/pool.rs",
];

/// Files where [`FLOAT`] applies. `exaq/lut.rs` is deliberately NOT
/// here: `LutSum::sum_keys` (and the table builds feeding it) is the
/// blessed reduction the rule funnels everyone else into.
const FLOAT_SCOPE: &[&str] = &[
    "rust/src/exaq/batched.rs",
    "rust/src/exaq/plane.rs",
    "rust/src/exaq/simd.rs",
    "rust/src/exaq/softmax.rs",
    "rust/src/exaq/stream.rs",
];

/// File exempt from [`THREAD`]'s spawn/scope check: the scoped pool.
const POOL_HOME: &str = "rust/src/util/pool.rs";
/// File exempt from [`THREAD`]'s intrinsics check: the SIMD dispatch.
const SIMD_HOME: &str = "rust/src/exaq/simd.rs";

/// Run every rule over one lexed file; returns surviving violations
/// plus how many candidates `lint:allow` comments suppressed.
pub fn check_file(rel: &str, lexed: &LexedFile)
                  -> (Vec<Violation>, usize) {
    let mut candidates = Vec::new();
    clock_discipline(rel, &lexed.tokens, &mut candidates);
    seeded_rng(rel, &lexed.tokens, &mut candidates);
    deterministic_iteration(rel, &lexed.tokens, &mut candidates);
    no_panic_hot_path(rel, &lexed.tokens, &mut candidates);
    float_reduction(rel, &lexed.tokens, &mut candidates);
    thread_discipline(rel, &lexed.tokens, &mut candidates);

    let mut suppressed = 0usize;
    let mut out: Vec<Violation> = Vec::new();
    for v in candidates {
        let allowed = lexed.allows.iter().any(|a| {
            a.rule == v.rule
                && (a.line == v.line
                    || lexed.next_code_line(a.line) == Some(v.line))
        });
        if allowed {
            suppressed += 1;
        } else {
            out.push(v);
        }
    }

    // allow-comment hygiene (not itself suppressible)
    for (line, msg) in &lexed.bad_allows {
        out.push(Violation {
            rule: ALLOW_SYNTAX,
            file: rel.to_string(),
            line: *line,
            col: 1,
            message: msg.clone(),
        });
    }
    for a in &lexed.allows {
        if !RULES.iter().any(|r| r.name == a.rule) {
            out.push(Violation {
                rule: ALLOW_SYNTAX,
                file: rel.to_string(),
                line: a.line,
                col: 1,
                message: format!("lint:allow names unknown rule \
                                  '{}'", a.rule),
            });
        }
    }

    out.sort_by(|a, b| (a.line, a.col).cmp(&(b.line, b.col)));
    (out, suppressed)
}

fn violation(rule: &'static str, rel: &str, t: &Spanned,
             message: String) -> Violation {
    Violation {
        rule,
        file: rel.to_string(),
        line: t.line,
        col: t.col,
        message,
    }
}

fn ident<'a>(t: &'a Spanned) -> Option<&'a str> {
    match &t.tok {
        Tok::Ident(s) => Some(s.as_str()),
        _ => None,
    }
}

fn is_punct(t: &Spanned, c: char) -> bool {
    t.tok == Tok::Punct(c)
}

fn clock_discipline(rel: &str, toks: &[Spanned],
                    out: &mut Vec<Violation>) {
    if rel == CLOCK_HOME {
        return;
    }
    for t in toks.iter().filter(|t| !t.in_test) {
        if let Some(name) = ident(t) {
            if name == "Instant" || name == "SystemTime" {
                out.push(violation(CLOCK, rel, t, format!(
                    "raw `{name}` outside util::clock — route host \
                     timing through util::clock::Stopwatch (benches, \
                     compile timing) or the Clock trait (serving)")));
            }
        }
    }
}

fn seeded_rng(rel: &str, toks: &[Spanned], out: &mut Vec<Violation>) {
    if rel == RNG_HOME {
        return;
    }
    const AMBIENT: &[&str] = &[
        "thread_rng", "ThreadRng", "OsRng", "from_entropy",
        "RandomState", "getrandom", "StdRng", "SmallRng",
    ];
    for (i, t) in toks.iter().enumerate() {
        if t.in_test {
            continue;
        }
        let Some(name) = ident(t) else { continue };
        if AMBIENT.contains(&name) {
            out.push(violation(RNG, rel, t, format!(
                "ambient randomness `{name}` — every random stream \
                 must come from a seeded util::rng::SplitMix64")));
        }
        // `rand::random` (the ident pair around a `::`)
        if name == "rand"
            && toks.get(i + 1).is_some_and(|t| is_punct(t, ':'))
            && toks.get(i + 2).is_some_and(|t| is_punct(t, ':'))
            && toks.get(i + 3).and_then(ident) == Some("random")
        {
            out.push(violation(RNG, rel, t, "`rand::random` draws \
                from an ambient RNG — use a seeded \
                util::rng::SplitMix64".to_string()));
        }
    }
}

fn deterministic_iteration(rel: &str, toks: &[Spanned],
                           out: &mut Vec<Violation>) {
    if !ITER_SCOPE.iter().any(|p| rel.starts_with(p)) {
        return;
    }
    for t in toks.iter().filter(|t| !t.in_test) {
        if let Some(name) = ident(t) {
            if name == "HashMap" || name == "HashSet" {
                out.push(violation(ITER, rel, t, format!(
                    "`{name}` on a serving-visible path — iteration \
                     order is nondeterministic; use BTreeMap/BTreeSet \
                     or explicitly sorted iteration")));
            }
        }
    }
}

fn no_panic_hot_path(rel: &str, toks: &[Spanned],
                     out: &mut Vec<Violation>) {
    if !HOT_PATHS.contains(&rel) {
        return;
    }
    const MACROS: &[&str] =
        &["panic", "unreachable", "todo", "unimplemented"];
    for (i, t) in toks.iter().enumerate() {
        if t.in_test {
            continue;
        }
        let Some(name) = ident(t) else { continue };
        let method_call = i > 0
            && is_punct(&toks[i - 1], '.')
            && toks.get(i + 1).is_some_and(|t| is_punct(t, '('));
        if method_call && (name == "unwrap" || name == "expect") {
            out.push(violation(PANIC, rel, t, format!(
                "`.{name}()` on the decode/kernel hot path — convert \
                 to a util::error Result (`?`, ok_or_else, let-else)")));
        }
        if MACROS.contains(&name)
            && toks.get(i + 1).is_some_and(|t| is_punct(t, '!'))
        {
            out.push(violation(PANIC, rel, t, format!(
                "`{name}!` on the decode/kernel hot path — return a \
                 util::error Result instead of aborting the tick")));
        }
    }
}

fn float_reduction(rel: &str, toks: &[Spanned],
                   out: &mut Vec<Violation>) {
    if !FLOAT_SCOPE.contains(&rel) {
        return;
    }
    const ACCUMULATORS: &[&str] = &["sum", "acc", "total"];
    for (i, t) in toks.iter().enumerate() {
        if t.in_test {
            continue;
        }
        let Some(name) = ident(t) else { continue };
        // iterator reductions: `.sum(` / `.sum::<` / `.fold(` / ...
        let is_method = i > 0 && is_punct(&toks[i - 1], '.');
        let called = toks.get(i + 1).is_some_and(|n| {
            is_punct(n, '(') || is_punct(n, ':')
        });
        if is_method
            && called
            && matches!(name, "sum" | "fold" | "product")
        {
            out.push(violation(FLOAT, rel, t, format!(
                "iterator `.{name}()` in a softmax kernel — packed-\
                 code reductions must go through LutSum::sum_keys so \
                 scalar and batched paths stay bit-identical")));
        }
        // manual accumulation: `sum += ...` on a well-known
        // accumulator name
        if ACCUMULATORS.contains(&name)
            && toks.get(i + 1).is_some_and(|n| is_punct(n, '+'))
            && toks.get(i + 2).is_some_and(|n| is_punct(n, '='))
        {
            out.push(violation(FLOAT, rel, t, format!(
                "manual accumulation `{name} +=` in a softmax kernel \
                 — route the reduction through LutSum::sum_keys (or \
                 lint:allow with the numerical argument)")));
        }
    }
}

fn thread_discipline(rel: &str, toks: &[Spanned],
                     out: &mut Vec<Violation>) {
    for (i, t) in toks.iter().enumerate() {
        if t.in_test {
            continue;
        }
        let Some(name) = ident(t) else { continue };
        // `thread::spawn` / `thread::scope` (the ident pair around
        // `::`) — `thread::sleep` in util::clock stays legal.
        if rel != POOL_HOME
            && name == "thread"
            && toks.get(i + 1).is_some_and(|t| is_punct(t, ':'))
            && toks.get(i + 2).is_some_and(|t| is_punct(t, ':'))
            && matches!(toks.get(i + 3).and_then(ident),
                        Some("spawn" | "scope"))
        {
            out.push(violation(THREAD, rel, t, "raw \
                `std::thread` spawn/scope outside util::pool — \
                parallel work goes through the scoped pool so chunk \
                assignment (and therefore output) stays deterministic"
                .to_string()));
        }
        // arch-specific intrinsics: `cfg(target_arch = ...)` gates and
        // runtime feature probes belong to the simd dispatch module.
        if rel != SIMD_HOME
            && (name == "target_arch"
                || name == "is_x86_feature_detected")
        {
            out.push(violation(THREAD, rel, t, format!(
                "`{name}` outside exaq::simd — arch-specific lanes \
                 live behind the simd::Level dispatch next to the \
                 scalar reference they are tested against")));
        }
    }
}
