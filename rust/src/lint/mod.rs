//! `repro lint` — the repo-specific determinism lint pass.
//!
//! The repo's headline claims are bit-exactness claims: the batched
//! plane kernel is bit-identical to scalar `softmax_algo2`, and the
//! serving sim asserts deterministic latency percentiles over
//! thousands of virtual-clock requests. Nothing in rustc guards those
//! invariants — one `Instant::now()`, one ambient RNG, one `HashMap`
//! iteration or one reassociated f32 reduction silently breaks them.
//! This pass turns the invariants into machine-checked, named rules
//! with spans (see [`rules::RULES`] and CONTRIBUTING.md).
//!
//! The image vendors no crates, so instead of a `syn` AST walk the
//! rules run over an in-tree token stream ([`lexer`]) — the same
//! dependency-free trade as `util::json`. Diagnostics are emitted
//! human-readable (`file:line:col: rule: message`) and, on request,
//! as machine-readable JSON through [`crate::util::json`].
//!
//! Exit-code contract of the `repro lint` subcommand:
//! 0 = clean, 1 = violations found, 2 = internal error.

pub mod lexer;
pub mod rules;

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

use crate::util::error::{anyhow, Context, Result};
use crate::util::json::Json;

pub use rules::{Violation, RULES};

/// Directories scanned below the repo root, in deterministic order.
const SCAN_DIRS: &[&str] =
    &["rust/src", "rust/tests", "benches", "examples"];

/// Directories whose files are wholly test code: every rule skips
/// them, exactly like `#[cfg(test)]` items.
const TEST_DIRS: &[&str] = &["rust/tests"];

/// Result of linting one source string or a whole tree.
pub struct Report {
    /// Files scanned (0 for single-source runs).
    pub files: usize,
    pub violations: Vec<Violation>,
    /// Candidates silenced by `lint:allow` comments.
    pub suppressed: usize,
}

impl Report {
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty()
    }

    /// Machine-readable form of the report.
    pub fn to_json(&self, root: &str) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("tool".to_string(),
                   Json::Str("repro-lint".to_string()));
        obj.insert("root".to_string(), Json::Str(root.to_string()));
        obj.insert("files".to_string(), Json::Num(self.files as f64));
        obj.insert("suppressed".to_string(),
                   Json::Num(self.suppressed as f64));
        let vs = self
            .violations
            .iter()
            .map(|v| {
                let mut m = BTreeMap::new();
                m.insert("rule".to_string(),
                         Json::Str(v.rule.to_string()));
                m.insert("file".to_string(),
                         Json::Str(v.file.clone()));
                m.insert("line".to_string(), Json::Num(v.line as f64));
                m.insert("col".to_string(), Json::Num(v.col as f64));
                m.insert("message".to_string(),
                         Json::Str(v.message.clone()));
                Json::Obj(m)
            })
            .collect();
        obj.insert("violations".to_string(), Json::Arr(vs));
        Json::Obj(obj)
    }
}

/// Lint one source string as if it lived at repo-relative path `rel`
/// (forward slashes). This is the fixture-test entry point; rule
/// scoping (hot paths, exempt modules, test directories) is driven
/// entirely by `rel`.
pub fn lint_source(rel: &str, src: &str) -> Report {
    let rel = rel.replace('\\', "/");
    let mut lexed = lexer::lex(src);
    let in_test_dir = TEST_DIRS
        .iter()
        .any(|d| rel.starts_with(&format!("{d}/")));
    if in_test_dir {
        for t in &mut lexed.tokens {
            t.in_test = true;
        }
    }
    let (violations, suppressed) = rules::check_file(&rel, &lexed);
    Report { files: 0, violations, suppressed }
}

/// Lint the whole tree under `root` (the repo checkout). Files are
/// visited in sorted path order so output and JSON are stable.
pub fn run_tree(root: &Path) -> Result<Report> {
    if !root.join("rust/src").is_dir() {
        return Err(anyhow!(
            "{} does not look like the repo root (no rust/src)",
            root.display()));
    }
    let mut files: Vec<PathBuf> = Vec::new();
    for d in SCAN_DIRS {
        let dir = root.join(d);
        if dir.is_dir() {
            collect_rs(&dir, &mut files)?;
        }
    }
    files.sort();

    let mut violations = Vec::new();
    let mut suppressed = 0usize;
    for f in &files {
        let src = fs::read_to_string(f)
            .with_context(|| format!("reading {}", f.display()))?;
        let rel = f
            .strip_prefix(root)
            .unwrap_or(f)
            .to_string_lossy()
            .replace('\\', "/");
        let r = lint_source(&rel, &src);
        violations.extend(r.violations);
        suppressed += r.suppressed;
    }
    Ok(Report { files: files.len(), violations, suppressed })
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let entries = fs::read_dir(dir)
        .with_context(|| format!("scanning {}", dir.display()))?;
    for entry in entries {
        let entry = entry
            .with_context(|| format!("reading {}", dir.display()))?;
        let p = entry.path();
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_json_round_trips_through_util_json() {
        let r = lint_source(
            "rust/src/runtime/fake.rs",
            "use std::collections::HashMap;\n",
        );
        assert_eq!(r.violations.len(), 1);
        let j = r.to_json(".");
        let txt = j.to_string_pretty();
        let back = Json::parse(&txt).expect("valid json");
        let vs = back.get("violations").and_then(Json::as_arr)
            .expect("violations array");
        assert_eq!(vs.len(), 1);
        assert_eq!(vs[0].get("rule").and_then(Json::as_str),
                   Some("deterministic-iteration"));
        assert_eq!(vs[0].get("line").and_then(Json::as_f64), Some(1.0));
    }

    #[test]
    fn test_dir_files_are_exempt() {
        let src = "use std::collections::HashMap;\n\
                   fn f() { x.unwrap(); }\n";
        let r = lint_source("rust/tests/some_integration.rs", src);
        assert!(r.is_clean(), "{:?}", r.violations);
    }

    #[test]
    fn run_tree_rejects_non_repo_roots() {
        let err = run_tree(Path::new("/definitely/not/a/repo"));
        assert!(err.is_err());
    }
}
