//! A small Rust lexer for the lint pass.
//!
//! The build image vendors no crates, so the determinism lint cannot
//! link `syn`; instead the rules run over this hand-rolled token
//! stream, the same trade the repo already makes for JSON
//! (`util::json`) and errors (`util::error`). The lexer understands
//! exactly as much Rust as the rules need to avoid false positives:
//!
//! * line / nested block comments (dropped, except `lint:allow`),
//! * string, raw-string, byte-string, char and byte-char literals
//!   (collapsed into opaque [`Tok::Literal`] tokens so braces or rule
//!   keywords inside them never reach a rule),
//! * lifetimes vs char literals,
//! * identifiers and single-character punctuation with 1-based
//!   line/column spans,
//! * `#[test]` / `#[cfg(test)]` regions, whose tokens are flagged
//!   [`Spanned::in_test`] (rules skip test code),
//! * `// lint:allow(<rule>): <reason>` suppression comments.

/// One lexical token. Operators are split into single-character
/// [`Tok::Punct`] tokens; rules match multi-character operators by
/// token adjacency (`+` followed by `=` can only be `+=` in valid
/// Rust).
#[derive(Clone, Debug, PartialEq)]
pub enum Tok {
    /// Identifier or keyword.
    Ident(String),
    /// Single punctuation character.
    Punct(char),
    /// String / char / byte / numeric literal (content dropped).
    Literal,
    /// A lifetime such as `'a` (distinct from char literals).
    Lifetime,
}

/// A token with its 1-based source position.
#[derive(Clone, Debug)]
pub struct Spanned {
    pub tok: Tok,
    pub line: usize,
    pub col: usize,
    /// True inside `#[test]` / `#[cfg(test)]` items (and for every
    /// token of files under `rust/tests/`).
    pub in_test: bool,
}

/// A well-formed `// lint:allow(<rule>): <reason>` comment. It
/// suppresses matching violations on its own line (trailing form) or
/// on the next line that carries code (standalone form).
#[derive(Clone, Debug)]
pub struct Allow {
    pub rule: String,
    pub reason: String,
    pub line: usize,
}

/// The lexed view of one source file.
pub struct LexedFile {
    pub tokens: Vec<Spanned>,
    pub allows: Vec<Allow>,
    /// Malformed `lint:allow` comments: (line, what is wrong).
    pub bad_allows: Vec<(usize, String)>,
    /// Sorted, deduplicated lines that carry at least one token; used
    /// to resolve which line a standalone allow-comment targets.
    pub code_lines: Vec<usize>,
}

impl LexedFile {
    /// The first line after `line` that carries code, if any.
    pub fn next_code_line(&self, line: usize) -> Option<usize> {
        let i = self.code_lines.partition_point(|&l| l <= line);
        self.code_lines.get(i).copied()
    }
}

struct Scan {
    chars: Vec<char>,
    i: usize,
    line: usize,
    col: usize,
}

impl Scan {
    fn peek(&self) -> Option<char> {
        self.chars.get(self.i).copied()
    }

    fn peek_at(&self, ahead: usize) -> Option<char> {
        self.chars.get(self.i + ahead).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.chars.get(self.i).copied()?;
        self.i += 1;
        if c == '\n' {
            self.line += 1;
            self.col = 1;
        } else {
            self.col += 1;
        }
        Some(c)
    }
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Lex a whole source file. Never fails: unterminated literals simply
/// run to end of file, which is good enough for linting a tree that
/// rustc also compiles.
pub fn lex(src: &str) -> LexedFile {
    let mut s = Scan {
        chars: src.chars().collect(),
        i: 0,
        line: 1,
        col: 1,
    };
    let mut tokens: Vec<Spanned> = Vec::new();
    let mut allows = Vec::new();
    let mut bad_allows = Vec::new();

    while let Some(c) = s.peek() {
        let (line, col) = (s.line, s.col);
        if c.is_whitespace() {
            s.bump();
            continue;
        }
        if c == '/' && s.peek_at(1) == Some('/') {
            let mut text = String::new();
            while let Some(ch) = s.peek() {
                if ch == '\n' {
                    break;
                }
                text.push(ch);
                s.bump();
            }
            // doc comments are prose (they may *describe* the
            // directive syntax); only plain `//` comments carry
            // lint:allow directives
            let is_doc = text.starts_with("///")
                || text.starts_with("//!");
            if !is_doc {
                match parse_allow(&text, line) {
                    AllowParse::None => {}
                    AllowParse::Ok(a) => allows.push(a),
                    AllowParse::Bad(msg) => {
                        bad_allows.push((line, msg))
                    }
                }
            }
            continue;
        }
        if c == '/' && s.peek_at(1) == Some('*') {
            s.bump();
            s.bump();
            let mut depth = 1usize;
            while depth > 0 {
                match (s.peek(), s.peek_at(1)) {
                    (Some('/'), Some('*')) => {
                        s.bump();
                        s.bump();
                        depth += 1;
                    }
                    (Some('*'), Some('/')) => {
                        s.bump();
                        s.bump();
                        depth -= 1;
                    }
                    (Some(_), _) => {
                        s.bump();
                    }
                    (None, _) => break,
                }
            }
            continue;
        }
        if c == '"' {
            s.bump();
            skip_string_body(&mut s);
            push(&mut tokens, Tok::Literal, line, col);
            continue;
        }
        if c == '\'' {
            lex_quote(&mut s, &mut tokens, line, col);
            continue;
        }
        if c.is_ascii_digit() {
            // consume `.` only before another digit, so ranges
            // (`0..n`) and tuple-index method chains (`x.0.unwrap()`)
            // don't get swallowed into the number
            while let Some(ch) = s.peek() {
                if is_ident_continue(ch) {
                    s.bump();
                } else if ch == '.'
                    && s.peek_at(1)
                        .is_some_and(|n| n.is_ascii_digit())
                {
                    s.bump();
                } else {
                    break;
                }
            }
            push(&mut tokens, Tok::Literal, line, col);
            continue;
        }
        if is_ident_start(c) {
            let mut name = String::new();
            while s.peek().is_some_and(is_ident_continue) {
                name.push(s.bump().unwrap_or(' '));
            }
            if lex_literal_prefix(&mut s, &mut tokens, &name, line, col)
            {
                continue;
            }
            push(&mut tokens, Tok::Ident(name), line, col);
            continue;
        }
        s.bump();
        push(&mut tokens, Tok::Punct(c), line, col);
    }

    mark_test_regions(&mut tokens);

    let mut code_lines: Vec<usize> =
        tokens.iter().map(|t| t.line).collect();
    code_lines.dedup();
    code_lines.sort_unstable();
    code_lines.dedup();

    LexedFile { tokens, allows, bad_allows, code_lines }
}

fn push(tokens: &mut Vec<Spanned>, tok: Tok, line: usize, col: usize) {
    tokens.push(Spanned { tok, line, col, in_test: false });
}

/// Consume a (non-raw) string body after the opening `"`.
fn skip_string_body(s: &mut Scan) {
    while let Some(ch) = s.peek() {
        s.bump();
        match ch {
            '"' => return,
            '\\' => {
                s.bump(); // the escaped char, whatever it is
            }
            _ => {}
        }
    }
}

/// `'` starts either a lifetime or a char literal. Uses the same
/// lookahead rustc does: `'x'` (next-next is a closing quote) or an
/// escape means char literal; `'ident` without a closing quote is a
/// lifetime; anything else (`'('`, `'∈'`) is a char literal.
fn lex_quote(s: &mut Scan, tokens: &mut Vec<Spanned>, line: usize,
             col: usize) {
    s.bump(); // the opening '
    match (s.peek(), s.peek_at(1)) {
        (Some('\\'), _) => {
            s.bump();
            s.bump(); // escape designator
            while s.peek().is_some_and(|ch| ch != '\'') {
                s.bump(); // \u{..} payloads
            }
            s.bump(); // closing '
            push(tokens, Tok::Literal, line, col);
        }
        (Some(a), Some('\'')) if is_ident_continue(a) => {
            s.bump();
            s.bump();
            push(tokens, Tok::Literal, line, col);
        }
        (Some(a), _) if is_ident_start(a) => {
            while s.peek().is_some_and(is_ident_continue) {
                s.bump();
            }
            push(tokens, Tok::Lifetime, line, col);
        }
        (Some(_), _) => {
            s.bump();
            if s.peek() == Some('\'') {
                s.bump();
            }
            push(tokens, Tok::Literal, line, col);
        }
        (None, _) => push(tokens, Tok::Literal, line, col),
    }
}

/// Handle `r"..."`, `r#"..."#`, `b"..."`, `br"..."`, `b'..'` and raw
/// identifiers `r#name` after the ident characters of `name` have been
/// consumed. Returns true when a literal (or raw ident) was emitted.
fn lex_literal_prefix(s: &mut Scan, tokens: &mut Vec<Spanned>,
                      name: &str, line: usize, col: usize) -> bool {
    let raw = name == "r" || name == "br";
    if raw && matches!(s.peek(), Some('"') | Some('#')) {
        let mut hashes = 0usize;
        while s.peek() == Some('#') {
            hashes += 1;
            s.bump();
        }
        if s.peek() != Some('"') {
            // `r#ident` — a raw identifier, not a string
            if hashes == 1 && s.peek().is_some_and(is_ident_start) {
                let mut id = String::new();
                while s.peek().is_some_and(is_ident_continue) {
                    id.push(s.bump().unwrap_or(' '));
                }
                push(tokens, Tok::Ident(id), line, col);
                return true;
            }
            return false;
        }
        s.bump(); // opening "
        'body: while s.peek().is_some() {
            if s.peek() == Some('"') {
                let mut ok = true;
                for h in 0..hashes {
                    if s.peek_at(1 + h) != Some('#') {
                        ok = false;
                        break;
                    }
                }
                if ok {
                    for _ in 0..=hashes {
                        s.bump();
                    }
                    break 'body;
                }
            }
            s.bump();
        }
        push(tokens, Tok::Literal, line, col);
        return true;
    }
    if name == "b" || name == "br" {
        if s.peek() == Some('"') {
            s.bump();
            skip_string_body(s);
            push(tokens, Tok::Literal, line, col);
            return true;
        }
        if name == "b" && s.peek() == Some('\'') {
            lex_quote(s, tokens, line, col);
            return true;
        }
    }
    false
}

/// Flag every token belonging to a `#[test]` or `#[cfg(test)]` item
/// (through the end of its balanced `{..}` block, or its terminating
/// `;`). `#[cfg(not(test))]` is recognised as NOT test code.
fn mark_test_regions(tokens: &mut [Spanned]) {
    let mut i = 0usize;
    while i < tokens.len() {
        let starts_attr = matches!(tokens[i].tok, Tok::Punct('#'))
            && matches!(tokens.get(i + 1).map(|t| &t.tok),
                        Some(Tok::Punct('[')));
        if !starts_attr {
            i += 1;
            continue;
        }
        let mut j = i + 2;
        let mut depth = 1usize;
        let mut has_test = false;
        let mut has_not = false;
        while j < tokens.len() && depth > 0 {
            match &tokens[j].tok {
                Tok::Punct('[') => depth += 1,
                Tok::Punct(']') => depth -= 1,
                Tok::Ident(s) if s == "test" => has_test = true,
                Tok::Ident(s) if s == "not" => has_not = true,
                _ => {}
            }
            j += 1;
        }
        if !(has_test && !has_not) {
            i = j;
            continue;
        }
        // Skip over any further attributes, then the item signature, to
        // its body. The first `{` at depth 0 opens the body; a `;`
        // before any `{` ends a block-less item (use, const, …).
        let mut k = j;
        let mut brace = 0usize;
        let mut entered = false;
        while k < tokens.len() {
            match &tokens[k].tok {
                Tok::Punct('{') => {
                    brace += 1;
                    entered = true;
                }
                Tok::Punct('}') => {
                    brace = brace.saturating_sub(1);
                    if entered && brace == 0 {
                        k += 1;
                        break;
                    }
                }
                Tok::Punct(';') if !entered => {
                    k += 1;
                    break;
                }
                _ => {}
            }
            k += 1;
        }
        let end = k.min(tokens.len());
        for t in &mut tokens[i..end] {
            t.in_test = true;
        }
        i = end;
    }
}

enum AllowParse {
    /// No `lint:allow` marker in this comment.
    None,
    Ok(Allow),
    Bad(String),
}

/// Parse `lint:allow(<rule>): <reason>` out of a line comment's text.
/// A bare `lint:allow` mention without the `(` is comment prose, not
/// a (malformed) directive.
fn parse_allow(comment: &str, line: usize) -> AllowParse {
    let Some(pos) = comment.find("lint:allow(") else {
        return AllowParse::None;
    };
    let rest = &comment[pos + "lint:allow(".len()..];
    let Some(close) = rest.find(')') else {
        return AllowParse::Bad(
            "unclosed rule name in lint:allow(...)".into());
    };
    let rule = rest[..close].trim().to_string();
    if rule.is_empty() {
        return AllowParse::Bad("empty rule name in lint:allow".into());
    }
    let after = &rest[close + 1..];
    let Some(reason) = after.trim_start().strip_prefix(':') else {
        return AllowParse::Bad(format!(
            "lint:allow({rule}) needs a `: <reason>` suffix"));
    };
    let reason = reason.trim().to_string();
    if reason.is_empty() {
        return AllowParse::Bad(format!(
            "lint:allow({rule}) has an empty reason"));
    }
    AllowParse::Ok(Allow { rule, reason, line })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(f: &LexedFile) -> Vec<(String, usize, bool)> {
        f.tokens
            .iter()
            .filter_map(|t| match &t.tok {
                Tok::Ident(s) => {
                    Some((s.clone(), t.line, t.in_test))
                }
                _ => None,
            })
            .collect()
    }

    #[test]
    fn comments_strings_and_chars_hide_their_contents() {
        let src = "// Instant in a comment\n\
                   /* HashMap /* nested */ still comment */\n\
                   let s = \"Instant::now() inside\";\n\
                   let r = r#\"unwrap() \"quoted\" inside\"#;\n\
                   let c = '{';\n\
                   let b = b'\\'';\n\
                   let real = 1;\n";
        let f = lex(src);
        let names: Vec<String> =
            idents(&f).into_iter().map(|(n, _, _)| n).collect();
        assert!(!names.contains(&"Instant".to_string()), "{names:?}");
        assert!(!names.contains(&"HashMap".to_string()));
        assert!(!names.contains(&"unwrap".to_string()));
        assert!(names.contains(&"real".to_string()));
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let f = lex("fn f<'a>(x: &'a str) -> char { 'a' }");
        let lifetimes = f
            .tokens
            .iter()
            .filter(|t| t.tok == Tok::Lifetime)
            .count();
        assert_eq!(lifetimes, 2);
        let literals = f
            .tokens
            .iter()
            .filter(|t| t.tok == Tok::Literal)
            .count();
        assert_eq!(literals, 1);
    }

    #[test]
    fn numbers_do_not_swallow_ranges_or_tuple_methods() {
        let f = lex("for i in 0..n { x.0.unwrap(); let y = 1.5e3; }");
        let names: Vec<String> =
            idents(&f).into_iter().map(|(n, _, _)| n).collect();
        assert!(names.contains(&"n".to_string()), "{names:?}");
        assert!(names.contains(&"unwrap".to_string()), "{names:?}");
    }

    #[test]
    fn spans_are_one_based_lines_and_columns() {
        let f = lex("let a = 1;\n  foo();\n");
        let foo = f
            .tokens
            .iter()
            .find(|t| t.tok == Tok::Ident("foo".into()))
            .map(|t| (t.line, t.col));
        assert_eq!(foo, Some((2, 3)));
    }

    #[test]
    fn cfg_test_blocks_are_flagged() {
        let src = "fn hot() { work(); }\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                       fn helper() { scratch(); }\n\
                   }\n\
                   fn also_hot() { more(); }\n";
        let f = lex(src);
        for (name, _, in_test) in idents(&f) {
            match name.as_str() {
                "work" | "more" | "hot" | "also_hot" => {
                    assert!(!in_test, "{name} wrongly flagged")
                }
                "helper" | "scratch" | "tests" => {
                    assert!(in_test, "{name} not flagged")
                }
                _ => {}
            }
        }
    }

    #[test]
    fn cfg_not_test_is_not_test_code() {
        let f = lex("#[cfg(not(test))]\nfn hot() { work(); }\n");
        for (name, _, in_test) in idents(&f) {
            if name == "work" {
                assert!(!in_test);
            }
        }
    }

    #[test]
    fn test_attribute_marks_only_its_fn() {
        let src = "#[test]\nfn check() { probe(); }\n\
                   fn hot() { work(); }\n";
        let f = lex(src);
        for (name, _, in_test) in idents(&f) {
            match name.as_str() {
                "probe" | "check" => assert!(in_test, "{name}"),
                "work" | "hot" => assert!(!in_test, "{name}"),
                _ => {}
            }
        }
    }

    #[test]
    fn allow_comments_parse_and_reject_missing_reasons() {
        let src = "// lint:allow(clock-discipline): bench timing\n\
                   let a = 1;\n\
                   let b = 2; // lint:allow(seeded-rng): trailing ok\n\
                   // lint:allow(no-reason)\n";
        let f = lex(src);
        assert_eq!(f.allows.len(), 2);
        assert_eq!(f.allows[0].rule, "clock-discipline");
        assert_eq!(f.allows[0].line, 1);
        assert_eq!(f.next_code_line(1), Some(2));
        assert_eq!(f.allows[1].rule, "seeded-rng");
        assert_eq!(f.allows[1].line, 3);
        assert_eq!(f.bad_allows.len(), 1);
        assert_eq!(f.bad_allows[0].0, 4);
    }

    #[test]
    fn doc_comments_and_prose_mentions_are_not_directives() {
        let src = "//! docs may show `lint:allow(<rule>): <reason>`\n\
                   /// same for item docs: lint:allow(x)\n\
                   // prose mentioning lint:allow without parens\n\
                   fn f() {}\n";
        let f = lex(src);
        assert!(f.allows.is_empty(), "{:?}", f.allows);
        assert!(f.bad_allows.is_empty(), "{:?}", f.bad_allows);
    }
}
