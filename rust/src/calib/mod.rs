//! Runtime calibration driver (paper §5.1.1: 25 iterations × batch 4).
//!
//! Runs the `prefill_stats` artifact over a deterministic calibration
//! stream, folds per-batch (count, mean, M2, min) with the
//! parallel-Welford rule, and derives per-layer clip thresholds. Also
//! regenerates the Fig. 6 series (sigma across layers and iterations)
//! and can read the build-time `calibration.json` produced by
//! `python -m compile.calibrate` (the two paths agree; tested).

use std::path::Path;

use crate::util::error::{anyhow, Result};

use crate::eval::corpus::generate_tokens;
use crate::eval::{family_world_seed, World};
use crate::exaq::clip::LayerStats;
use crate::model::Tokenizer;
use crate::runtime::{Engine, HostTensor};
use crate::util::json::Json;

pub const CALIB_ITERS: usize = 25;
pub const CALIB_BATCH: usize = 4;
/// Matches python compile/calibrate.py CALIB_SEED.
pub const CALIB_SEED: u64 = 20240555;

/// Welford accumulator for one layer.
#[derive(Clone, Copy, Debug, Default)]
pub struct Welford {
    pub count: f64,
    pub mean: f64,
    pub m2: f64,
    pub min: f64,
}

impl Welford {
    pub fn merge(&mut self, count: f64, mean: f64, m2: f64, min: f64) {
        if self.count == 0.0 {
            *self = Welford { count, mean, m2, min };
            return;
        }
        let n = self.count + count;
        let d = mean - self.mean;
        self.mean += d * count / n;
        self.m2 += m2 + d * d * self.count * count / n;
        self.count = n;
        self.min = self.min.min(min);
    }

    pub fn sigma(&self) -> f64 {
        if self.count > 0.0 { (self.m2 / self.count).sqrt() } else { 0.0 }
    }

    pub fn stats(&self) -> LayerStats {
        LayerStats {
            sigma: self.sigma(),
            min: self.min,
            mean: self.mean,
            count: self.count,
        }
    }
}

/// Full calibration output for one model.
#[derive(Clone, Debug)]
pub struct Calibration {
    pub model: String,
    pub layers: Vec<LayerStats>,
    /// Fig. 6 raw series: per-iteration, per-layer sigma.
    pub fig6_sigma: Vec<Vec<f64>>,
}

/// Run the calibration protocol against the engine.
pub fn calibrate(engine: &mut Engine, model: &str) -> Result<Calibration> {
    let entry = engine.manifest.model(model)?.clone();
    let seq = engine.manifest.seq;
    let tok = Tokenizer::from_manifest(&engine.manifest);
    let world = World::build(family_world_seed(entry.family));
    let stream = generate_tokens(&world, &tok, CALIB_SEED,
                                 CALIB_ITERS * CALIB_BATCH * seq + 1);

    let n_layers = entry.config.n_layers;
    let mut acc = vec![Welford::default(); n_layers];
    let mut fig6 = Vec::with_capacity(CALIB_ITERS);
    for it in 0..CALIB_ITERS {
        let lo = it * CALIB_BATCH * seq;
        let tokens = HostTensor::i32(
            stream[lo..lo + CALIB_BATCH * seq].to_vec(),
            &[CALIB_BATCH, seq]);
        let lengths = vec![seq as i32; CALIB_BATCH];
        let (_, stats) = engine.prefill_stats(model, &tokens, &lengths)?;
        let s = stats.as_f32()?;
        let mut iter_sigma = Vec::with_capacity(n_layers);
        for (layer, acc_l) in acc.iter_mut().enumerate() {
            let row = &s[layer * 4..layer * 4 + 4];
            let (count, mean, m2, min) =
                (row[0] as f64, row[1] as f64, row[2] as f64,
                 row[3] as f64);
            iter_sigma.push(if count > 0.0 { (m2 / count).sqrt() }
                            else { 0.0 });
            acc_l.merge(count, mean, m2, min);
        }
        fig6.push(iter_sigma);
    }
    Ok(Calibration {
        model: model.to_string(),
        layers: acc.iter().map(Welford::stats).collect(),
        fig6_sigma: fig6,
    })
}

/// Read the build-time calibration.json for a model.
pub fn load_calibration(dir: &Path, model: &str) -> Result<Calibration> {
    let j = Json::parse(&std::fs::read_to_string(
        dir.join("calibration.json"))?)?;
    let m = j
        .at(&["models", model])
        .ok_or_else(|| anyhow!("model {model} not in calibration.json"))?;
    let mut layers = Vec::new();
    for l in m.get("layers").and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("layers missing"))?
    {
        layers.push(LayerStats {
            sigma: l.get("sigma").and_then(Json::as_f64).unwrap_or(0.0),
            min: l.get("min").and_then(Json::as_f64).unwrap_or(0.0),
            mean: l.get("mean").and_then(Json::as_f64).unwrap_or(0.0),
            count: l.get("count").and_then(Json::as_f64).unwrap_or(0.0),
        });
    }
    let fig6_sigma = m
        .get("fig6_sigma")
        .and_then(Json::as_arr)
        .map(|rows| {
            rows.iter().filter_map(Json::as_f64_vec).collect()
        })
        .unwrap_or_default();
    Ok(Calibration { model: model.to_string(), layers, fig6_sigma })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_merge_matches_direct_computation() {
        // two chunks of known data
        let a = [1.0, 2.0, 3.0];
        let b = [10.0, 11.0];
        let all: Vec<f64> = a.iter().chain(b.iter()).copied().collect();
        let stats = |xs: &[f64]| {
            let n = xs.len() as f64;
            let mean = xs.iter().sum::<f64>() / n;
            let m2 = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>();
            let min = xs.iter().copied().fold(f64::INFINITY, f64::min);
            (n, mean, m2, min)
        };
        let (n1, m1, q1, mn1) = stats(&a);
        let (n2, m2v, q2, mn2) = stats(&b);
        let mut w = Welford::default();
        w.merge(n1, m1, q1, mn1);
        w.merge(n2, m2v, q2, mn2);
        let (n, mean, m2, min) = stats(&all);
        assert!((w.count - n).abs() < 1e-12);
        assert!((w.mean - mean).abs() < 1e-12);
        assert!((w.m2 - m2).abs() < 1e-9);
        assert!((w.min - min).abs() < 1e-12);
    }

    #[test]
    fn sigma_of_constant_data_is_zero() {
        let mut w = Welford::default();
        w.merge(10.0, 5.0, 0.0, 5.0);
        assert_eq!(w.sigma(), 0.0);
    }
}
