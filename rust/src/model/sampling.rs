//! Sampling over logits — the L3 hot path where the Rust-native EXAQ
//! softmax (Algorithm 2) is deployed: converting logits to a sampling
//! distribution uses the same quantize + LUT pipeline the paper
//! accelerates, so serving exercises the paper's kernel end to end even
//! outside the attention blocks.
//!
//! Two entry points share all numeric machinery:
//! * [`sample_with`] — one logit row at a time (prefill, library use);
//! * [`BatchSampler`] — the decode hot path: every active slot's row in
//!   one [`BatchSoftmax`] plane call, with tokens drawn in row order so
//!   the RNG stream matches the per-row path draw for draw.

use crate::exaq::batched::{ensure_engine, BatchSoftmax};
use crate::exaq::plane::AttentionPlane;
use crate::exaq::softmax::softmax_exact;
use crate::exaq::stream::StreamingAttention;
use crate::util::rng::SplitMix64;

/// How to turn logits into a next token.
#[derive(Clone, Copy, Debug)]
pub struct SamplingParams {
    /// 0.0 -> greedy argmax.
    pub temperature: f32,
    /// 0 -> no top-k filtering.
    pub top_k: usize,
    /// When set, run the sampling softmax through the EXAQ Algorithm 2
    /// pipeline at this (bits, clip) instead of exact exp.
    pub exaq: Option<(u32, f32)>,
}

impl Default for SamplingParams {
    fn default() -> Self {
        Self { temperature: 0.0, top_k: 0, exaq: None }
    }
}

impl SamplingParams {
    pub fn greedy() -> Self {
        Self::default()
    }

    /// Stochastic sampling whose softmax runs through the EXAQ
    /// Algorithm-2 pipeline at (`bits`, `clip`) — the configuration the
    /// serving stress scenarios use to keep the paper kernel on the
    /// sampling hot path.
    pub fn exaq(temperature: f32, bits: u32, clip: f32) -> Self {
        Self { temperature, top_k: 0, exaq: Some((bits, clip)) }
    }
}

/// Reusable sampling scratch (no allocation at steady state). The EXAQ
/// tables live in a cached [`BatchSoftmax`] keyed by (bits, clip), so
/// decode loops sampling at a fixed configuration never rebuild the
/// tables per token.
#[derive(Default)]
pub struct SamplerScratch {
    probs: Vec<f32>,
    idx: Vec<usize>,
    engine: Option<BatchSoftmax>,
}

/// Sample one token id from `logits`.
pub fn sample(logits: &[f32], params: &SamplingParams,
              rng: &mut SplitMix64) -> i32 {
    let mut scratch = SamplerScratch::default();
    sample_with(logits, params, rng, &mut scratch)
}

/// Allocation-free variant for per-row callers (prefill admission).
pub fn sample_with(logits: &[f32], params: &SamplingParams,
                   rng: &mut SplitMix64,
                   scratch: &mut SamplerScratch) -> i32 {
    if params.temperature <= 0.0 {
        return argmax(logits);
    }
    let probs = &mut scratch.probs;
    probs.clear();
    probs.extend(logits.iter().map(|&x| x / params.temperature));

    match params.exaq {
        Some((bits, c)) => {
            let engine = ensure_engine(&mut scratch.engine, bits, c);
            let n = probs.len();
            engine.softmax_row(probs, n);
        }
        None => softmax_exact(probs),
    }

    if params.top_k > 0 && params.top_k < probs.len() {
        apply_top_k(probs, params.top_k, &mut scratch.idx);
    }

    draw(probs, rng).unwrap_or_else(|| argmax(logits))
}

/// Zero all but the `k` largest probabilities and renormalise.
/// Partial selection (`select_nth_unstable_by`) is O(V) per token where
/// the old full sort was O(V log V).
fn apply_top_k(probs: &mut [f32], k: usize, idx: &mut Vec<usize>) {
    debug_assert!(k > 0 && k < probs.len());
    idx.clear();
    idx.extend(0..probs.len());
    idx.select_nth_unstable_by(k - 1, |&a, &b| {
        probs[b].total_cmp(&probs[a])
    });
    for &i in &idx[k..] {
        probs[i] = 0.0;
    }
    let total: f32 = probs.iter().sum();
    if total > 0.0 {
        for p in probs.iter_mut() {
            *p /= total;
        }
    }
}

/// Inverse-CDF draw over a probability row; `None` when the walk falls
/// off the end (degenerate rows) so callers can fall back to argmax.
fn draw(probs: &[f32], rng: &mut SplitMix64) -> Option<i32> {
    let u = rng.uniform() as f32;
    let mut acc = 0.0;
    for (i, &p) in probs.iter().enumerate() {
        acc += p;
        if u < acc {
            return Some(i as i32);
        }
    }
    None
}

fn argmax(xs: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best as i32
}

/// Row class for the batched plane partition.
#[derive(Clone, Copy, PartialEq)]
enum RowClass {
    Greedy,
    Exact,
    Exaq(u32, f32),
}

fn classify(p: &SamplingParams) -> RowClass {
    if p.temperature <= 0.0 {
        RowClass::Greedy
    } else {
        match p.exaq {
            // a NaN clip would never equal itself and break the
            // PartialEq grouping; canonicalise it to the bound the
            // quantizer clamps to anyway
            Some((b, c)) if c.is_nan() => {
                RowClass::Exaq(b, -crate::exaq::quant::CLIP_EPS)
            }
            Some((b, c)) => RowClass::Exaq(b, c),
            None => RowClass::Exact,
        }
    }
}

/// Decode-time batched sampler: gathers every stochastic row of a
/// logits plane into a contiguous scratch plane grouped by softmax
/// configuration, runs each EXAQ group through ONE
/// [`BatchSoftmax::softmax_rows`] kernel call, then draws tokens in the
/// caller's row order (one `rng.uniform()` per stochastic row — the
/// exact draw sequence of per-row [`sample_with`], and, because the
/// batched kernel is bit-identical to the scalar one, the exact same
/// tokens).
#[derive(Default)]
pub struct BatchSampler {
    plane: Vec<f32>,
    map: Vec<usize>,
    idx: Vec<usize>,
    engines: Vec<BatchSoftmax>,
    /// Per-(bits, clip) fused attention planes, same keep-per-config
    /// policy as `engines` so alternating configurations never rebuild
    /// LUTs or reallocate the packed plane.
    planes: Vec<AttentionPlane>,
    /// Per-(bits, clip) streaming one-pass kernels, cached under the
    /// same policy (see [`BatchSampler::attend_streaming`]).
    streams: Vec<StreamingAttention>,
    // partition scratch, reused so a decode tick allocates nothing
    // at steady state
    groups: Vec<(RowClass, usize)>,
    offsets: Vec<usize>,
    cursor: Vec<usize>,
    /// Worker-count override handed to every engine (0 = auto: the
    /// pool default behind its plane-size heuristic, so small decode
    /// ticks stay inline and big ones fan out).
    threads: usize,
}

impl BatchSampler {
    /// Pin the worker count used by the per-config
    /// [`BatchSoftmax::softmax_rows`] calls. Tokens are identical for
    /// any value — the pooled kernel is bit-identical to scalar — so
    /// this is purely a throughput knob.
    pub fn set_threads(&mut self, threads: usize) -> &mut Self {
        self.threads = threads;
        self
    }

    /// Run a `[rows × len]` attention-score plane through the fused
    /// packed pipeline ([`AttentionPlane::attend`]) at (`bits`,
    /// `clip`): quantize once, stay in `PackedCodes` through exp and
    /// accumulation, and fold the premultiplied decode into the
    /// weighted-value pass over `values` (`[len × d_head]`). `out`
    /// (`[rows × d_head]`) receives the attended vectors,
    /// bit-identical to softmax + dense PV. Planes are cached per
    /// configuration exactly like the sampling engines.
    #[allow(clippy::too_many_arguments)]
    pub fn attend_rows(&mut self, scores: &[f32], rows: usize,
                       len: usize, valid_lens: &[usize],
                       values: &[f32], d_head: usize, bits: u32,
                       clip: f32, out: &mut [f32]) {
        let pi = match self
            .planes
            .iter()
            .position(|p| p.matches(bits, clip))
        {
            Some(i) => i,
            None => {
                self.planes.push(AttentionPlane::new(bits, clip));
                self.planes.len() - 1
            }
        };
        self.planes[pi].set_threads(self.threads);
        self.planes[pi]
            .attend(scores, rows, len, valid_lens, values, d_head, out);
    }

    /// [`Self::attend_rows`] through the streaming one-pass kernel
    /// ([`crate::exaq::StreamingAttention::attend_scores`]): same
    /// `[rows × len]` score plane in, bit-identical attended vectors
    /// out, but the kernel consumes the scores one `TILE_LANES` strip
    /// at a time and never allocates its own dense f32 plane. Kernels
    /// are cached per (bits, clip) exactly like `planes`/`engines`.
    #[allow(clippy::too_many_arguments)]
    pub fn attend_streaming(&mut self, scores: &[f32], rows: usize,
                            len: usize, valid_lens: &[usize],
                            values: &[f32], d_head: usize, bits: u32,
                            clip: f32, out: &mut [f32]) {
        let si = match self
            .streams
            .iter()
            .position(|s| s.matches(bits, clip))
        {
            Some(i) => i,
            None => {
                self.streams.push(StreamingAttention::new(bits, clip));
                self.streams.len() - 1
            }
        };
        self.streams[si].set_threads(self.threads);
        self.streams[si].attend_scores(scores, rows, len, valid_lens,
                                       values, d_head, out);
    }

    /// Sample one token per entry of `rows` from a `[* × vocab]` logits
    /// plane. `rows` pairs a plane row index with that row's sampling
    /// params; `out` receives one token per entry, in order.
    pub fn sample_rows(&mut self, logits: &[f32], vocab: usize,
                       rows: &[(usize, SamplingParams)],
                       rng: &mut SplitMix64, out: &mut Vec<i32>) {
        out.clear();
        if rows.is_empty() {
            return;
        }
        assert!(vocab > 0, "empty vocabulary");
        for &(r, _) in rows {
            assert!((r + 1) * vocab <= logits.len(),
                    "row {r} outside the logits plane");
        }

        // ---- partition: stochastic rows get plane slots grouped by
        // softmax config (greedy rows never touch the plane)
        self.groups.clear(); // (class, count) pairs
        for (_, p) in rows {
            let cl = classify(p);
            if cl == RowClass::Greedy {
                continue;
            }
            match self.groups.iter_mut().find(|g| g.0 == cl) {
                Some(g) => g.1 += 1,
                None => self.groups.push((cl, 1)),
            }
        }
        self.offsets.clear();
        let mut total = 0usize;
        for &(_, count) in &self.groups {
            self.offsets.push(total);
            total += count;
        }
        self.plane.resize(total * vocab, 0.0);
        self.map.clear();
        self.cursor.clear();
        self.cursor.extend_from_slice(&self.offsets);
        for (r, p) in rows {
            let cl = classify(p);
            if cl == RowClass::Greedy {
                self.map.push(usize::MAX);
                continue;
            }
            let Some(gi) =
                self.groups.iter().position(|g| g.0 == cl)
            else {
                // unreachable by construction (every non-greedy class
                // was registered in the partition pass); degrade to the
                // greedy fallback rather than aborting a decode tick
                self.map.push(usize::MAX);
                continue;
            };
            let slot = self.cursor[gi];
            self.cursor[gi] += 1;
            self.map.push(slot);
            let dst = &mut self.plane[slot * vocab..(slot + 1) * vocab];
            let src = &logits[r * vocab..(r + 1) * vocab];
            for (d, &s) in dst.iter_mut().zip(src) {
                *d = s / p.temperature;
            }
        }

        // ---- softmax: one batched kernel call per EXAQ config group
        for (gi, &(cl, count)) in self.groups.iter().enumerate() {
            let start = self.offsets[gi];
            let slice =
                &mut self.plane[start * vocab..(start + count) * vocab];
            match cl {
                RowClass::Exact => {
                    for row in slice.chunks_exact_mut(vocab) {
                        softmax_exact(row);
                    }
                }
                RowClass::Exaq(bits, c) => {
                    let ei = match self
                        .engines
                        .iter()
                        .position(|e| e.matches(bits, c))
                    {
                        Some(i) => i,
                        None => {
                            self.engines.push(BatchSoftmax::new(bits, c));
                            self.engines.len() - 1
                        }
                    };
                    self.engines[ei].set_threads(self.threads);
                    self.engines[ei]
                        .softmax_rows(slice, count, vocab, &[]);
                }
                // greedy rows never enter the partition groups
                RowClass::Greedy => {}
            }
        }

        // ---- draw: caller's row order, one uniform per stochastic row
        for (i, (r, p)) in rows.iter().enumerate() {
            let tok = if self.map[i] == usize::MAX {
                argmax(&logits[r * vocab..(r + 1) * vocab])
            } else {
                let slot = self.map[i];
                let probs =
                    &mut self.plane[slot * vocab..(slot + 1) * vocab];
                if p.top_k > 0 && p.top_k < vocab {
                    apply_top_k(probs, p.top_k, &mut self.idx);
                }
                draw(probs, rng).unwrap_or_else(|| {
                    argmax(&logits[r * vocab..(r + 1) * vocab])
                })
            };
            out.push(tok);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        let mut rng = SplitMix64::new(1);
        let logits = vec![0.1, 2.0, -1.0, 1.9];
        assert_eq!(sample(&logits, &SamplingParams::greedy(), &mut rng), 1);
    }

    #[test]
    fn temperature_sampling_respects_distribution() {
        let mut rng = SplitMix64::new(2);
        let logits = vec![0.0, 3.0];
        let params = SamplingParams { temperature: 1.0, top_k: 0,
                                      exaq: None };
        let n = 5000;
        let ones = (0..n)
            .filter(|_| sample(&logits, &params, &mut rng) == 1)
            .count();
        let frac = ones as f64 / n as f64;
        // p(1) = e^3/(1+e^3) ≈ 0.953
        assert!((frac - 0.953).abs() < 0.02, "{frac}");
    }

    #[test]
    fn top_k_masks_tail() {
        let mut rng = SplitMix64::new(3);
        let logits = vec![3.0, 2.9, -5.0, -6.0];
        let params = SamplingParams { temperature: 1.0, top_k: 2,
                                      exaq: None };
        for _ in 0..200 {
            let t = sample(&logits, &params, &mut rng);
            assert!(t == 0 || t == 1, "sampled masked token {t}");
        }
    }

    #[test]
    fn top_k_selection_matches_full_sort_reference() {
        // the select_nth path must keep exactly the k largest lanes
        let mut rng = SplitMix64::new(31);
        for trial in 0..50 {
            let v = 16 + rng.below(64);
            let k = 1 + rng.below(v - 1);
            let raw: Vec<f32> =
                (0..v).map(|_| rng.normal() as f32).collect();
            let mut probs = raw.clone();
            softmax_exact(&mut probs);
            let mut fast = probs.clone();
            apply_top_k(&mut fast, k, &mut Vec::new());
            // reference: full sort
            let mut order: Vec<usize> = (0..v).collect();
            order.sort_unstable_by(|&a, &b| {
                probs[b].partial_cmp(&probs[a]).unwrap()
            });
            let mut slow = probs.clone();
            for &i in &order[k..] {
                slow[i] = 0.0;
            }
            let total: f32 = slow.iter().sum();
            for p in slow.iter_mut() {
                *p /= total;
            }
            let kept_fast = fast.iter().filter(|&&p| p > 0.0).count();
            assert_eq!(kept_fast, k, "trial {trial}");
            for (i, (a, b)) in fast.iter().zip(&slow).enumerate() {
                assert!((a - b).abs() < 1e-6,
                        "trial {trial} lane {i}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn exaq_sampling_close_to_exact() {
        let mut rng = SplitMix64::new(4);
        let logits = vec![2.0, 1.5, 0.0, -1.0];
        let exact = SamplingParams { temperature: 1.0, top_k: 0,
                                     exaq: None };
        let quant = SamplingParams { temperature: 1.0, top_k: 0,
                                     exaq: Some((4, -8.0)) };
        let n = 4000;
        let mut counts = [[0usize; 4]; 2];
        for _ in 0..n {
            counts[0][sample(&logits, &exact, &mut rng) as usize] += 1;
            counts[1][sample(&logits, &quant, &mut rng) as usize] += 1;
        }
        for i in 0..4 {
            let a = counts[0][i] as f64 / n as f64;
            let b = counts[1][i] as f64 / n as f64;
            assert!((a - b).abs() < 0.05, "token {i}: {a} vs {b}");
        }
    }

    #[test]
    fn batch_sampler_matches_per_row_sampling_exactly() {
        // mixed greedy / exact / EXAQ rows: the batched plane path must
        // reproduce the per-row path token for token (same RNG stream,
        // bit-identical softmax)
        let vocab = 48usize;
        let rows = 7usize;
        let mut gen = SplitMix64::new(99);
        let logits: Vec<f32> =
            (0..rows * vocab).map(|_| gen.normal() as f32 * 2.0).collect();
        let params = [
            SamplingParams::greedy(),
            SamplingParams::exaq(0.9, 2, -4.0),
            SamplingParams { temperature: 1.1, top_k: 0, exaq: None },
            SamplingParams::exaq(0.9, 2, -4.0),
            SamplingParams { temperature: 0.7, top_k: 5,
                             exaq: Some((3, -5.0)) },
            SamplingParams::greedy(),
            SamplingParams { temperature: 1.0, top_k: 3, exaq: None },
        ];
        let sel: Vec<(usize, SamplingParams)> =
            (0..rows).map(|r| (r, params[r])).collect();

        let mut batched = Vec::new();
        let mut sampler = BatchSampler::default();
        let mut rng_a = SplitMix64::new(1234);
        sampler.sample_rows(&logits, vocab, &sel, &mut rng_a,
                            &mut batched);

        let mut rng_b = SplitMix64::new(1234);
        let mut scratch = SamplerScratch::default();
        let scalar: Vec<i32> = (0..rows)
            .map(|r| {
                sample_with(&logits[r * vocab..(r + 1) * vocab],
                            &params[r], &mut rng_b, &mut scratch)
            })
            .collect();
        assert_eq!(batched, scalar);
        // and the call is repeatable with a fresh rng
        let mut again = Vec::new();
        let mut rng_c = SplitMix64::new(1234);
        sampler.sample_rows(&logits, vocab, &sel, &mut rng_c,
                            &mut again);
        assert_eq!(batched, again);
    }

    #[test]
    fn sampler_attend_rows_matches_two_step_reference() {
        // the sampler's packed-plane entry must be bit-identical to
        // the quantize -> softmax_rows -> dense-PV reference, and the
        // per-config plane cache must be reused across calls
        let (rows, len, d) = (4usize, 37usize, 6usize);
        let mut gen = SplitMix64::new(77);
        let scores: Vec<f32> =
            (0..rows * len).map(|_| gen.normal() as f32).collect();
        let values: Vec<f32> =
            (0..len * d).map(|_| gen.normal() as f32).collect();
        let vlens = [len, 0, 11, len];

        let mut sampler = BatchSampler::default();
        sampler.set_threads(2);
        let mut fused = vec![0.0f32; rows * d];
        for bits in [2u32, 3, 4] {
            sampler.attend_rows(&scores, rows, len, &vlens, &values,
                                d, bits, -4.0, &mut fused);
            let mut reference = AttentionPlane::new(bits, -4.0);
            reference.set_threads(2);
            let mut two_step = vec![0.0f32; rows * d];
            reference.attend_two_step(&scores, rows, len, &vlens,
                                      &values, d, &mut two_step);
            let a: Vec<u32> =
                fused.iter().map(|x| x.to_bits()).collect();
            let b: Vec<u32> =
                two_step.iter().map(|x| x.to_bits()).collect();
            assert_eq!(a, b, "bits={bits}");
        }
        // three configs -> three cached planes, and repeating a
        // config must not grow the cache
        assert_eq!(sampler.planes.len(), 3);
        sampler.attend_rows(&scores, rows, len, &vlens, &values, d, 2,
                            -4.0, &mut fused);
        assert_eq!(sampler.planes.len(), 3);
    }

    #[test]
    fn sampler_attend_streaming_matches_the_fused_entry() {
        // the streaming entry point must produce the exact vectors of
        // the fused plane entry, and keep its own per-config cache
        let (rows, len, d) = (4usize, 37usize, 6usize);
        let mut gen = SplitMix64::new(77);
        let scores: Vec<f32> =
            (0..rows * len).map(|_| gen.normal() as f32).collect();
        let values: Vec<f32> =
            (0..len * d).map(|_| gen.normal() as f32).collect();
        let vlens = [len, 0, 11, len];

        let mut sampler = BatchSampler::default();
        sampler.set_threads(2);
        let mut fused = vec![0.0f32; rows * d];
        let mut streamed = vec![0.0f32; rows * d];
        for bits in [2u32, 3, 4] {
            sampler.attend_rows(&scores, rows, len, &vlens, &values,
                                d, bits, -4.0, &mut fused);
            sampler.attend_streaming(&scores, rows, len, &vlens,
                                     &values, d, bits, -4.0,
                                     &mut streamed);
            let a: Vec<u32> =
                fused.iter().map(|x| x.to_bits()).collect();
            let b: Vec<u32> =
                streamed.iter().map(|x| x.to_bits()).collect();
            assert_eq!(a, b, "bits={bits}");
        }
        // three configs -> three cached kernels, and repeating a
        // config must not grow the cache
        assert_eq!(sampler.streams.len(), 3);
        sampler.attend_streaming(&scores, rows, len, &vlens, &values,
                                 d, 2, -4.0, &mut streamed);
        assert_eq!(sampler.streams.len(), 3);
    }

    #[test]
    fn batch_sampler_empty_and_single_row() {
        let mut sampler = BatchSampler::default();
        let mut rng = SplitMix64::new(5);
        let mut out = vec![99i32];
        sampler.sample_rows(&[], 4, &[], &mut rng, &mut out);
        assert!(out.is_empty());
        let logits = vec![0.0f32, 4.0, -1.0, 0.5];
        sampler.sample_rows(&logits, 4,
                            &[(0, SamplingParams::greedy())], &mut rng,
                            &mut out);
        assert_eq!(out, vec![1]);
    }
}
