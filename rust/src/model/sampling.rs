//! Sampling over logits — the L3 hot path where the Rust-native EXAQ
//! softmax (Algorithm 2) is deployed: converting logits to a sampling
//! distribution uses the same quantize + LUT pipeline the paper
//! accelerates, so serving exercises the paper's kernel end to end even
//! outside the attention blocks.

use crate::exaq::lut::{LutExp, LutSum};
use crate::exaq::quant::Quantizer;
use crate::exaq::softmax::{softmax_algo2, softmax_exact, Algo2Scratch};
use crate::util::rng::SplitMix64;

/// How to turn logits into a next token.
#[derive(Clone, Copy, Debug)]
pub struct SamplingParams {
    /// 0.0 -> greedy argmax.
    pub temperature: f32,
    /// 0 -> no top-k filtering.
    pub top_k: usize,
    /// When set, run the sampling softmax through the EXAQ Algorithm 2
    /// pipeline at this (bits, clip) instead of exact exp.
    pub exaq: Option<(u32, f32)>,
}

impl Default for SamplingParams {
    fn default() -> Self {
        Self { temperature: 0.0, top_k: 0, exaq: None }
    }
}

impl SamplingParams {
    pub fn greedy() -> Self {
        Self::default()
    }

    /// Stochastic sampling whose softmax runs through the EXAQ
    /// Algorithm-2 pipeline at (`bits`, `clip`) — the configuration the
    /// serving stress scenarios use to keep the paper kernel on the
    /// sampling hot path.
    pub fn exaq(temperature: f32, bits: u32, clip: f32) -> Self {
        Self { temperature, top_k: 0, exaq: Some((bits, clip)) }
    }
}

/// Reusable sampling scratch (no allocation at steady state). The EXAQ
/// quantizer + LUT pair is cached keyed by (bits, clip), so decode loops
/// sampling at a fixed configuration never rebuild the tables per token.
#[derive(Default)]
pub struct SamplerScratch {
    probs: Vec<f32>,
    idx: Vec<usize>,
    algo2: Algo2Scratch,
    exaq_tables: Option<(u32, f32, Quantizer, LutExp, LutSum)>,
}

/// Sample one token id from `logits`.
pub fn sample(logits: &[f32], params: &SamplingParams,
              rng: &mut SplitMix64) -> i32 {
    let mut scratch = SamplerScratch::default();
    sample_with(logits, params, rng, &mut scratch)
}

/// Allocation-free variant for the decode loop.
pub fn sample_with(logits: &[f32], params: &SamplingParams,
                   rng: &mut SplitMix64,
                   scratch: &mut SamplerScratch) -> i32 {
    if params.temperature <= 0.0 {
        return argmax(logits);
    }
    let probs = &mut scratch.probs;
    probs.clear();
    probs.extend(logits.iter().map(|&x| x / params.temperature));

    match params.exaq {
        Some((bits, c)) => {
            let cached = matches!(&scratch.exaq_tables,
                                  Some((b, cc, ..))
                                  if *b == bits && *cc == c);
            if !cached {
                let q = Quantizer::new(bits, c);
                let le = LutExp::build(&q);
                let ls = LutSum::build(&q);
                scratch.exaq_tables = Some((bits, c, q, le, ls));
            }
            let (_, _, q, le, ls) =
                scratch.exaq_tables.as_ref().unwrap();
            let n = probs.len();
            softmax_algo2(probs, n, q, le, ls, &mut scratch.algo2);
        }
        None => softmax_exact(probs),
    }

    if params.top_k > 0 && params.top_k < probs.len() {
        let idx = &mut scratch.idx;
        idx.clear();
        idx.extend(0..probs.len());
        idx.sort_unstable_by(|&a, &b| {
            probs[b].partial_cmp(&probs[a]).unwrap()
        });
        for &i in &idx[params.top_k..] {
            probs[i] = 0.0;
        }
        let total: f32 = probs.iter().sum();
        if total > 0.0 {
            for p in probs.iter_mut() {
                *p /= total;
            }
        }
    }

    let u = rng.uniform() as f32;
    let mut acc = 0.0;
    for (i, &p) in probs.iter().enumerate() {
        acc += p;
        if u < acc {
            return i as i32;
        }
    }
    argmax(logits)
}

fn argmax(xs: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        let mut rng = SplitMix64::new(1);
        let logits = vec![0.1, 2.0, -1.0, 1.9];
        assert_eq!(sample(&logits, &SamplingParams::greedy(), &mut rng), 1);
    }

    #[test]
    fn temperature_sampling_respects_distribution() {
        let mut rng = SplitMix64::new(2);
        let logits = vec![0.0, 3.0];
        let params = SamplingParams { temperature: 1.0, top_k: 0,
                                      exaq: None };
        let n = 5000;
        let ones = (0..n)
            .filter(|_| sample(&logits, &params, &mut rng) == 1)
            .count();
        let frac = ones as f64 / n as f64;
        // p(1) = e^3/(1+e^3) ≈ 0.953
        assert!((frac - 0.953).abs() < 0.02, "{frac}");
    }

    #[test]
    fn top_k_masks_tail() {
        let mut rng = SplitMix64::new(3);
        let logits = vec![3.0, 2.9, -5.0, -6.0];
        let params = SamplingParams { temperature: 1.0, top_k: 2,
                                      exaq: None };
        for _ in 0..200 {
            let t = sample(&logits, &params, &mut rng);
            assert!(t == 0 || t == 1, "sampled masked token {t}");
        }
    }

    #[test]
    fn exaq_sampling_close_to_exact() {
        let mut rng = SplitMix64::new(4);
        let logits = vec![2.0, 1.5, 0.0, -1.0];
        let exact = SamplingParams { temperature: 1.0, top_k: 0,
                                     exaq: None };
        let quant = SamplingParams { temperature: 1.0, top_k: 0,
                                     exaq: Some((4, -8.0)) };
        let n = 4000;
        let mut counts = [[0usize; 4]; 2];
        for _ in 0..n {
            counts[0][sample(&logits, &exact, &mut rng) as usize] += 1;
            counts[1][sample(&logits, &quant, &mut rng) as usize] += 1;
        }
        for i in 0..4 {
            let a = counts[0][i] as f64 / n as f64;
            let b = counts[1][i] as f64 / n as f64;
            assert!((a - b).abs() < 0.05, "token {i}: {a} vs {b}");
        }
    }
}
