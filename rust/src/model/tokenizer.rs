//! Closed-vocabulary word tokenizer (manifest-driven).

use std::collections::BTreeMap;

use crate::util::error::{anyhow, Result};

/// Word-level tokenizer over the bundle vocabulary.
#[derive(Clone, Debug)]
pub struct Tokenizer {
    vocab: Vec<String>,
    index: BTreeMap<String, i32>,
    pub pad: i32,
    pub bos: i32,
    pub eos: i32,
    pub sep: i32,
}

impl Tokenizer {
    pub fn new(vocab: Vec<String>, pad: usize, bos: usize, eos: usize,
               sep: usize) -> Self {
        let index = vocab
            .iter()
            .enumerate()
            .map(|(i, w)| (w.clone(), i as i32))
            .collect();
        Self {
            vocab,
            index,
            pad: pad as i32,
            bos: bos as i32,
            eos: eos as i32,
            sep: sep as i32,
        }
    }

    pub fn from_manifest(m: &crate::runtime::Manifest) -> Self {
        Self::new(m.vocab.clone(), m.pad, m.bos, m.eos, m.sep)
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab.len()
    }

    pub fn id(&self, word: &str) -> Result<i32> {
        self.index
            .get(word)
            .copied()
            .ok_or_else(|| anyhow!("word '{word}' not in vocabulary"))
    }

    pub fn word(&self, id: i32) -> &str {
        self.vocab
            .get(id as usize)
            .map(String::as_str)
            .unwrap_or("<unk>")
    }

    /// Encode a whitespace-separated sentence.
    pub fn encode(&self, text: &str) -> Result<Vec<i32>> {
        text.split_whitespace().map(|w| self.id(w)).collect()
    }

    pub fn decode(&self, ids: &[i32]) -> String {
        ids.iter()
            .map(|&i| self.word(i))
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// `<bos>` + tokens, padded with `<pad>` to `len`. Errors if too long.
    pub fn pad_to(&self, tokens: &[i32], len: usize) -> Result<Vec<i32>> {
        if tokens.len() + 1 > len {
            return Err(anyhow!("sequence of {} tokens exceeds {len}",
                               tokens.len()));
        }
        let mut out = Vec::with_capacity(len);
        out.push(self.bos);
        out.extend_from_slice(tokens);
        out.resize(len, self.pad);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tok() -> Tokenizer {
        Tokenizer::new(
            vec!["<pad>".into(), "<bos>".into(), "<eos>".into(),
                 "<sep>".into(), "the".into(), "ball".into(), "is".into(),
                 "red".into()],
            0, 1, 2, 3,
        )
    }

    #[test]
    fn encode_decode_roundtrip() {
        let t = tok();
        let ids = t.encode("the ball is red").unwrap();
        assert_eq!(ids, vec![4, 5, 6, 7]);
        assert_eq!(t.decode(&ids), "the ball is red");
        assert!(t.encode("the zebra").is_err());
    }

    #[test]
    fn pad_to_shapes() {
        let t = tok();
        let ids = t.encode("the ball").unwrap();
        let p = t.pad_to(&ids, 6).unwrap();
        assert_eq!(p, vec![1, 4, 5, 0, 0, 0]);
        assert!(t.pad_to(&ids, 2).is_err());
    }
}
