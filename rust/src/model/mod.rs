//! Tokenizer + sampling — the model-adjacent utilities of the serving
//! stack.
//!
//! The tokenizer is the closed-vocabulary word tokenizer of the corpus
//! spec; the vocabulary itself ships in the manifest, so Rust never
//! hardcodes token ids (the world constants live in `eval::world`, which
//! cross-checks them against the golden dump).

pub mod sampling;
pub mod tokenizer;

pub use sampling::{sample, BatchSampler, SamplingParams};
pub use tokenizer::Tokenizer;
