//! lm-evaluation-harness-style scorer: batched log-likelihood of each
//! choice, argmax -> accuracy (Tables 2/4/5/6 of the paper).

use crate::util::error::Result;

use crate::model::Tokenizer;
use crate::runtime::{Engine, HostTensor, QuantMode};
use crate::util::rng::SplitMix64;

use super::tasks::{Instance, Task};
use super::world::World;

/// One (prompt, choice) scoring request flattened for batching.
struct Request {
    tokens: Vec<i32>,
    /// logits positions [start, start+len) predict the choice tokens.
    start: usize,
    len: usize,
    instance: usize,
    choice: usize,
}

/// Accuracy of one task under one quantization configuration.
#[derive(Clone, Debug)]
pub struct TaskResult {
    pub task: Task,
    pub accuracy: f64,
    pub n: usize,
}

/// Evaluate `task` on `n` instances. `c_vec` is required for Static
/// quant modes (computed by `exaq::clip` from calibration stats).
pub fn eval_task(engine: &mut Engine, model: &str, quant: QuantMode,
                 c_vec: Option<&[f32]>, task: Task, world: &World,
                 n: usize, seed: u64) -> Result<TaskResult> {
    let tok = Tokenizer::from_manifest(&engine.manifest);
    let seq = engine.manifest.seq;
    let mut rng = SplitMix64::new(seed ^ (task as u64).wrapping_mul(0x9e37));

    let mut instances = Vec::with_capacity(n);
    while instances.len() < n {
        let inst = task.generate(world, &mut rng);
        if fits(&inst, seq) {
            instances.push(inst);
        }
    }

    // flatten to requests
    let mut requests = Vec::new();
    for (ii, inst) in instances.iter().enumerate() {
        let prompt: Vec<i32> = inst
            .prompt
            .iter()
            .map(|w| tok.id(w))
            .collect::<Result<_>>()?;
        for (ci, choice) in inst.choices.iter().enumerate() {
            let choice_ids: Vec<i32> = choice
                .iter()
                .map(|w| tok.id(w))
                .collect::<Result<_>>()?;
            let mut tokens = prompt.clone();
            tokens.extend_from_slice(&choice_ids);
            let padded = tok.pad_to(&tokens, seq)?;
            requests.push(Request {
                tokens: padded,
                // with <bos> at index 0, logits index (1 + prompt_len - 1
                // + j) predicts choice token j
                start: prompt.len(),
                len: choice_ids.len(),
                instance: ii,
                choice: ci,
            });
        }
    }

    // batched prefill scoring (batch 8 artifacts; remainder via batch 1)
    let vocab = tok.vocab_size();
    let mut lls: Vec<Vec<f64>> = instances
        .iter()
        .map(|i| vec![f64::NEG_INFINITY; i.choices.len()])
        .collect();
    let mut i = 0;
    while i < requests.len() {
        let bsz = if requests.len() - i >= 8 { 8 } else { 1 };
        let chunk = &requests[i..i + bsz];
        let mut flat = Vec::with_capacity(bsz * seq);
        for r in chunk {
            flat.extend_from_slice(&r.tokens);
        }
        let tokens = HostTensor::i32(flat, &[bsz, seq]);
        let (logits, _) = engine.prefill(model, quant, &tokens, c_vec)?;
        let lg = logits.as_f32()?;
        for (bi, r) in chunk.iter().enumerate() {
            let mut total = 0.0f64;
            for j in 0..r.len {
                let pos = r.start + j;
                let row = &lg[(bi * seq + pos) * vocab
                    ..(bi * seq + pos + 1) * vocab];
                let target = r.tokens[pos + 1] as usize;
                total += log_softmax_at(row, target);
            }
            lls[r.instance][r.choice] = total / r.len as f64;
        }
        i += bsz;
    }

    let mut correct = 0usize;
    for (inst, ll) in instances.iter().zip(&lls) {
        let best = ll
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        if best == inst.gold {
            correct += 1;
        }
    }
    Ok(TaskResult {
        task,
        accuracy: correct as f64 / instances.len() as f64,
        n: instances.len(),
    })
}

fn fits(inst: &Instance, seq: usize) -> bool {
    let longest = inst.choices.iter().map(Vec::len).max().unwrap_or(0);
    1 + inst.prompt.len() + longest + 1 <= seq
}

fn log_softmax_at(row: &[f32], target: usize) -> f64 {
    let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    let mut sum = 0.0f64;
    for &x in row {
        sum += ((x as f64) - m).exp();
    }
    (row[target] as f64) - m - sum.ln()
}

/// Mean and population std over per-seed accuracies (Tables 4/6).
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n;
    (mean, var.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_softmax_is_normalised() {
        let row = vec![1.0f32, 2.0, 3.0];
        let total: f64 = (0..3).map(|t| log_softmax_at(&row, t).exp())
            .sum();
        assert!((total - 1.0).abs() < 1e-9);
        assert!(log_softmax_at(&row, 2) > log_softmax_at(&row, 0));
    }

    #[test]
    fn mean_std_basic() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((s - (2.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }
}
