//! The seven evaluation task families — the substitution for the paper's
//! BoolQ / HellaSwag / PIQA / WinoGrande / ARC-c / ARC-e / OpenBookQA
//! suite (DESIGN.md §2). Every task is multiple-choice and scored by
//! length-normalised log-likelihood, exactly like lm-evaluation-harness.
//!
//! Context-retrieval families (openbook, completion) prepend distractor
//! facts so the answer requires attention over competing keys — the
//! mechanism through which softmax-input quantization damages accuracy.

use crate::util::rng::SplitMix64;

use super::world::{
    hardness, material_prop, World, COLORS, NAMES, OBJECTS,
    PLACES, PROPERTIES,
};

/// One multiple-choice instance (word-level, pre-tokenizer).
#[derive(Clone, Debug)]
pub struct Instance {
    pub prompt: Vec<String>,
    pub choices: Vec<Vec<String>>,
    pub gold: usize,
}

/// The seven families, mapped to the paper's Table 2 columns.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Task {
    /// BoolQ analogue: yes/no colour question.
    BoolQa,
    /// HellaSwag analogue: location completion with distractor context.
    Completion,
    /// PIQA analogue: which of two objects is harder.
    Physical,
    /// WinoGrande analogue: pronoun-style property binding ("it is ...").
    Coref,
    /// ARC-Challenge analogue: two-hop property (object -> material ->
    /// property) WITHOUT the chain in context.
    ArcChallenge,
    /// ARC-Easy analogue: direct colour attribute.
    ArcEasy,
    /// OpenBookQA analogue: property chain stated in context, answer
    /// requires in-context retrieval under distraction.
    OpenBook,
}

pub const ALL_TASKS: [Task; 7] = [
    Task::BoolQa,
    Task::Completion,
    Task::Physical,
    Task::Coref,
    Task::ArcChallenge,
    Task::ArcEasy,
    Task::OpenBook,
];

impl Task {
    pub fn name(&self) -> &'static str {
        match self {
            Task::BoolQa => "bool-qa",
            Task::Completion => "completion",
            Task::Physical => "physical",
            Task::Coref => "coref",
            Task::ArcChallenge => "arc-challenge",
            Task::ArcEasy => "arc-easy",
            Task::OpenBook => "openbook",
        }
    }

    /// Paper column this family substitutes for.
    pub fn paper_column(&self) -> &'static str {
        match self {
            Task::BoolQa => "BoolQ",
            Task::Completion => "HellaSwag",
            Task::Physical => "PIQA",
            Task::Coref => "WinoGrande",
            Task::ArcChallenge => "ARC Challenge",
            Task::ArcEasy => "ARC Easy",
            Task::OpenBook => "OpenBookQA",
        }
    }

    /// Generate one instance.
    pub fn generate(&self, w: &World, rng: &mut SplitMix64) -> Instance {
        match self {
            Task::BoolQa => bool_qa(w, rng),
            Task::Completion => completion(w, rng),
            Task::Physical => physical(w, rng),
            Task::Coref => coref(w, rng),
            Task::ArcChallenge => arc_challenge(w, rng),
            Task::ArcEasy => arc_easy(w, rng),
            Task::OpenBook => openbook(w, rng),
        }
    }
}

fn words(v: &[&str]) -> Vec<String> {
    v.iter().map(|s| s.to_string()).collect()
}

fn bool_qa(w: &World, rng: &mut SplitMix64) -> Instance {
    let obj = rng.below(OBJECTS.len());
    let mut color = rng.below(COLORS.len());
    if rng.below(2) == 0 {
        color = w.color[obj];
    }
    let truth = w.color[obj] == color;
    Instance {
        prompt: words(&["question", ":", "is", "the", OBJECTS[obj],
                        COLORS[color], "?", "answer", ":"]),
        choices: vec![words(&["yes"]), words(&["no"])],
        gold: if truth { 0 } else { 1 },
    }
}

fn completion(w: &World, rng: &mut SplitMix64) -> Instance {
    // distractor people + their places, then the query person
    let p = rng.below(NAMES.len());
    let mut prompt = Vec::new();
    for _ in 0..2 {
        let mut q = rng.below(NAMES.len());
        while q == p {
            q = rng.below(NAMES.len());
        }
        prompt.extend(words(&[NAMES[q], "is", "in", "the",
                              PLACES[w.place[q]], "."]));
    }
    prompt.extend(words(&[NAMES[p], "is", "in", "the"]));
    let gold_place = w.place[p];
    let mut choices = vec![words(&[PLACES[gold_place]])];
    let mut used = vec![gold_place];
    while choices.len() < 4 {
        let c = rng.below(PLACES.len());
        if !used.contains(&c) {
            used.push(c);
            choices.push(words(&[PLACES[c]]));
        }
    }
    Instance { prompt, choices, gold: 0 }
}

fn physical(w: &World, rng: &mut SplitMix64) -> Instance {
    let a = rng.below(OBJECTS.len());
    let mut b = rng.below(OBJECTS.len());
    while w.object_hardness(a) == w.object_hardness(b) {
        b = rng.below(OBJECTS.len());
    }
    let winner = if w.object_hardness(a) > w.object_hardness(b) { 0 }
                 else { 1 };
    Instance {
        prompt: words(&["question", ":", "which", "is", "harder", ":",
                        OBJECTS[a], "or", OBJECTS[b], "?", "answer", ":"]),
        choices: vec![words(&[OBJECTS[a]]), words(&[OBJECTS[b]])],
        gold: winner,
    }
}

fn coref(w: &World, rng: &mut SplitMix64) -> Instance {
    let p = rng.below(NAMES.len());
    let obj = w.owned[p];
    let right = w.color[obj];
    let mut wrong = rng.below(COLORS.len());
    while wrong == right {
        wrong = rng.below(COLORS.len());
    }
    // 2-choice, randomised order like WinoGrande
    let flip = rng.below(2) == 1;
    let (c0, c1, gold) = if flip {
        (COLORS[wrong], COLORS[right], 1)
    } else {
        (COLORS[right], COLORS[wrong], 0)
    };
    Instance {
        prompt: words(&[NAMES[p], "has", "the", OBJECTS[obj], ".", "it",
                        "is"]),
        choices: vec![words(&[c0]), words(&[c1])],
        gold,
    }
}

fn arc_challenge(w: &World, rng: &mut SplitMix64) -> Instance {
    // two-hop: object -> material -> property, no chain in context
    let obj = rng.below(OBJECTS.len());
    let gold_prop = w.object_property(obj);
    let mut choices = vec![words(&[gold_prop])];
    let mut used = vec![gold_prop];
    while choices.len() < 4 {
        let c = PROPERTIES[rng.below(PROPERTIES.len())];
        if !used.contains(&c) {
            used.push(c);
            choices.push(words(&[c]));
        }
    }
    Instance {
        prompt: words(&["the", OBJECTS[obj], "is"]),
        choices,
        gold: 0,
    }
}

fn arc_easy(w: &World, rng: &mut SplitMix64) -> Instance {
    let obj = rng.below(OBJECTS.len());
    let gold_color = w.color[obj];
    let mut choices = vec![words(&[COLORS[gold_color]])];
    let mut used = vec![gold_color];
    while choices.len() < 4 {
        let c = rng.below(COLORS.len());
        if !used.contains(&c) {
            used.push(c);
            choices.push(words(&[COLORS[c]]));
        }
    }
    Instance {
        prompt: words(&["the", OBJECTS[obj], "is"]),
        choices,
        gold: 0,
    }
}

fn openbook(w: &World, rng: &mut SplitMix64) -> Instance {
    // distractor chains for other objects, then the query object's chain
    // WITHOUT its conclusion — in-context retrieval under distraction.
    let obj = rng.below(OBJECTS.len());
    let mut prompt = Vec::new();
    let mut used = vec![obj];
    for _ in 0..2 {
        let mut o = rng.below(OBJECTS.len());
        while used.contains(&o) {
            o = rng.below(OBJECTS.len());
        }
        used.push(o);
        let m = w.object_material(o);
        prompt.extend(words(&["the", OBJECTS[o], "is", "made", "of", m,
                              ".", m, "is", material_prop(w.material[o]),
                              "."]));
    }
    let m = w.object_material(obj);
    let gold_prop = w.object_property(obj);
    prompt.extend(words(&["the", OBJECTS[obj], "is", "made", "of", m, ".",
                          m, "is", gold_prop, ".", "the", OBJECTS[obj],
                          "is"]));
    let mut choices = vec![words(&[gold_prop])];
    let mut usedp = vec![gold_prop];
    while choices.len() < 4 {
        let c = PROPERTIES[rng.below(PROPERTIES.len())];
        if !usedp.contains(&c) {
            usedp.push(c);
            choices.push(words(&[c]));
        }
    }
    Instance { prompt, choices, gold: 0 }
}

// keep clippy quiet about the unused import when tests are off
#[allow(unused_imports)]
use hardness as _hardness_used;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn instances_are_well_formed() {
        let w = World::build(1);
        let mut rng = SplitMix64::new(9);
        for task in ALL_TASKS {
            for _ in 0..50 {
                let inst = task.generate(&w, &mut rng);
                assert!(!inst.prompt.is_empty());
                assert!(inst.choices.len() >= 2);
                assert!(inst.gold < inst.choices.len());
                // choices distinct
                for i in 0..inst.choices.len() {
                    for j in i + 1..inst.choices.len() {
                        assert_ne!(inst.choices[i], inst.choices[j],
                                   "{:?}", task);
                    }
                }
                // prompt fits the model context with room for a choice
                assert!(inst.prompt.len() + 3 <= 63,
                        "{:?} prompt too long: {}", task,
                        inst.prompt.len());
            }
        }
    }

    #[test]
    fn gold_answers_are_correct_facts() {
        let w = World::build(1);
        let mut rng = SplitMix64::new(11);
        for _ in 0..50 {
            let inst = Task::ArcEasy.generate(&w, &mut rng);
            // choice[gold] is the actual colour of the object in prompt
            let obj_word = &inst.prompt[1];
            let obj = OBJECTS.iter().position(|o| o == obj_word).unwrap();
            assert_eq!(inst.choices[inst.gold][0], w.object_color(obj));
        }
        for _ in 0..50 {
            let inst = Task::Physical.generate(&w, &mut rng);
            let a = OBJECTS.iter()
                .position(|o| *o == inst.prompt[6]).unwrap();
            let b = OBJECTS.iter()
                .position(|o| *o == inst.prompt[8]).unwrap();
            let winner_word = &inst.choices[inst.gold][0];
            let winner = OBJECTS.iter()
                .position(|o| o == winner_word).unwrap();
            assert!(winner == a || winner == b);
            let loser = if winner == a { b } else { a };
            assert!(w.object_hardness(winner) > w.object_hardness(loser));
        }
    }

    #[test]
    fn generation_is_seed_deterministic() {
        let w = World::build(1);
        let mut a = SplitMix64::new(5);
        let mut b = SplitMix64::new(5);
        for task in ALL_TASKS {
            let ia = task.generate(&w, &mut a);
            let ib = task.generate(&w, &mut b);
            assert_eq!(ia.prompt, ib.prompt);
            assert_eq!(ia.gold, ib.gold);
        }
    }

    #[test]
    fn coref_gold_position_varies() {
        let w = World::build(1);
        let mut rng = SplitMix64::new(13);
        let golds: Vec<usize> = (0..40)
            .map(|_| Task::Coref.generate(&w, &mut rng).gold)
            .collect();
        assert!(golds.iter().any(|&g| g == 0));
        assert!(golds.iter().any(|&g| g == 1));
    }
}
