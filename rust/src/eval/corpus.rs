//! Sentence sampler — mirror of `corpus.py::sample_sentence` /
//! `generate_tokens` (template ids and RNG call order are part of the
//! cross-language spec; the `corpus_prefix` field of the golden dump pins
//! it).

use crate::model::Tokenizer;
use crate::util::rng::SplitMix64;

use super::world::{
    material_prop, World, COLORS, MATERIALS, NAMES, OBJECTS, PLACES,
};

const N_TEMPLATES: usize = 11;

/// One sampled sentence, as words.
pub fn sample_sentence(w: &World, rng: &mut SplitMix64) -> Vec<String> {
    let s = |v: Vec<&str>| v.into_iter().map(str::to_string).collect();
    match rng.below(N_TEMPLATES) {
        0 => {
            let o = rng.below(OBJECTS.len());
            s(vec!["the", OBJECTS[o], "is", w.object_color(o), "."])
        }
        1 => {
            let o = rng.below(OBJECTS.len());
            s(vec!["the", OBJECTS[o], "is", "made", "of",
                   w.object_material(o), "."])
        }
        2 => {
            let m = rng.below(MATERIALS.len());
            s(vec![MATERIALS[m], "is", material_prop(m), "."])
        }
        3 => {
            let p = rng.below(NAMES.len());
            s(vec![NAMES[p], "is", "in", "the", PLACES[w.place[p]], "."])
        }
        4 => {
            let p = rng.below(NAMES.len());
            s(vec![NAMES[p], "has", "the", OBJECTS[w.owned[p]], "."])
        }
        5 => {
            let p = rng.below(NAMES.len());
            s(vec!["the", OBJECTS[w.owned[p]], "belongs", "to", NAMES[p],
                   "."])
        }
        6 => {
            let a = rng.below(OBJECTS.len());
            let mut b = rng.below(OBJECTS.len());
            while w.object_hardness(a) == w.object_hardness(b) {
                b = rng.below(OBJECTS.len());
            }
            let (hi, lo) = if w.object_hardness(a) > w.object_hardness(b) {
                (a, b)
            } else {
                (b, a)
            };
            s(vec!["the", OBJECTS[hi], "is", "harder", "than", "the",
                   OBJECTS[lo], "."])
        }
        7 => {
            let o = rng.below(OBJECTS.len());
            let mut color = rng.below(COLORS.len());
            if rng.below(2) == 0 {
                color = w.color[o];
            }
            let ans = if w.color[o] == color { "yes" } else { "no" };
            s(vec!["question", ":", "is", "the", OBJECTS[o],
                   COLORS[color], "?", "answer", ":", ans, "."])
        }
        8 => {
            let a = rng.below(OBJECTS.len());
            let mut b = rng.below(OBJECTS.len());
            while w.object_hardness(a) == w.object_hardness(b) {
                b = rng.below(OBJECTS.len());
            }
            let winner = if w.object_hardness(a) > w.object_hardness(b) {
                a
            } else {
                b
            };
            s(vec!["question", ":", "which", "is", "harder", ":",
                   OBJECTS[a], "or", OBJECTS[b], "?", "answer", ":",
                   OBJECTS[winner], "."])
        }
        9 => {
            let p = rng.below(NAMES.len());
            let o = w.owned[p];
            s(vec![NAMES[p], "has", "the", OBJECTS[o], ".", "it", "is",
                   w.object_color(o), "."])
        }
        _ => {
            let o = rng.below(OBJECTS.len());
            let m = w.object_material(o);
            let pr = w.object_property(o);
            s(vec!["the", OBJECTS[o], "is", "made", "of", m, ".", m,
                   "is", pr, ".", "the", OBJECTS[o], "is", pr, "."])
        }
    }
}

/// Token stream mirroring corpus.py::generate_tokens.
pub fn generate_tokens(w: &World, tok: &Tokenizer, corpus_seed: u64,
                       n_tokens: usize) -> Vec<i32> {
    let mut rng = SplitMix64::new(corpus_seed);
    let mut out = vec![tok.bos];
    let mut sent_in_doc = 0;
    while out.len() < n_tokens {
        for word in sample_sentence(w, &mut rng) {
            out.push(tok.id(&word).expect("corpus word in vocab"));
        }
        sent_in_doc += 1;
        if sent_in_doc == 8 {
            out.push(tok.sep);
            sent_in_doc = 0;
        }
    }
    out.truncate(n_tokens);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;
    use std::path::Path;

    #[test]
    fn corpus_prefix_matches_python_golden() {
        let p = Path::new(concat!(env!("CARGO_MANIFEST_DIR"),
                                  "/artifacts/world_family1.json"));
        if !p.exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let j = Json::parse(&std::fs::read_to_string(p).unwrap()).unwrap();
        let vocab = j.get("vocab").unwrap().as_str_vec().unwrap();
        let tok = Tokenizer::new(vocab, 0, 1, 2, 3);
        let seed = j.get("seed").unwrap().as_usize().unwrap() as u64;
        let w = World::build(seed);
        let want: Vec<i32> = j.get("corpus_prefix").unwrap()
            .as_f64_vec().unwrap().into_iter().map(|x| x as i32)
            .collect();
        let got = generate_tokens(&w, &tok, seed + 1, want.len());
        assert_eq!(got, want, "corpus sampler diverged from python spec");
    }

    #[test]
    fn tokens_deterministic_and_in_range() {
        let w = World::build(1);
        let vocab: Vec<String> = {
            // minimal vocab: build from the known layout
            let mut v: Vec<String> =
                ["<pad>", "<bos>", "<eos>", "<sep>"]
                    .iter().map(|s| s.to_string()).collect();
            for w_ in NAMES.iter().chain(OBJECTS.iter())
                .chain(PLACES.iter()).chain(COLORS.iter())
                .chain(MATERIALS.iter())
                .chain(super::super::world::PROPERTIES.iter())
                .chain(["the", "is", "in", "has", "made", "of", "than",
                        "harder", "softer", "question", "answer", "yes",
                        "no", "it", "belongs", "to", "a", "which", "or",
                        ".", "?", ":"].iter())
            {
                v.push(w_.to_string());
            }
            v
        };
        let tok = Tokenizer::new(vocab, 0, 1, 2, 3);
        let a = generate_tokens(&w, &tok, 7, 300);
        let b = generate_tokens(&w, &tok, 7, 300);
        assert_eq!(a, b);
        assert_eq!(a[0], tok.bos);
        assert!(a.iter().all(|&t| (t as usize) < tok.vocab_size()));
    }
}
