//! Evaluation stack: procedural world, corpus sampler, seven task
//! families, and the lm-eval-style scoring harness (paper §5.1).

pub mod corpus;
pub mod harness;
pub mod tasks;
pub mod world;

pub use harness::{eval_task, mean_std, TaskResult};
pub use tasks::{Task, ALL_TASKS};
pub use world::World;

/// World seeds per family (mirror of train.py FAMILY_WORLD_SEED).
pub fn family_world_seed(family: u32) -> u64 {
    match family {
        1 => 1,
        2 => 7,
        other => panic!("unknown family {other}"),
    }
}
