//! The procedural world — bit-for-bit mirror of
//! `python/compile/corpus.py` (derivation order is part of the spec; the
//! golden dump `artifacts/world_family*.json` pins both sides).

use crate::util::rng::SplitMix64;

pub const NAMES: [&str; 20] = [
    "alice", "bob", "carol", "david", "erin", "frank", "grace", "henry",
    "iris", "jack", "karen", "leo", "mona", "nina", "oscar", "paul",
    "quinn", "rosa", "sam", "tina",
];
pub const OBJECTS: [&str; 24] = [
    "ball", "cup", "book", "knife", "hammer", "pillow", "bottle", "lamp",
    "chair", "rope", "coin", "plate", "shirt", "box", "mirror", "brick",
    "blanket", "spoon", "vase", "drum", "kite", "glove", "candle",
    "basket",
];
pub const PLACES: [&str; 12] = [
    "kitchen", "garden", "library", "garage", "park", "office", "attic",
    "cellar", "market", "station", "museum", "bakery",
];
pub const COLORS: [&str; 8] =
    ["red", "blue", "green", "yellow", "black", "white", "purple",
     "orange"];
pub const MATERIALS: [&str; 8] = [
    "wood", "metal", "glass", "stone", "cloth", "plastic", "rubber",
    "paper",
];
pub const PROPERTIES: [&str; 6] =
    ["hard", "soft", "fragile", "sturdy", "heavy", "light"];

/// material index -> characteristic property.
pub fn material_prop(mat: usize) -> &'static str {
    ["sturdy", "heavy", "fragile", "hard", "soft", "light", "soft",
     "fragile"][mat]
}

/// material index -> hardness rank (higher = harder).
pub fn hardness(mat: usize) -> u32 {
    [5, 6, 4, 7, 0, 3, 2, 1][mat]
}

/// World-fact assignments (see corpus.py `build_world`).
#[derive(Clone, Debug)]
pub struct World {
    pub seed: u64,
    pub color: Vec<usize>,
    pub material: Vec<usize>,
    pub owned: Vec<usize>,
    pub place: Vec<usize>,
}

impl World {
    pub fn build(seed: u64) -> World {
        let mut rng = SplitMix64::new(seed);
        let mut color = Vec::with_capacity(OBJECTS.len());
        let mut material = Vec::with_capacity(OBJECTS.len());
        for _ in 0..OBJECTS.len() {
            color.push(rng.below(COLORS.len()));
            material.push(rng.below(MATERIALS.len()));
        }
        let mut perm: Vec<usize> = (0..OBJECTS.len()).collect();
        for i in (1..OBJECTS.len()).rev() {
            let j = rng.below(i + 1);
            perm.swap(i, j);
        }
        let owned = perm[..NAMES.len()].to_vec();
        let place =
            (0..NAMES.len()).map(|_| rng.below(PLACES.len())).collect();
        World { seed, color, material, owned, place }
    }

    pub fn object_color(&self, obj: usize) -> &'static str {
        COLORS[self.color[obj]]
    }

    pub fn object_material(&self, obj: usize) -> &'static str {
        MATERIALS[self.material[obj]]
    }

    pub fn object_property(&self, obj: usize) -> &'static str {
        material_prop(self.material[obj])
    }

    pub fn object_hardness(&self, obj: usize) -> u32 {
        hardness(self.material[obj])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;
    use std::path::Path;

    #[test]
    fn world_is_deterministic() {
        let a = World::build(1);
        let b = World::build(1);
        assert_eq!(a.color, b.color);
        assert_eq!(a.owned, b.owned);
        let c = World::build(2);
        assert_ne!(a.color, c.color);
    }

    #[test]
    fn ownership_is_injective() {
        let w = World::build(1);
        let mut seen = std::collections::HashSet::new();
        for &o in &w.owned {
            assert!(seen.insert(o), "object {o} owned twice");
        }
    }

    #[test]
    fn matches_python_golden_dump() {
        // The cross-language contract: artifacts/world_family1.json was
        // derived by corpus.py; our derivation must agree exactly.
        let p = Path::new(concat!(env!("CARGO_MANIFEST_DIR"),
                                  "/artifacts/world_family1.json"));
        if !p.exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let j = Json::parse(&std::fs::read_to_string(p).unwrap()).unwrap();
        let seed = j.get("seed").unwrap().as_usize().unwrap() as u64;
        let w = World::build(seed);
        let as_usize = |key: &str| -> Vec<usize> {
            j.get(key).unwrap().as_f64_vec().unwrap()
                .into_iter().map(|x| x as usize).collect()
        };
        assert_eq!(w.color, as_usize("color"));
        assert_eq!(w.material, as_usize("material"));
        assert_eq!(w.owned, as_usize("owned"));
        assert_eq!(w.place, as_usize("place"));
        // vocab layout agrees with the tokenizer's expectations
        let vocab = j.get("vocab").unwrap().as_str_vec().unwrap();
        assert_eq!(vocab[4], NAMES[0]);
        assert_eq!(vocab[4 + NAMES.len()], OBJECTS[0]);
    }
}
