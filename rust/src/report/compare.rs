//! Bench-regression comparison over two `BENCH_*.json` documents.
//!
//! The gate behind `repro compare <baseline> <current>`: result
//! records are keyed by their identity fields (kind / scenario / rows
//! / len / bits / group / kernel / mode — whichever are present), the
//! timing metrics of matching cells are diffed, and any cell whose
//! metric grew by more than the threshold (default
//! [`DEFAULT_THRESHOLD`] = 10%) is a regression. All tracked metrics
//! are lower-is-better wall times, so "grew" means "got slower".
//!
//! The comparison itself is pure (JSON in, report out) so it can be
//! unit-tested without touching the filesystem; `main.rs` owns file
//! IO, exit codes, and the soft/hard gate toggle.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::json::Json;

/// Default allowed slowdown before a cell counts as a regression.
pub const DEFAULT_THRESHOLD: f64 = 0.10;

/// Fields that identify a result cell (joined into the match key in
/// this order; absent fields are skipped so schemas can differ).
pub const KEY_FIELDS: &[&str] = &[
    "kind", "scenario", "rows", "len", "bits", "group", "kernel",
    "mode", "d_head", "replicas", "replica",
];

/// Lower-is-better timing metrics eligible for the gate. Derived
/// ratios (speedups) are deliberately not compared — they move
/// whenever either side of the division does.
pub const METRICS: &[&str] = &[
    "algo1_us", "scalar_us", "batched_us", "baseline_us", "host_s",
    "scalar_host_s", "batched_host_s", "fused_us", "two_step_us",
    "streaming_us",
];

/// One metric of one matched cell, baseline vs current.
#[derive(Clone, Debug)]
pub struct MetricDiff {
    pub metric: &'static str,
    pub base: f64,
    pub current: f64,
    /// Relative change: `(current - base) / base`. Positive = slower.
    pub ratio: f64,
}

/// All compared metrics of one matched cell.
#[derive(Clone, Debug)]
pub struct CellDiff {
    /// Human-readable identity, e.g. `bits=2 rows=64 len=256`.
    pub key: String,
    pub diffs: Vec<MetricDiff>,
}

/// The full comparison of two bench documents.
#[derive(Clone, Debug)]
pub struct CompareReport {
    pub bench: String,
    pub threshold: f64,
    pub cells: Vec<CellDiff>,
    /// Baseline cells with no counterpart in the current run — the
    /// gate treats vanished coverage as a failure, not a pass.
    pub missing: Vec<String>,
}

impl CompareReport {
    /// Every (cell key, metric diff) beyond the threshold.
    pub fn regressions(&self) -> Vec<(&str, &MetricDiff)> {
        let mut out = Vec::new();
        for cell in &self.cells {
            for d in &cell.diffs {
                if d.ratio > self.threshold {
                    out.push((cell.key.as_str(), d));
                }
            }
        }
        out
    }

    /// True when the gate should fail: a regressed metric or a
    /// baseline cell that disappeared from the current run.
    pub fn failed(&self) -> bool {
        !self.missing.is_empty() || !self.regressions().is_empty()
    }

    /// Render the human-readable gate report.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "bench '{}': {} matched cells, threshold {:.0}%",
            self.bench,
            self.cells.len(),
            100.0 * self.threshold
        );
        let regs = self.regressions();
        for (key, d) in &regs {
            let _ = writeln!(
                out,
                "  REGRESSION {key}: {} {:.3} -> {:.3} ({:+.1}%)",
                d.metric, d.base, d.current, 100.0 * d.ratio
            );
        }
        for key in &self.missing {
            let _ = writeln!(
                out,
                "  MISSING {key}: in baseline, absent from current"
            );
        }
        if regs.is_empty() && self.missing.is_empty() {
            let best = self
                .cells
                .iter()
                .flat_map(|c| c.diffs.iter())
                .map(|d| d.ratio)
                .fold(f64::INFINITY, f64::min);
            if best.is_finite() {
                let _ = writeln!(
                    out,
                    "  ok — no regressions (best delta {:+.1}%)",
                    100.0 * best
                );
            } else {
                let _ = writeln!(out, "  ok — no shared metrics");
            }
        }
        out
    }

    /// Render the full comparison as a GitHub-flavoured markdown
    /// table: one row per (cell, metric) with baseline, current,
    /// delta, and status. Unlike [`Self::render`] (which only prints
    /// problems), every compared metric gets a row, so the output is
    /// paste-ready for PR descriptions. Purely presentational — the
    /// pass/fail contract stays with [`Self::failed`].
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "### bench `{}` — {} matched cells, threshold {:.0}%",
            self.bench,
            self.cells.len(),
            100.0 * self.threshold
        );
        let _ = writeln!(out);
        let _ = writeln!(
            out,
            "| cell | metric | baseline | current | delta | status |"
        );
        let _ =
            writeln!(out, "|---|---|---:|---:|---:|---|");
        for cell in &self.cells {
            for d in &cell.diffs {
                let status = if d.ratio > self.threshold {
                    "**REGRESSION**"
                } else if d.ratio < 0.0 {
                    "faster"
                } else {
                    "ok"
                };
                let _ = writeln!(
                    out,
                    "| {} | {} | {:.3} | {:.3} | {:+.1}% | {} |",
                    cell.key, d.metric, d.base, d.current,
                    100.0 * d.ratio, status
                );
            }
        }
        for key in &self.missing {
            let _ = writeln!(
                out,
                "| {key} | — | — | — | — | **MISSING** |"
            );
        }
        let _ = writeln!(out);
        let verdict = if self.failed() { "FAIL" } else { "PASS" };
        let _ = writeln!(out, "verdict: **{verdict}**");
        out
    }
}

/// Identity key of one result record (present [`KEY_FIELDS`] joined).
fn cell_key(rec: &Json) -> String {
    let mut parts = Vec::new();
    for &field in KEY_FIELDS {
        let Some(v) = rec.get(field) else { continue };
        let rendered = match v {
            Json::Str(s) => s.clone(),
            _ => match v.as_f64() {
                Some(x) if x.fract() == 0.0 => {
                    format!("{}", x as i64)
                }
                Some(x) => format!("{x}"),
                None => continue,
            },
        };
        parts.push(format!("{field}={rendered}"));
    }
    if parts.is_empty() {
        "<unkeyed>".to_string()
    } else {
        parts.join(" ")
    }
}

fn results_of(doc: &Json) -> Result<&[Json], String> {
    doc.get("results")
        .and_then(Json::as_arr)
        .ok_or_else(|| "document has no 'results' array".to_string())
}

/// Compare two parsed bench documents. Errors only on structurally
/// invalid documents (no `results` array); schema drift between the
/// two sides degrades to fewer shared metrics, not an error.
pub fn compare(baseline: &Json, current: &Json, threshold: f64)
               -> Result<CompareReport, String> {
    let bench = baseline
        .get("bench")
        .and_then(Json::as_str)
        .unwrap_or("?")
        .to_string();
    let base_rows = results_of(baseline)?;
    let cur_rows = results_of(current)?;

    let mut cur_by_key: BTreeMap<String, &Json> = BTreeMap::new();
    for rec in cur_rows {
        // last record wins on duplicate keys — benches emit unique
        // cells, so this only matters for malformed inputs
        cur_by_key.insert(cell_key(rec), rec);
    }

    let mut cells = Vec::new();
    let mut missing = Vec::new();
    for rec in base_rows {
        let key = cell_key(rec);
        let Some(cur) = cur_by_key.get(&key) else {
            missing.push(key);
            continue;
        };
        let mut diffs = Vec::new();
        for &metric in METRICS {
            let (Some(b), Some(c)) = (
                rec.get(metric).and_then(Json::as_f64),
                cur.get(metric).and_then(Json::as_f64),
            ) else {
                continue;
            };
            let ratio = (c - b) / b.max(1e-12);
            diffs.push(MetricDiff { metric, base: b, current: c,
                                    ratio });
        }
        cells.push(CellDiff { key, diffs });
    }
    Ok(CompareReport { bench, threshold, cells, missing })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc(rows: &[&str]) -> Json {
        let body = format!(
            "{{\"bench\":\"softmax\",\"meta\":{{}},\"results\":[{}]}}",
            rows.join(",")
        );
        Json::parse(&body).unwrap()
    }

    #[test]
    fn identical_documents_pass() {
        let d = doc(&[
            "{\"bits\":2,\"rows\":64,\"len\":256,\"batched_us\":10.0}",
        ]);
        let r = compare(&d, &d, DEFAULT_THRESHOLD).unwrap();
        assert!(!r.failed());
        assert_eq!(r.cells.len(), 1);
        assert_eq!(r.cells[0].key, "rows=64 len=256 bits=2");
        assert!(r.render().contains("ok — no regressions"));
    }

    #[test]
    fn slowdown_beyond_threshold_fails_but_speedup_passes() {
        let base = doc(&[
            "{\"bits\":2,\"batched_us\":10.0,\"scalar_us\":40.0}",
            "{\"bits\":3,\"batched_us\":20.0}",
        ]);
        let cur = doc(&[
            "{\"bits\":2,\"batched_us\":11.5,\"scalar_us\":20.0}",
            "{\"bits\":3,\"batched_us\":21.0}",
        ]);
        let r = compare(&base, &cur, 0.10).unwrap();
        let regs = r.regressions();
        // bits=2 batched 10 -> 11.5 is +15%: regression. The 2x
        // scalar speedup and the +5% bits=3 drift are fine.
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].0, "bits=2");
        assert_eq!(regs[0].1.metric, "batched_us");
        assert!((regs[0].1.ratio - 0.15).abs() < 1e-9);
        assert!(r.failed());
        assert!(r.render().contains("REGRESSION bits=2"));
        // a looser threshold lets the same delta through
        assert!(!compare(&base, &cur, 0.20).unwrap().failed());
    }

    #[test]
    fn vanished_baseline_cell_fails_the_gate() {
        let base = doc(&[
            "{\"bits\":2,\"batched_us\":10.0}",
            "{\"bits\":4,\"batched_us\":12.0}",
        ]);
        let cur = doc(&["{\"bits\":2,\"batched_us\":10.0}"]);
        let r = compare(&base, &cur, DEFAULT_THRESHOLD).unwrap();
        assert_eq!(r.missing, vec!["bits=4".to_string()]);
        assert!(r.failed());
        assert!(r.render().contains("MISSING bits=4"));
        // extra current-only cells are NOT a failure
        let widened =
            compare(&cur, &base, DEFAULT_THRESHOLD).unwrap();
        assert!(!widened.failed());
    }

    #[test]
    fn zero_baseline_metric_does_not_divide_by_zero() {
        let base = doc(&["{\"bits\":2,\"batched_us\":0.0}"]);
        let cur = doc(&["{\"bits\":2,\"batched_us\":1.0}"]);
        let r = compare(&base, &cur, DEFAULT_THRESHOLD).unwrap();
        assert!(r.cells[0].diffs[0].ratio.is_finite());
        assert!(r.failed(), "growth from zero is a regression");
    }

    #[test]
    fn schema_drift_and_bad_documents() {
        // disjoint metrics -> no shared diffs, gate passes
        let base = doc(&["{\"bits\":2,\"algo1_us\":5.0}"]);
        let cur = doc(&["{\"bits\":2,\"host_s\":0.5}"]);
        let r = compare(&base, &cur, DEFAULT_THRESHOLD).unwrap();
        assert!(!r.failed());
        assert!(r.cells[0].diffs.is_empty());
        // structurally invalid input errors instead of passing
        let bad = Json::parse("{\"bench\":\"x\"}").unwrap();
        assert!(compare(&bad, &cur, DEFAULT_THRESHOLD).is_err());
        assert!(compare(&base, &bad, DEFAULT_THRESHOLD).is_err());
    }

    #[test]
    fn markdown_render_tables_every_metric_and_the_verdict() {
        let base = doc(&[
            "{\"bits\":2,\"batched_us\":10.0,\"streaming_us\":8.0}",
        ]);
        let cur = doc(&[
            "{\"bits\":2,\"batched_us\":12.0,\"streaming_us\":7.0}",
        ]);
        let r = compare(&base, &cur, 0.10).unwrap();
        let md = r.render_markdown();
        // header + alignment row, one row per metric, verdict line
        assert!(md.contains(
            "| cell | metric | baseline | current | delta | status |"
        ));
        assert!(md.contains(
            "| bits=2 | batched_us | 10.000 | 12.000 | +20.0% | \
             **REGRESSION** |"
        ));
        assert!(md.contains(
            "| bits=2 | streaming_us | 8.000 | 7.000 | -12.5% | \
             faster |"
        ));
        assert!(md.contains("verdict: **FAIL**"));
        // a clean compare renders PASS and no regression rows
        let ok = compare(&base, &base, 0.10).unwrap();
        let md = ok.render_markdown();
        assert!(md.contains("verdict: **PASS**"));
        assert!(!md.contains("REGRESSION"));
        // vanished cells still surface in the table
        let shrunk = doc(&["{\"bits\":3,\"batched_us\":1.0}"]);
        let miss = compare(&base, &shrunk, 0.10).unwrap();
        assert!(miss
            .render_markdown()
            .contains("| bits=2 | — | — | — | — | **MISSING** |"));
    }

    #[test]
    fn string_and_float_keys_render_stably() {
        let base = doc(&[
            "{\"scenario\":\"burst\",\"mode\":\"batched\",\
             \"host_s\":1.0}",
        ]);
        let r = compare(&base, &base, DEFAULT_THRESHOLD).unwrap();
        assert_eq!(r.cells[0].key, "scenario=burst mode=batched");
        let frac = doc(&["{\"rows\":1.5,\"host_s\":1.0}"]);
        let rf = compare(&frac, &frac, DEFAULT_THRESHOLD).unwrap();
        assert_eq!(rf.cells[0].key, "rows=1.5");
    }
}
