//! Rendering of experiment outputs: markdown tables and CSV series —
//! every bench/example funnels its rows through here so EXPERIMENTS.md
//! entries are regenerated in a uniform format.

use std::fmt::Write as _;

/// A simple column-aligned markdown table builder.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(),
                   "row arity != header arity");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn to_markdown(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> =
            self.headers.iter().map(String::len).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "### {}\n", self.title);
        }
        let line = |cells: &[String], out: &mut String| {
            let mut s = String::from("|");
            for i in 0..ncol {
                let _ = write!(s, " {:width$} |", cells[i],
                               width = widths[i]);
            }
            let _ = writeln!(out, "{s}");
        };
        line(&self.headers, &mut out);
        let sep: Vec<String> =
            widths.iter().map(|w| "-".repeat(*w)).collect();
        line(&sep, &mut out);
        for r in &self.rows {
            line(r, &mut out);
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = self.headers.join(",");
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a float with fixed decimals (bench output convention).
pub fn f(x: f64, decimals: usize) -> String {
    format!("{x:.decimals$}")
}

/// Format a percentage.
pub fn pct(x: f64) -> String {
    format!("{:.1}%", 100.0 * x)
}

/// Write a CSV series next to the bench output for plotting.
pub fn write_csv(path: &str, table: &Table) -> std::io::Result<()> {
    if let Some(parent) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, table.to_csv())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = Table::new("Demo", &["name", "value"]);
        t.row(&["short".into(), "1".into()]);
        t.row(&["a-much-longer-name".into(), "2.5".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| a-much-longer-name | 2.5   |"));
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(&["1".into()]);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(f(1.23456, 2), "1.23");
        assert_eq!(pct(0.369), "36.9%");
    }
}
