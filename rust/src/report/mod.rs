//! Rendering of experiment outputs: markdown tables, CSV series, and
//! machine-readable bench JSON — every bench/example funnels its rows
//! through here so EXPERIMENTS.md entries are regenerated in a uniform
//! format and `BENCH_*.json` files seed the perf trajectory that later
//! PRs regress against.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::json::Json;

pub mod compare;

/// A simple column-aligned markdown table builder.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(),
                   "row arity != header arity");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn to_markdown(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> =
            self.headers.iter().map(String::len).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "### {}\n", self.title);
        }
        let line = |cells: &[String], out: &mut String| {
            let mut s = String::from("|");
            for i in 0..ncol {
                let _ = write!(s, " {:width$} |", cells[i],
                               width = widths[i]);
            }
            let _ = writeln!(out, "{s}");
        };
        line(&self.headers, &mut out);
        let sep: Vec<String> =
            widths.iter().map(|w| "-".repeat(*w)).collect();
        line(&sep, &mut out);
        for r in &self.rows {
            line(r, &mut out);
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = self.headers.join(",");
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a float with fixed decimals (bench output convention).
pub fn f(x: f64, decimals: usize) -> String {
    format!("{x:.decimals$}")
}

/// Format a percentage.
pub fn pct(x: f64) -> String {
    format!("{:.1}%", 100.0 * x)
}

/// Write a CSV series next to the bench output for plotting.
pub fn write_csv(path: &str, table: &Table) -> std::io::Result<()> {
    if let Some(parent) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, table.to_csv())
}

/// Machine-readable bench telemetry. Each bench builds one of these
/// and [`BenchJson::write`]s it as `BENCH_<name>.json` in the working
/// directory (the repo root under `cargo bench`), giving every future
/// PR a baseline to regress against:
///
/// ```json
/// { "bench": "softmax",
///   "meta": { "reps": 8 },
///   "results": [ {"bits": 2, "scalar_us": ..., "batched_us": ...} ] }
/// ```
#[derive(Clone, Debug)]
pub struct BenchJson {
    name: String,
    meta: BTreeMap<String, Json>,
    results: Vec<Json>,
}

impl BenchJson {
    pub fn new(name: &str) -> Self {
        Self {
            name: name.to_string(),
            meta: BTreeMap::new(),
            results: Vec::new(),
        }
    }

    /// Attach a run-level metadata field (reps, request counts, …).
    pub fn meta(&mut self, key: &str, value: Json) -> &mut Self {
        self.meta.insert(key.to_string(), value);
        self
    }

    /// Append one result record (an object built from `fields`).
    pub fn result(&mut self, fields: &[(&str, Json)]) -> &mut Self {
        let mut obj = BTreeMap::new();
        for (k, v) in fields {
            obj.insert((*k).to_string(), v.clone());
        }
        self.results.push(Json::Obj(obj));
        self
    }

    pub fn to_json(&self) -> Json {
        let mut root = BTreeMap::new();
        root.insert("bench".to_string(),
                    Json::Str(self.name.clone()));
        root.insert("meta".to_string(),
                    Json::Obj(self.meta.clone()));
        root.insert("results".to_string(),
                    Json::Arr(self.results.clone()));
        Json::Obj(root)
    }

    /// Canonical output path: `BENCH_<name>.json`.
    pub fn path(&self) -> String {
        format!("BENCH_{}.json", self.name)
    }

    /// Serialise to `BENCH_<name>.json`; returns the path written.
    /// When `EXAQ_BENCH_COMMIT=1`, also snapshot the same document to
    /// `BENCH_baseline/BENCH_<name>.json` — the checked-in baseline
    /// the `repro compare` regression gate diffs future runs against.
    pub fn write(&self) -> std::io::Result<String> {
        let path = self.path();
        let mut body = self.to_json().to_string_pretty();
        body.push('\n');
        std::fs::write(&path, &body)?;
        if std::env::var("EXAQ_BENCH_COMMIT").as_deref() == Ok("1") {
            std::fs::create_dir_all("BENCH_baseline")?;
            std::fs::write(format!("BENCH_baseline/{path}"), &body)?;
        }
        Ok(path)
    }
}

/// Shorthand numeric JSON value for [`BenchJson`] rows.
pub fn jnum(x: f64) -> Json {
    Json::Num(x)
}

/// Shorthand string JSON value for [`BenchJson`] rows.
pub fn jstr(s: &str) -> Json {
    Json::Str(s.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = Table::new("Demo", &["name", "value"]);
        t.row(&["short".into(), "1".into()]);
        t.row(&["a-much-longer-name".into(), "2.5".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| a-much-longer-name | 2.5   |"));
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(&["1".into()]);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(f(1.23456, 2), "1.23");
        assert_eq!(pct(0.369), "36.9%");
    }

    #[test]
    fn bench_json_roundtrips_through_the_parser() {
        let mut b = BenchJson::new("demo");
        b.meta("reps", jnum(8.0));
        b.result(&[("bits", jnum(2.0)), ("mode", jstr("batched")),
                   ("us", jnum(1.25))]);
        b.result(&[("bits", jnum(3.0)), ("mode", jstr("scalar")),
                   ("us", jnum(2.5))]);
        assert_eq!(b.path(), "BENCH_demo.json");
        let re = Json::parse(&b.to_json().to_string_pretty()).unwrap();
        assert_eq!(re.get("bench").unwrap().as_str(), Some("demo"));
        assert_eq!(re.at(&["meta", "reps"]).unwrap().as_f64(),
                   Some(8.0));
        let rows = re.get("results").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("mode").unwrap().as_str(),
                   Some("batched"));
        assert_eq!(rows[1].get("us").unwrap().as_f64(), Some(2.5));
    }
}
