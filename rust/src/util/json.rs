//! Minimal dependency-free JSON parser + writer.
//!
//! The image ships no serde, so the runtime parses `manifest.json`,
//! `calibration.json` and the world golden dumps with this module. It
//! supports the full JSON grammar the build pipeline emits (objects,
//! arrays, strings with escapes, numbers incl. exponents, bools, null).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let b = s.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Panic-free lookup chain helper: `j.at(&["models", "s", "config"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Array of numbers -> Vec<f64>.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(Json::as_f64).collect()
    }

    pub fn as_str_vec(&self) -> Option<Vec<String>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_str().map(str::to_owned))
            .collect()
    }

    // -- writer ----------------------------------------------------------
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&" ".repeat(indent + 1));
                    v.write(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&" ".repeat(indent));
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&" ".repeat(indent + 1));
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&" ".repeat(indent));
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4],
                            )
                            .map_err(|_| self.err("bad \\u"))?;
                            let n = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u"))?;
                            self.i += 4;
                            out.push(char::from_u32(n).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                Some(_) => {
                    // advance over one UTF-8 char
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("bad utf8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": null, "d": true},
                      "s": "he\"llo\nworld"}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.at(&["b", "c"]), Some(&Json::Null));
        assert_eq!(v.get("a").unwrap().as_f64_vec().unwrap(),
                   vec![1.0, 2.5, -300.0]);
        let re = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""Aéü""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aéü");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("0.125").unwrap().as_f64(), Some(0.125));
        assert_eq!(Json::parse("-7").unwrap().as_f64(), Some(-7.0));
        assert_eq!(Json::parse("1e-3").unwrap().as_f64(), Some(0.001));
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"xs": ["a", "b"], "n": 3}"#).unwrap();
        assert_eq!(v.get("xs").unwrap().as_str_vec().unwrap(),
                   vec!["a".to_string(), "b".to_string()]);
        assert_eq!(v.get("n").unwrap().as_usize(), Some(3));
        assert!(v.get("missing").is_none());
    }
}
