//! Minimal `anyhow`-style error substrate.
//!
//! The build image vendors no crates, so the whole workspace compiles
//! dependency-free against this module: a message-carrying [`Error`], a
//! defaulted [`Result`] alias, the [`Context`] extension trait, and the
//! [`anyhow!`]/[`bail!`] macros with their familiar spelling.
//!
//! Like `anyhow::Error`, [`Error`] deliberately does NOT implement
//! `std::error::Error` — that keeps the blanket `From<E: Error>` impl
//! (which powers `?` on `io::Error`, parse errors, `JsonError`, …)
//! coherent with the reflexive `From<T> for T`.

use std::fmt;

/// A human-readable error message, possibly built up from context.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Self { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// Crate-wide result type (error defaults to [`Error`]).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(|| ..)` in the `anyhow` idiom.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
                                                       -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
                                                       -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
                                                       -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// `anyhow!("...")` — format a message into an [`Error`] value.
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// `bail!("...")` — early-return `Err(anyhow!(...))`.
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)))
    };
}

pub use anyhow;
pub use bail;

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/file")?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn context_chains_messages() {
        let r: std::result::Result<(), std::fmt::Error> =
            Err(std::fmt::Error);
        let e = r.context("outer").unwrap_err();
        assert!(e.to_string().starts_with("outer: "));
        let o: Option<u32> = None;
        assert_eq!(o.with_context(|| "missing").unwrap_err().to_string(),
                   "missing");
    }

    #[test]
    fn macros_format_and_bail() {
        fn f(x: u32) -> Result<u32> {
            if x == 0 {
                bail!("zero is not allowed (got {x})");
            }
            Err(anyhow!("always fails with {x}"))
        }
        assert_eq!(f(0).unwrap_err().to_string(),
                   "zero is not allowed (got 0)");
        assert_eq!(f(3).unwrap_err().to_string(), "always fails with 3");
    }
}
