//! Time source abstraction for the serving stack.
//!
//! The coordinator used to read `Instant::now()` directly, which made
//! TTFT/latency metrics untestable. Everything now goes through
//! [`Clock`]: [`WallClock`] for real serving, [`VirtualClock`] for the
//! deterministic simulation harness, where backends *advance* time by
//! their modeled step latency and metrics become exactly reproducible.

use std::cell::Cell;
use std::rc::Rc;
use std::time::{Duration, Instant};

/// A monotonically non-decreasing time source, in seconds since the
/// clock's own epoch.
pub trait Clock {
    /// Seconds elapsed since the clock was created.
    fn now(&self) -> f64;

    /// Let `dt` seconds pass: a virtual clock jumps, a wall clock
    /// sleeps. No-op for `dt <= 0`.
    fn advance(&self, dt: f64);
}

/// Shared handle used by schedulers and simulation backends (serving is
/// single-threaded per scheduler, so `Rc` suffices).
pub type SharedClock = Rc<dyn Clock>;

/// Real time, anchored at construction.
pub struct WallClock {
    epoch: Instant,
}

impl WallClock {
    pub fn new() -> Self {
        Self { epoch: Instant::now() }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for WallClock {
    fn now(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    fn advance(&self, dt: f64) {
        if dt > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(dt));
        }
    }
}

/// Host-side elapsed-time stopwatch for bench harnesses and compile /
/// execute timing. Together with [`WallClock`] this is the only
/// sanctioned wall-time entry point: the `clock-discipline` lint rule
/// (`crate::lint`) rejects raw `Instant` / `SystemTime` reads outside
/// this module, so host timing can never leak into the deterministic
/// serving or kernel paths unnoticed.
#[derive(Clone, Copy, Debug)]
pub struct Stopwatch {
    epoch: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self { epoch: Instant::now() }
    }

    /// Seconds since [`Stopwatch::start`].
    pub fn seconds(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    /// Whole microseconds since [`Stopwatch::start`].
    pub fn micros(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }
}

/// Deterministic simulated time: starts at 0.0 and moves only when
/// someone calls [`Clock::advance`].
pub struct VirtualClock {
    t: Cell<f64>,
}

impl VirtualClock {
    pub fn new() -> Self {
        Self { t: Cell::new(0.0) }
    }
}

impl Default for VirtualClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> f64 {
        self.t.get()
    }

    fn advance(&self, dt: f64) {
        if dt > 0.0 {
            self.t.set(self.t.get() + dt);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_clock_only_moves_on_advance() {
        let c = VirtualClock::new();
        assert_eq!(c.now(), 0.0);
        c.advance(0.25);
        c.advance(0.5);
        assert_eq!(c.now(), 0.75);
        c.advance(-1.0); // ignored
        c.advance(0.0); // ignored
        assert_eq!(c.now(), 0.75);
    }

    #[test]
    fn wall_clock_is_monotonic() {
        let c = WallClock::new();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
        assert!(a >= 0.0);
    }

    #[test]
    fn stopwatch_is_monotonic() {
        let w = Stopwatch::start();
        let a = w.seconds();
        let b = w.seconds();
        assert!(a >= 0.0);
        assert!(b >= a);
        let us = w.micros();
        assert!(us as f64 / 1e6 <= w.seconds() + 1e-3);
    }

    #[test]
    fn shared_clock_is_shared() {
        let v = Rc::new(VirtualClock::new());
        let c: SharedClock = v.clone();
        c.advance(1.5);
        assert_eq!(v.now(), 1.5);
    }
}
