//! Small shared substrates: deterministic RNG, a dependency-free JSON
//! parser/writer (the image has no serde; artifacts/manifest.json and
//! calibration.json are parsed with [`json`]), the `anyhow`-style
//! [`error`] module every layer's `Result` flows through, the
//! [`clock`] abstraction (wall vs virtual time) the serving coordinator
//! is tested against, and the scoped worker [`pool`] — the one
//! sanctioned `std::thread` site (`thread-discipline` lint).

pub mod clock;
pub mod error;
pub mod json;
pub mod pool;
pub mod rng;
