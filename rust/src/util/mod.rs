//! Small shared substrates: deterministic RNG and a dependency-free JSON
//! parser/writer (the image has no serde; artifacts/manifest.json and
//! calibration.json are parsed with [`json`]).

pub mod json;
pub mod rng;
