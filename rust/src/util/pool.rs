//! The one sanctioned `std::thread` site: a hand-rolled scoped
//! work-distributing pool (the crate is dependency-free — no rayon).
//!
//! [`run_chunks`] takes an explicit list of work chunks and drains it
//! with `threads` scoped workers self-scheduling off a shared atomic
//! cursor — dynamic load balancing with zero channels and zero
//! allocation beyond the slot vector. Determinism falls out of the
//! shape of the work, not the schedule: every chunk owns a disjoint
//! `&mut` region fixed *before* any worker starts, and chunk results
//! land only inside that region, so output is bit-identical for any
//! thread count or interleaving. Callers (the `BatchSoftmax` plane
//! kernel) keep per-chunk scratch inside the worker closure, so no
//! state leaks across chunks either.
//!
//! The `thread-discipline` lint pins raw `std::thread::spawn`/`scope`
//! to this file; everything else parallelises by building chunks and
//! calling in here.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};

/// Default worker count: `EXAQ_THREADS` if set to a positive integer,
/// else `std::thread::available_parallelism()`. Read once per process.
pub fn default_threads() -> usize {
    static CACHED: OnceLock<usize> = OnceLock::new();
    *CACHED.get_or_init(|| {
        if cfg!(miri) {
            // Keep the interpreted test runs single-threaded unless a
            // test opts in explicitly via `set_threads`.
            return 1;
        }
        let from_env = std::env::var("EXAQ_THREADS")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .filter(|&n| n > 0);
        from_env.unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        })
    })
}

/// Run `f` over every chunk, on up to `threads` scoped workers.
///
/// Chunks are claimed dynamically (atomic cursor), so a slow chunk
/// does not stall the rest of the queue; each chunk is processed
/// exactly once. With `threads <= 1` or a single chunk the call runs
/// inline on the caller's thread — same results, no spawns.
pub fn run_chunks<T, F>(chunks: Vec<T>, threads: usize, f: F)
where
    T: Send,
    F: Fn(T) + Sync,
{
    let workers = threads.min(chunks.len());
    if workers <= 1 {
        for c in chunks {
            f(c);
        }
        return;
    }
    let slots: Vec<Mutex<Option<T>>> =
        chunks.into_iter().map(|c| Mutex::new(Some(c))).collect();
    let cursor = AtomicUsize::new(0);
    let drain = |slots: &[Mutex<Option<T>>]| loop {
        let i = cursor.fetch_add(1, Ordering::Relaxed);
        let Some(slot) = slots.get(i) else { break };
        // A poisoned slot means a sibling worker panicked mid-chunk;
        // the scope is about to propagate that panic, so just skip.
        let item = slot.lock().ok().and_then(|mut g| g.take());
        if let Some(c) = item {
            f(c);
        }
    };
    std::thread::scope(|s| {
        for _ in 1..workers {
            s.spawn(|| drain(&slots));
        }
        drain(&slots);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn every_chunk_runs_exactly_once_under_any_thread_count() {
        for threads in [1usize, 2, 7, 64] {
            let hits: Vec<AtomicU64> =
                (0..33).map(|_| AtomicU64::new(0)).collect();
            let chunks: Vec<usize> = (0..33).collect();
            run_chunks(chunks, threads, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1,
                           "threads={threads} chunk {i}");
            }
        }
    }

    #[test]
    fn chunk_outputs_land_in_their_own_regions() {
        // The determinism contract: results live in per-chunk &mut
        // regions decided before any worker starts.
        let mut data = vec![0u64; 40];
        let chunks: Vec<(usize, &mut [u64])> =
            data.chunks_mut(7).enumerate().collect();
        run_chunks(chunks, 5, |(idx, slice)| {
            for (j, x) in slice.iter_mut().enumerate() {
                *x = (idx as u64) << 8 | j as u64;
            }
        });
        for (i, &x) in data.iter().enumerate() {
            assert_eq!(x, ((i / 7) as u64) << 8 | (i % 7) as u64);
        }
    }

    #[test]
    fn empty_and_single_chunk_run_inline() {
        run_chunks(Vec::<usize>::new(), 8, |_| unreachable!());
        let seen = AtomicU64::new(0);
        run_chunks(vec![41usize], 8, |x| {
            seen.store(x as u64 + 1, Ordering::Relaxed);
        });
        assert_eq!(seen.load(Ordering::Relaxed), 42);
    }

    #[test]
    fn default_threads_is_positive_and_stable() {
        let a = default_threads();
        assert!(a >= 1);
        assert_eq!(a, default_threads());
    }
}
