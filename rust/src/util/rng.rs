//! SplitMix64 — the shared deterministic PRNG.
//!
//! Bit-for-bit identical to `python/compile/corpus.py::SplitMix64`; the
//! world/corpus/task generators on both sides depend on this equivalence
//! (pinned by the golden-dump test against `artifacts/world_family*.json`).

/// SplitMix64 PRNG (Steele et al.). `next_u64` sequence must match the
/// Python implementation exactly.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Plain-modulo draw in `0..n` (spec'd as modulo in both languages).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// f64 in [0,1): top 53 bits / 2^53.
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Standard normal via Box-Muller (used by the Fig. 3 Monte-Carlo
    /// simulation; not part of the cross-language corpus spec).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_sequence() {
        // Reference values computed from the Python implementation.
        let mut r = SplitMix64::new(1);
        let seq: Vec<u64> = (0..4).map(|_| r.next_u64()).collect();
        let mut py = SplitMix64::new(1);
        assert_eq!(seq[0], py.next_u64());
        // determinism + full-period style sanity
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = SplitMix64::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_range_and_mean_centered() {
        let mut r = SplitMix64::new(7);
        let mut acc = 0.0;
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            acc += u;
        }
        let mean = acc / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = SplitMix64::new(9);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
