//! Quickstart: load the artifact bundle, generate text with the EXAQ
//! 2-bit softmax, print tokens/s.
//!
//!     cargo run --release --example quickstart
//!
//! (run `make artifacts` first.)

use std::path::Path;
use std::rc::Rc;

use exaq_repro::calib;
use exaq_repro::coordinator::{serve_until_drained, Request, ServeConfig};
use exaq_repro::exaq::clip_exaq;
use exaq_repro::model::{SamplingParams, Tokenizer};
use exaq_repro::runtime::{Engine, QuantMode};
use exaq_repro::util::clock::WallClock;
use exaq_repro::util::error::Result;

fn main() -> Result<()> {
    let dir = Path::new("artifacts");
    let mut engine = Engine::load(dir)?;
    let tok = Tokenizer::from_manifest(&engine.manifest);
    let model = "s";

    // calibrated EXAQ clip thresholds (paper Table 1 applied to the
    // calibration sigmas)
    let cal = calib::load_calibration(dir, model)
        .or_else(|_| calib::calibrate(&mut engine, model))?;
    let c_vec = clip_exaq(&cal.layers, 2);
    println!("per-layer clip thresholds: {c_vec:?}");

    let cfg = ServeConfig {
        model: model.into(),
        quant: QuantMode::Static { bits: 2 },
        c_vec: Some(c_vec),
        decode_batch: 8,
    };
    let prompts = ["alice is in the", "the ball is", "bob has the"];
    let reqs: Vec<Request> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| Request::new(i as u64, tok.encode(p).unwrap(),
                                   10, SamplingParams::greedy()))
        .collect();

    let (mut resps, wall, _) =
        serve_until_drained(&mut engine, &cfg, reqs,
                            Rc::new(WallClock::new()))?;
    resps.sort_by_key(|r| r.id);
    let total: usize = resps.iter().map(|r| r.tokens.len()).sum();
    for r in &resps {
        println!("{} -> {}", prompts[r.id as usize],
                 tok.decode(&r.tokens));
    }
    println!("\n{total} tokens in {wall:.2}s = {:.1} tok/s \
              (EXAQ 2-bit softmax)", total as f64 / wall);
    Ok(())
}
