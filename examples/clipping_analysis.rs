//! Fig. 2 + Fig. 3 + Table 1 from the analytic stack: the distortion
//! decomposition, the sigma sweep (analysis vs simulation), and the
//! regenerated linear approximation — including the documented
//! discrepancy of the literal mean-zero reading (EXPERIMENTS.md).
//!
//!     cargo run --release --example clipping_analysis

use exaq_repro::exaq::fit::{fit_table1, SIGMA_RANGE};
use exaq_repro::exaq::mc::simulated_optimal_clip;
use exaq_repro::exaq::mse::MseModel;
use exaq_repro::exaq::solver::{minimise_clip, optimal_clip,
                               optimal_clip_mean_zero};
use exaq_repro::report::{f as fnum, Table};

fn main() {
    // Fig. 2
    let model = MseModel::max_shifted(1.0, 2);
    let mut fig2 = Table::new(
        "Fig. 2 — distortion decomposition (sigma=1, M=2)",
        &["C", "MSE_quant", "MSE_clip", "MSE_total"]);
    for p in model.curve(-9.0, -0.5, 18) {
        fig2.row(&[fnum(p.c, 2), format!("{:.3e}", p.quant),
                   format!("{:.3e}", p.clip),
                   format!("{:.3e}", p.total)]);
    }
    println!("{}", fig2.to_markdown());
    println!("C* = {:.3}\n", minimise_clip(&model));

    // Fig. 3
    let mut fig3 = Table::new(
        "Fig. 3 — optimal clip vs sigma",
        &["sigma", "M=2 analytic", "M=2 sim", "M=2 paper",
          "M=3 analytic", "M=3 sim", "M=3 paper"]);
    for i in 0..6 {
        let s = 0.9 + i as f64 * 0.5;
        fig3.row(&[
            fnum(s, 2),
            fnum(optimal_clip(s, 2), 2),
            fnum(simulated_optimal_clip(s, 2, 12, 5 + i as u64), 2),
            fnum(-1.66 * s - 1.85, 2),
            fnum(optimal_clip(s, 3), 2),
            fnum(simulated_optimal_clip(s, 3, 12, 50 + i as u64), 2),
            fnum(-1.75 * s - 2.06, 2),
        ]);
    }
    println!("{}", fig3.to_markdown());

    // Table 1
    let mut t1 = Table::new(
        &format!("Table 1 — linear fit over sigma ∈ [{}, {}]",
                 SIGMA_RANGE.0, SIGMA_RANGE.1),
        &["M", "ours", "paper"]);
    for (bits, paper) in [(2u32, "-1.66·σ - 1.85"),
                          (3, "-1.75·σ - 2.06"), (4, "(extension)")] {
        let f = fit_table1(bits);
        t1.row(&[bits.to_string(),
                 format!("{:.2}·σ {:+.2}", f.slope, f.intercept),
                 paper.to_string()]);
    }
    println!("{}", t1.to_markdown());

    // Soundness note demonstration
    println!("literal mean-0 reading:  C*(1, M=2) = {:.3}  \
              (Table 1 says -3.51 — see EXPERIMENTS.md §Soundness)",
             optimal_clip_mean_zero(1.0, 2));
}
