//! Table 2/5 sweep: NONE / NAIVE / EXAQ × INT2/INT3 across model sizes
//! and the seven task families.
//!
//!     cargo run --release --example accuracy_sweep [models] [n] [seeds]
//!
//! e.g. `accuracy_sweep s,m,l,xl 40 3` regenerates the full Table 2 + 4
//! analogue; `accuracy_sweep v2-s,v2-m,v2-l 40 3` the Table 5 + 6 one.

use std::path::Path;

use exaq_repro::calib;
use exaq_repro::eval::{eval_task, family_world_seed, mean_std, World,
                       ALL_TASKS};
use exaq_repro::exaq::{clip_exaq, clip_naive};
use exaq_repro::report::{f as fnum, Table};
use exaq_repro::runtime::{Engine, QuantMode};
use exaq_repro::util::error::Result;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let models = args.first().map(String::as_str).unwrap_or("s,m");
    let n: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(30);
    let seeds: usize =
        args.get(2).and_then(|s| s.parse().ok()).unwrap_or(1);

    let dir = Path::new("artifacts");
    let mut engine = Engine::load(dir)?;

    for model in models.split(',') {
        let entry = engine.manifest.model(model)?.clone();
        let world = World::build(family_world_seed(entry.family));
        let cal = calib::load_calibration(dir, model)
            .or_else(|_| calib::calibrate(&mut engine, model))?;
        let configs: Vec<(&str, QuantMode, Option<Vec<f32>>)> = vec![
            ("NONE", QuantMode::None, None),
            ("NAIVE-INT2", QuantMode::Static { bits: 2 },
             Some(clip_naive(&cal.layers))),
            ("EXAQ-INT2", QuantMode::Static { bits: 2 },
             Some(clip_exaq(&cal.layers, 2))),
            ("NAIVE-INT3", QuantMode::Static { bits: 3 },
             Some(clip_naive(&cal.layers))),
            ("EXAQ-INT3", QuantMode::Static { bits: 3 },
             Some(clip_exaq(&cal.layers, 3))),
        ];
        let mut headers = vec!["config".to_string()];
        headers.extend(ALL_TASKS.iter().map(|t| t.name().to_string()));
        headers.push("avg".into());
        let hdr: Vec<&str> = headers.iter().map(String::as_str).collect();
        let mut t = Table::new(
            &format!("model {model} ({} params) — n={n}, seeds={seeds}",
                     entry.config.n_params),
            &hdr);
        for (name, quant, c_vec) in &configs {
            let mut cells = vec![name.to_string()];
            let mut sum = 0.0;
            for task in ALL_TASKS {
                let mut accs = Vec::new();
                for s in 0..seeds {
                    let r = eval_task(&mut engine, model, *quant,
                                      c_vec.as_deref(), task, &world, n,
                                      1000 + s as u64 * 7919)?;
                    accs.push(r.accuracy * 100.0);
                }
                let (m, _) = mean_std(&accs);
                sum += m;
                cells.push(fnum(m, 1));
            }
            cells.push(fnum(sum / ALL_TASKS.len() as f64, 1));
            t.row(&cells);
            eprintln!("[sweep] {model} {name} done");
        }
        println!("{}", t.to_markdown());
        let _ = exaq_repro::report::write_csv(
            &format!("reports/accuracy_{model}.csv"), &t);
    }
    Ok(())
}
