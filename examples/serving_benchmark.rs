//! End-to-end serving benchmark (DESIGN.md's end-to-end driver): a
//! synthetic request trace through the continuous-batching coordinator,
//! reporting latency/throughput for the exact vs EXAQ-quantized softmax
//! configurations.
//!
//!     cargo run --release --example serving_benchmark [model] [n_req]

use std::path::Path;
use std::rc::Rc;

use exaq_repro::calib;
use exaq_repro::coordinator::{serve_until_drained, Request, ServeConfig};
use exaq_repro::eval::{family_world_seed, Task, World};
use exaq_repro::exaq::clip_exaq;
use exaq_repro::model::{SamplingParams, Tokenizer};
use exaq_repro::report::{f as fnum, Table};
use exaq_repro::runtime::{Engine, QuantMode};
use exaq_repro::util::clock::WallClock;
use exaq_repro::util::error::Result;
use exaq_repro::util::rng::SplitMix64;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(String::as_str).unwrap_or("s");
    let n_req: usize =
        args.get(1).and_then(|s| s.parse().ok()).unwrap_or(24);

    let dir = Path::new("artifacts");
    let mut engine = Engine::load(dir)?;
    let tok = Tokenizer::from_manifest(&engine.manifest);
    let entry = engine.manifest.model(model)?.clone();
    let world = World::build(family_world_seed(entry.family));
    let cal = calib::load_calibration(dir, model)
        .or_else(|_| calib::calibrate(&mut engine, model))?;

    let make_trace = |seed: u64| -> Vec<Request> {
        let mut rng = SplitMix64::new(seed);
        (0..n_req as u64)
            .map(|id| {
                let task = [Task::Completion, Task::OpenBook,
                            Task::ArcEasy][rng.below(3)];
                let inst = task.generate(&world, &mut rng);
                Request::new(
                    id,
                    inst.prompt.iter()
                        .map(|w| tok.id(w).unwrap()).collect(),
                    8 + rng.below(9),
                    SamplingParams::greedy(),
                )
            })
            .collect()
    };

    let mut t = Table::new(
        &format!("Serving benchmark — model {model}, {n_req} requests, \
                  decode batch 8"),
        &["softmax", "tok/s", "p50 ttft (s)", "p50 latency (s)",
          "mean batch occupancy"]);
    for (name, quant, c_vec) in [
        ("exact", QuantMode::None, None),
        ("EXAQ INT3", QuantMode::Static { bits: 3 },
         Some(clip_exaq(&cal.layers, 3))),
        ("EXAQ INT2", QuantMode::Static { bits: 2 },
         Some(clip_exaq(&cal.layers, 2))),
    ] {
        let cfg = ServeConfig {
            model: model.into(),
            quant,
            c_vec,
            decode_batch: 8,
        };
        let (resps, wall, sched) =
            serve_until_drained(&mut engine, &cfg, make_trace(11),
                                Rc::new(WallClock::new()))?;
        let toks: usize = resps.iter().map(|r| r.tokens.len()).sum();
        t.row(&[name.into(), fnum(toks as f64 / wall, 1),
                fnum(sched.metrics().ttft.quantile(0.5), 3),
                fnum(sched.metrics().total_latency.quantile(0.5), 3),
                fnum(sched.metrics().mean_occupancy(), 2)]);
        assert_eq!(resps.len(), n_req, "all requests must complete");
    }
    println!("{}", t.to_markdown());
    let _ = exaq_repro::report::write_csv(
        "reports/serving_benchmark.csv", &t);
    Ok(())
}
